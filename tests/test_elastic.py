"""Elastic control-plane tests: cross-process live migration over the
snapshot wire codec, worker drain (scale-in), and shape-affinity routing.

The migration lock: a tenant extracted on worker A, shipped over the wire,
and admitted on worker B must be **bit-for-bit identical** — final engine
state and every deterministic counter — to the same tenant run solo,
uninterrupted, in this process.  Per-tick-seeded synth features and the
snapshot-carried LatencyTeacher state make that comparable across
processes.
"""

import json
import time

import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import snapshot, stream
from repro.runtime import elastic
from repro.runtime import worker as worker_mod

# Wall-clock fields (tick_ms, wall_s, tick_rate_ema, ring HWM timing) can't
# match across runs; everything here must.
DETERMINISTIC_STATS = (
    "ticks", "stream_steps", "tickets_issued", "queries_issued",
    "labels_applied", "tickets_dropped", "queries_dropped",
    "replies_orphaned", "tickets_lost", "queries_lost",
    "tickets_coalesced", "queries_coalesced", "asks_deferred",
    "tickets_reasked",
)

T_TOTAL = 400
# Migrate once every tenant has passed this tick.  Low on purpose: the
# source worker keeps streaming while earlier tenants quiesce, so the last
# extract must still land well before T_TOTAL.
CUT_AT = 40


def _cfg():
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=24, n_hidden=16, n_out=4, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=1_000_000),
        drift=drift_mod.DriftConfig(),
    )


def _spec(name, policy, seed, tick_sleep_ms=3.0):
    return worker_mod.tenant_spec(
        name, _cfg(), s=4, mode="train_phase", capacity=4,
        backpressure=policy,
        ticks=worker_mod.synth_ticks_spec(
            seed=seed, t_total=T_TOTAL, tick_sleep_ms=tick_sleep_ms
        ),
        teacher=worker_mod.latency_teacher_spec(
            n_out=4, latency=2, jitter=2, loss=0.2, partial=0.15, seed=seed
        ),
    )


def _solo_reference(spec):
    """The uninterrupted run the migrated tenant must reproduce, built from
    the *same spec builders* the workers use (sleep stripped: tick values
    depend only on (seed, tick))."""
    solo = dict(spec, ticks=dict(spec["ticks"], tick_sleep_ms=0.0))
    it = iter(worker_mod._build_ticks(solo, {}))
    teacher = worker_mod._build_teacher(solo, {})
    cfg = snapshot.config_from_dict(solo["cfg"])
    sess = stream.StreamSession(
        engine.init_fleet(cfg, solo["s"]), cfg, teacher, mode=solo["mode"],
        capacity=solo["capacity"], backpressure=solo["backpressure"],
    )
    sess.start(next(it))
    while sess._p is not None:
        sess.advance(next(it, None))
    sess.drain_replies()
    state, _, stats = sess.finish()
    return state, stats


def _assert_state_trees_equal(a, b, msg):
    from repro.runtime import checkpoint as ckpt

    fa, fb = dict(ckpt._flatten(a)), dict(ckpt._flatten(b))
    assert sorted(fa) == sorted(fb), f"{msg}: leaf sets differ"
    for path in fa:
        xa, xb = np.asarray(fa[path]), np.asarray(fb[path])
        assert xa.dtype == xb.dtype and xa.tobytes() == xb.tobytes(), (
            f"{msg}: state leaf {'/'.join(path)} diverged"
        )


@pytest.fixture(scope="module")
def fleet():
    """Two worker subprocesses shared by every test in this module (each
    spawn pays the worker's jax import).  quantum=1 keeps the mux lock
    hold per round short (~one tick per member), so control commands —
    the four back-to-back extracts especially — don't queue behind whole
    scheduler rounds while the source tenants race toward T_TOTAL."""
    workers = [elastic.spawn_worker(f"tw{i}", quantum=1) for i in range(2)]
    yield workers
    for w in workers:
        w.close(shutdown=True)


def _wait_live_at(client, names, tick, timeout_s=120.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        rows = {t["name"]: t for t in client.status()["live"]}
        if any(n not in rows for n in names):
            raise AssertionError(
                f"tenant finished before the migration point: have {sorted(rows)}"
            )
        if all(rows[n]["t"] >= tick for n in names):
            return rows
        time.sleep(0.01)
    raise TimeoutError(f"{names} never reached tick {tick}")


def test_migrate_bit_for_bit_all_policies(fleet):
    """ALL FOUR backpressure policies, concurrently (they fuse into one
    cohort on the source worker): extract on worker A mid-stream, ship the
    wire bytes through this process, admit on worker B — final state and
    deterministic stats equal the uninterrupted solo run."""
    w_a, w_b = fleet
    specs = {
        policy: _spec(f"mig_{policy}", policy, seed=300 + i)
        for i, policy in enumerate(stream.BACKPRESSURE_POLICIES)
    }
    for spec in specs.values():
        w_a.admit(spec)
    names = [s["name"] for s in specs.values()]
    _wait_live_at(w_a, names, CUT_AT)

    for policy, spec in specs.items():
        sent_spec, wire = w_a.extract(spec["name"])
        # The spec crossed a JSON boundary (tuples become lists); compare
        # JSON-normalized.
        assert sent_spec == json.loads(json.dumps(spec))
        assert isinstance(wire, bytes) and len(wire) > 0
        cut = snapshot.ticks_consumed(snapshot.decode_snapshot(wire))
        assert CUT_AT <= cut < T_TOTAL, f"{policy}: cut at {cut} not mid-stream"
        reply = w_b.admit(sent_spec, wire)
        assert reply["migrated"] is True

    router = elastic.Router(list(fleet))
    router.wait_finished(names, timeout_s=180)

    for policy, spec in specs.items():
        stats_wire, tree = w_b.result(spec["name"])
        solo_state, solo_stats = _solo_reference(spec)
        _assert_state_trees_equal(
            snapshot.state_to_tree(solo_state), tree["state"],
            f"policy {policy}"
        )
        for f in DETERMINISTIC_STATS:
            assert stats_wire[f] == getattr(solo_stats, f), (
                f"policy {policy}: stats.{f} diverged: "
                f"{stats_wire[f]} != {getattr(solo_stats, f)}"
            )
        assert stats_wire["label_latency_ticks"] == list(
            solo_stats.label_latency_ticks
        ), f"policy {policy}: label latency history diverged"
        assert stats_wire["reconciled"], f"policy {policy}: accounting broke"
        # Latency-teacher state rides the snapshot: nothing was re-asked.
        assert stats_wire["tickets_reasked"] == 0


def test_drain_worker_to_zero_preserves_fleet_identity(fleet):
    """Scale-in: every live tenant on a 4-tenant worker migrates off (the
    worker drains to zero), and the fleet-wide query-accounting identity
    still reconciles after the moves."""
    w_extra = elastic.spawn_worker("tw_drain", quantum=1)
    router = elastic.Router(list(fleet) + [w_extra])
    names = []
    try:
        for i in range(4):
            spec = _spec(f"drain{i}", "drop_oldest", seed=500 + i)
            names.append(spec["name"])
            w_extra.admit(spec)
        _wait_live_at(w_extra, names, 10)  # all mid-stream
        migrated, finished_there = router.scale_in(w_extra)
        assert sorted(migrated) == sorted(names), (
            f"drain left tenants behind: moved {migrated}"
        )
        assert w_extra not in router.workers
        assert not finished_there  # all were live when the drain started
        # The drained worker's subprocess actually exited.
        assert w_extra.proc.wait(timeout=30) == 0

        router.wait_finished(names, timeout_s=180)
        results = {
            n: s for n, s in router.fleet_results().items() if n in names
        }
        assert sorted(results) == sorted(names)
        agg = elastic.reconcile(results)
        assert agg["reconciled"], f"fleet identity broke: {agg}"
        assert all(agg["per_tenant"].values())
        assert agg["queries_issued"] > 0  # the identity wasn't vacuous
        assert agg["queries_issued"] == (
            agg["labels_applied"] + agg["queries_dropped"]
            + agg["queries_lost"] + agg["queries_coalesced"]
        )
    finally:
        if w_extra in router.workers:
            w_extra.close(shutdown=True)


def test_worker_status_reports_load_signals(fleet):
    """The router's placement inputs — tick-rate EMA, ring occupancy HWM,
    shape key — are live in the worker status while a tenant streams."""
    w_a, _ = fleet
    spec = _spec("load_probe", "drop_oldest", seed=900)
    w_a.admit(spec)
    rows = _wait_live_at(w_a, ["load_probe"], 30)
    row = rows["load_probe"]
    assert row["shape_key"] == worker_mod.spec_shape_key(spec)
    assert row["tick_rate_ema"] > 0
    assert row["ring_capacity"] == spec["capacity"]
    assert 0 <= row["ring"] <= row["ring_capacity"]
    assert row["ring_hwm"] >= 1  # train_phase mode queries every tick
    assert row["s"] == spec["s"]
    elastic.Router(list(fleet)).wait_finished(["load_probe"], timeout_s=120)


def test_unknown_tenant_errors_do_not_kill_worker(fleet):
    w_a, _ = fleet
    with pytest.raises(elastic.WorkerError):
        w_a.extract("no_such_tenant")
    with pytest.raises(elastic.WorkerError):
        w_a.result("no_such_tenant")
    assert w_a.status()["kind"] == "status_ok"  # connection still live


# ---------------------------------------------------------------------------
# Router placement logic (stub workers, no subprocesses)
# ---------------------------------------------------------------------------


class _StubWorker:
    def __init__(self, name, live=()):
        self.name = name
        self.live = list(live)

    def status(self):
        return {"kind": "status_ok", "worker": self.name,
                "live": list(self.live), "finished": []}

    def admit(self, spec, snapshot_wire=b""):
        self.live.append(_row(spec["name"], worker_mod.spec_shape_key(spec),
                              s=spec["s"]))
        return {"kind": "ok", "name": spec["name"],
                "migrated": bool(snapshot_wire)}


def _row(name, key, s=4, ema=100.0, draining=False):
    return {"name": name, "t": 10, "s": s, "shape_key": key,
            "tick_rate_ema": ema, "ring": 0, "ring_hwm": 0,
            "ring_capacity": 4, "queries_issued": 0, "labels_applied": 0,
            "draining": draining, "fused": False}


def test_router_places_by_shape_affinity_under_capacity():
    """Four same-shape tenants over two capacity-2 workers split 2+2 (two
    fusable pairs), not 4+0 or 1+1+1+1 round-robin."""
    w0, w1 = _StubWorker("w0"), _StubWorker("w1")
    router = elastic.Router([w0, w1], capacity=2)
    placed = [router.admit(_spec(f"a{i}", "drop_oldest", seed=i)).name
              for i in range(4)]
    assert placed == ["w0", "w0", "w1", "w1"]


def test_router_prefers_affinity_over_emptier_worker():
    """A tenant whose shape key matches tenants on a busier (but
    under-capacity) worker goes there — cohort fusion beats spreading."""
    spec_a = _spec("x", "drop_oldest", seed=1)
    key_a = worker_mod.spec_shape_key(spec_a)
    w0 = _StubWorker("w0", [_row("t0", key_a), _row("t1", key_a)])
    w1 = _StubWorker("w1")
    router = elastic.Router([w0, w1], capacity=8)
    assert router.place(_spec("x2", "drop_oldest", seed=2)).name == "w0"
    # A different-shaped tenant prefers the empty worker instead.
    other = worker_mod.tenant_spec(
        "y", _cfg(), s=8, mode="train_phase",
        ticks=worker_mod.synth_ticks_spec(seed=3, t_total=10),
        teacher=worker_mod.latency_teacher_spec(n_out=4),
    )
    assert worker_mod.spec_shape_key(other) != key_a
    assert router.place(other).name == "w1"


def test_router_capacity_spills_before_affinity():
    """Affinity never overrides capacity: a full worker is skipped even if
    every tenant on it matches."""
    spec = _spec("z", "drop_oldest", seed=4)
    key = worker_mod.spec_shape_key(spec)
    w0 = _StubWorker("w0", [_row("t0", key), _row("t1", key)])
    w1 = _StubWorker("w1")
    router = elastic.Router([w0, w1], capacity=2)
    assert router.place(spec).name == "w1"


def test_router_draining_tenants_do_not_attract():
    """A tenant that has exhausted its ticks (draining replies) is not a
    fusion partner — placement ignores it for affinity."""
    spec = _spec("q", "drop_oldest", seed=5)
    key = worker_mod.spec_shape_key(spec)
    w0 = _StubWorker("w0", [_row("t0", key, draining=True)])
    w1 = _StubWorker("w1")
    router = elastic.Router([w0, w1], capacity=8)
    # Tie on affinity (none) -> fewest live tenants wins.
    assert router.place(spec).name == "w1"


def test_reconcile_flags_broken_identity():
    ok = {"queries_issued": 10, "labels_applied": 7, "queries_dropped": 1,
          "queries_lost": 1, "queries_coalesced": 1, "reconciled": True}
    bad = dict(ok, labels_applied=6, reconciled=False)
    agg = elastic.reconcile({"a": ok})
    assert agg["reconciled"] and agg["per_tenant"]["a"]
    agg = elastic.reconcile({"a": ok, "b": bad})
    assert not agg["reconciled"]
    assert not agg["per_tenant"]["b"]
