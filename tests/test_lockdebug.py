"""Lock-order cycle detector (REPRO_LOCK_DEBUG=1): unit + integration.

The unit tests drive the acquisition graph directly; the integration
tests run one real rpc roundtrip and one telemetry workload with
tracking enabled — the runtime's locks are created through
``lockdebug.make_*``, so these exercise the actual production lock
graph and would fail on any inconsistent acquisition order introduced
there.
"""

import threading

import numpy as np
import pytest

from repro.engine import rpc
from repro.runtime import lockdebug, telemetry


@pytest.fixture
def lock_debug(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_DEBUG", "1")
    lockdebug.GRAPH.clear()
    yield
    lockdebug.GRAPH.clear()


# ---------------------------------------------------------------------------
# unit: the graph itself
# ---------------------------------------------------------------------------


def test_plain_locks_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_DEBUG", raising=False)
    assert isinstance(lockdebug.make_lock("x"), type(threading.Lock()))
    assert not isinstance(lockdebug.make_lock("x"), lockdebug._TrackedLock)


def test_consistent_order_is_fine(lock_debug):
    a, b = lockdebug.make_lock("A"), lockdebug.make_lock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert lockdebug.GRAPH.edges() == {"A": {"B"}}


def test_cycle_raises_before_blocking(lock_debug):
    a, b = lockdebug.make_lock("A"), lockdebug.make_lock("B")
    with a:
        with b:
            pass
    with pytest.raises(lockdebug.LockOrderError, match="A -> B -> A"):
        with b:
            with a:
                pass


def test_three_lock_cycle(lock_debug):
    a = lockdebug.make_lock("A")
    b = lockdebug.make_lock("B")
    c = lockdebug.make_lock("C")
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with pytest.raises(lockdebug.LockOrderError):
        with c:
            with a:
                pass


def test_rlock_reentrancy_adds_no_edge(lock_debug):
    r = lockdebug.make_rlock("R")
    with r:
        with r:  # reentrant: no self-edge, no false cycle
            pass
    assert lockdebug.GRAPH.edges() == {}


def test_condition_wait_releases_for_order_purposes(lock_debug):
    """While cond.wait() sleeps, the underlying lock is NOT held — an
    acquisition of another lock from the waking path must not see it."""
    cond = lockdebug.make_condition("C")
    other = lockdebug.make_lock("O")
    woke = []

    def waiter():
        with cond:
            cond.wait(timeout=5.0)
            woke.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    # give the waiter time to enter wait(); then C must not be on any
    # held stack observed by a fresh acquisition
    import time

    time.sleep(0.1)
    with other:
        pass  # would add C -> O if wait() leaked the hold
    with cond:
        cond.notify_all()
    t.join(timeout=5.0)
    assert woke
    assert "C" not in lockdebug.GRAPH.edges().get("C", set())
    assert "O" not in lockdebug.GRAPH.edges().get("C", set())


# ---------------------------------------------------------------------------
# integration: rpc under REPRO_LOCK_DEBUG=1
# ---------------------------------------------------------------------------


def _drain(handle, want=1, timeout_s=10.0):
    import time

    out = []
    deadline = time.monotonic() + timeout_s
    while len(out) < want and time.monotonic() < deadline:
        out += handle.poll(0)
        time.sleep(1e-3)
    return out


def test_rpc_roundtrip_under_lock_debug(lock_debug):
    server = rpc.LabelServer(n_out=4).start()
    try:
        feats = np.zeros((2, 4), np.float32)
        mask = np.ones(2, bool)
        with rpc.BatchedRpcClient(
            "127.0.0.1", server.port, timeout_s=10.0, batch_window_s=1e-3
        ) as client:
            # the client's condition + reconnect lock are tracked proxies
            assert isinstance(
                client._cond._lock, lockdebug._TrackedLock
            )
            assert isinstance(client._reconnect_lock, lockdebug._TrackedLock)
            t = client.tenant("a")
            ticket = t.ask(feats, mask, tick=1)
            replies = _drain(t)
        assert [r.ticket for r in replies] == [ticket]
        assert replies[0].labels.tolist() == [
            rpc.expected_label(1, s, 4) for s in range(2)
        ]
    finally:
        server.close()
    # the roundtrip completed without LockOrderError and left no lock held
    assert lockdebug.GRAPH.held_stack() == []


# ---------------------------------------------------------------------------
# integration: telemetry under REPRO_LOCK_DEBUG=1
# ---------------------------------------------------------------------------


def test_telemetry_contention_under_lock_debug(lock_debug):
    tel = telemetry.Telemetry(span_capacity=256, span_sample=2)
    assert isinstance(tel.registry._lock, lockdebug._TrackedLock)
    assert isinstance(tel.tracer._lock, lockdebug._TrackedLock)

    n_threads, n_iter = 4, 200
    errs = []

    def hammer(k):
        try:
            for i in range(n_iter):
                tel.registry.count("odl_test_total", tenant=str(k))
                tok = tel.tracer.begin("test.span")
                tel.tracer.end(tok, k=k)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errs
    # sample=2 drops exactly half of each name's begins — the PR-10 race
    # fix (increment under the lock) makes this exact under contention
    assert tel.tracer.dropped == n_threads * n_iter // 2
    total = sum(
        tel.registry.get_counter("odl_test_total", tenant=str(k))
        for k in range(n_threads)
    )
    assert total == n_threads * n_iter
    assert lockdebug.GRAPH.held_stack() == []
