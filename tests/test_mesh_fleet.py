"""Mesh-sharded mega-fleet tests, run in SUBPROCESSES with XLA_FLAGS
forcing 8 host devices (same rule as tests/test_multidevice.py: jax locks
the device count at first init, and the main test process must keep
seeing 1 device).

What these lock, per the sharding acceptance criteria:

* ``run_fleet_sharded`` (GSPMD resident fleet) and ``run_fleet_shards``
  (shard-local blocked dispatch) are BIT-FOR-BIT the single-device
  ``run_fleet`` at equal S — including a non-divisible S (dead-row
  padding at the tail) and a block size that does not divide the shard
  width (the partial / padding-straddling block path).
* ``stream.run_sharded`` is bit-for-bit the solo ``stream.run`` under a
  deterministic lossless teacher, at latency 0 and > 0, with and without
  stream-axis padding.
* Label application stays shard-local: the query-accounting identity must
  hold PER SHARD (a reply can only settle a query its own shard issued),
  so any cross-shard label leak breaks one shard's reconciliation.
* Everything sharded runs inside ``sharding.activate(mesh)`` — the
  shard-local dispatch paths must not trip full-mesh sharding
  constraints on their single-device operands (``sharding.deactivate``).

Parity note: dispatch widths here are "regular" (8 / 32 / 128 / 256 /
full) — XLA vectorizes tiny odd widths (1-5 rows) differently, at which
point parity is only ~1e-5, so shard/block sizes in bitwise tests must
keep every dispatch at a regular width.
"""

import os
import subprocess
import sys
import textwrap

ENV = dict(
    os.environ,
    XLA_FLAGS="--xla_force_host_platform_device_count=8",
    PYTHONPATH="src",
    JAX_PLATFORMS="cpu",
)

_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro import engine
from repro.core import drift as drift_mod, oselm, pruning
from repro.distributed import sharding
from repro.engine import stream
from repro.launch.mesh import make_fleet_mesh

cfg = engine.EngineConfig(
    elm=oselm.OSELMConfig(n_in=12, n_hidden=16, n_out=4, variant='hash',
                          ridge=1e-2),
    prune=pruning.PruneConfig(min_trained=2),
    drift=drift_mod.DriftConfig(),
)
mesh = make_fleet_mesh()
assert int(mesh.devices.size) == 8, mesh
"""


def _run(code: str, timeout=600):
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(code)],
        env=ENV,
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_fleet_sharded_modes_bitwise_parity():
    """GSPMD + shard-local blocked runs == single-device run, bit for bit.

    S=512 divides the 8-device mesh evenly; S=1020 pads to 1024 (width
    128, 4 dead tail rows) and block=32 forces the last block of the last
    shard to straddle live and dead rows (the ``real_hi`` path)."""
    _run(
        """
        t = 4
        for s, block in ((512, None), (1020, 32)):
            kx, ky = jax.random.split(jax.random.PRNGKey(s))
            xs = jnp.tanh(jax.random.normal(kx, (t, s, 12)))
            ys = jax.random.randint(ky, (t, s), 0, 4)
            ref, _ = engine.run_fleet(engine.init_fleet(cfg, s), xs, ys, cfg,
                                      mode='train_phase', chunk=t)
            beta_ref = np.asarray(ref.elm.beta)
            p_ref = np.asarray(ref.elm.P)
            with sharding.activate(mesh):
                placed, n_pad = engine.shard_fleet(engine.init_fleet(cfg, s), cfg)
                assert n_pad == (-s) % 8, n_pad
                st, _ = engine.run_fleet_sharded(placed, xs, ys, cfg,
                                                 mode='train_phase', chunk=t)
                got = np.asarray(jax.device_get(st.elm.beta))
                assert got.shape[0] == s + n_pad
                assert np.array_equal(beta_ref, got[:s]), f'gspmd diverged S={s}'

                sh = engine.split_fleet(engine.init_fleet(cfg, s), cfg,
                                        block=block)
                sh, _ = engine.run_fleet_shards(sh, xs, ys, cfg,
                                                mode='train_phase', chunk=t)
                merged = engine.merge_fleet(sh)
            assert merged.elm.beta.shape[0] == s  # padding stripped
            assert np.array_equal(beta_ref, np.asarray(merged.elm.beta)), (
                f'blocked shard run diverged S={s}')
            assert np.array_equal(p_ref, np.asarray(merged.elm.P)), (
                f'blocked shard P diverged S={s}')
        print('OK')
        """
    )


def test_stream_run_sharded_bitwise_parity_and_shard_local_accounting():
    """Sharded streaming sessions == solo ``stream.run``, and every
    shard's query accounting reconciles on its own (the
    no-cross-shard-gather lock): labels learn back only into the shard
    that planned them, so totals match the solo run AND each per-shard
    identity holds independently."""
    _run(
        """
        t, n = 8, 8
        for s in (64, 60):  # divisible; padded (60 -> 8 shards of width 8)
            kx, ky = jax.random.split(jax.random.PRNGKey(s))
            xs = jnp.tanh(jax.random.normal(kx, (t, s, 12)))
            ys = np.asarray(jax.random.randint(ky, (t, s), 0, 4), np.int32)
            xs_host = [np.asarray(x) for x in np.asarray(xs)]
            width = (s + (-s) % n) // n
            ys_pad = np.pad(ys, ((0, 0), (0, (-s) % n)))
            for lat in (0, 3):
                solo, _, solo_stats = stream.run(
                    engine.init_fleet(cfg, s), (x for x in xs_host), cfg,
                    stream.LatencyTeacher(stream.array_labels(ys), latency=lat),
                    mode='train_phase', capacity=16, collect=False)
                with sharding.activate(mesh):
                    assert sharding.fleet_axis_size() == n
                    st, _, stats_list = stream.run_sharded(
                        engine.init_fleet(cfg, s), (x for x in xs_host), cfg,
                        lambda k: stream.LatencyTeacher(
                            stream.array_labels(
                                ys_pad[:, k * width:(k + 1) * width]),
                            latency=lat),
                        mode='train_phase', capacity=16, collect=False)
                assert st.elm.beta.shape[0] == s  # padding stripped
                assert np.array_equal(np.asarray(solo.elm.beta),
                                      np.asarray(st.elm.beta)), (
                    f'sharded stream diverged S={s} lat={lat}')
                agg = stream.aggregate_stats(stats_list,
                                             padded_streams=(-s) % n)
                assert agg['n_shards'] == n
                assert agg['queries_reconciled']  # AND over shards
                assert agg['stream_steps'] == t * s  # dead rows excluded
                assert agg['queries_issued'] == solo_stats.queries_issued
                assert agg['labels_applied'] == solo_stats.labels_applied
                assert agg['labels_applied'] > 0
                per = agg['per_shard']
                assert len(per) == n
                assert all(p['queries_reconciled'] for p in per)
                assert sum(p['queries_issued'] for p in per) == \\
                    agg['queries_issued']
                assert sum(p['labels_applied'] for p in per) == \\
                    agg['labels_applied']
        print('OK')
        """
    )


def test_run_fleet_shards_outside_mesh_scope():
    """The blocked shard path also runs with NO active mesh (explicit
    device list), and with a teacher_available mask gating learns."""
    _run(
        """
        t, s = 3, 256
        kx, ky = jax.random.split(jax.random.PRNGKey(7))
        xs = jnp.tanh(jax.random.normal(kx, (t, s, 12)))
        ys = jax.random.randint(ky, (t, s), 0, 4)
        avail = jnp.asarray(
            np.asarray(jax.random.bernoulli(jax.random.PRNGKey(9), 0.5,
                                            (t, s))))
        ref, _ = engine.run_fleet(engine.init_fleet(cfg, s), xs, ys, cfg,
                                  mode='train_phase', chunk=t,
                                  teacher_available=avail)
        sh = engine.split_fleet(engine.init_fleet(cfg, s), cfg, n_shards=4,
                                devices=jax.devices()[:4])
        sh, _ = engine.run_fleet_shards(sh, xs, ys, cfg, mode='train_phase',
                                        teacher_available=avail, chunk=t)
        merged = engine.merge_fleet(sh)
        assert np.array_equal(np.asarray(ref.elm.beta),
                              np.asarray(merged.elm.beta))
        print('OK')
        """
    )
