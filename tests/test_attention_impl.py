"""Chunked (flash-style) attention == naive attention (all mask modes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention
from repro.models.transformer import lm_hidden


def _qkv_rand(key, b, sq, sk, kv, g, hd):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, sk, kv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunked_matches_naive(causal, window, chunk):
    b, s, kv, g, hd = 2, 64, 2, 2, 16
    q, k, v = _qkv_rand(jax.random.PRNGKey(0), b, s, s, kv, g, hd)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    bias = attention._mask_bias(pos, pos, causal, window)
    want = attention._grouped_attention(q, k, v, bias)
    got = attention._chunked_grouped_attention(q, k, v, pos, pos, causal, window, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)


def test_full_model_naive_vs_chunked():
    """Full bf16 model, naive vs chunked attention.

    Tolerances come from a 10-seed audit (plus repeated runs of the same
    seed): the Frobenius relative error is tight and stable at ~0.011,
    while the elementwise max wanders 0.047-0.078 *for the same seed*
    across processes — CPU matmul threading jitters the bf16 rounding
    tail.  The old ``atol=5e-2`` sat inside that band, which is exactly
    why this test flaked on multi-file runs.  So: bound the stable
    statistic tightly (~3x margin) and the noisy one loosely (~2x the
    observed worst at the hidden states' unit scale)."""
    cfg = configs.get_config("qwen3-4b", "smoke").replace(
        attention_impl="chunked", attention_chunk=16
    )
    cfg_naive = cfg.replace(attention_impl="naive")
    from repro.models import model as M

    params = M.layers.init_params(M.build_schema(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    h1, _ = lm_hidden(params, toks, cfg_naive, remat=False)
    h2, _ = lm_hidden(params, toks, cfg, remat=False)
    a = np.asarray(h1, np.float32)
    b = np.asarray(h2, np.float32)
    fro_rel = np.linalg.norm(a - b) / np.linalg.norm(a)
    assert fro_rel < 3e-2, f"Frobenius relative error {fro_rel:.4f}"
    assert np.abs(a - b).max() < 0.15, f"max abs diff {np.abs(a - b).max():.4f}"


def test_chunked_grads_finite():
    cfg = configs.get_config("h2o-danube-1.8b", "smoke").replace(
        attention_impl="chunked", attention_chunk=16
    )
    from repro.configs.base import TrainConfig
    from repro.models import model as M

    state = M.init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, cfg.vocab_size),
        "odl_labels": jnp.zeros((2,), jnp.int32),
    }
    state2, m = jax.jit(lambda s, b: M.train_step(s, b, cfg, TrainConfig()))(state, batch)
    assert np.isfinite(float(m["loss"]))
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
