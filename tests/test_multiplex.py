"""Multi-tenant multiplexer, backpressure policies, query-accounting
reconciliation, the RpcTeacher loopback transport, and the serve path's
plan-time (stale-reply) semantics — ISSUE 3."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import multiplex, rpc, stream


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=16):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return xs, ys


def _assert_state_equal(a, b, msg=""):
    for (path, la), (_, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=f"{msg} leaf {path} diverged"
        )


def _assert_reconciled(stats, policy="drop_oldest"):
    """The ISSUE-3 acceptance identity, exact."""
    assert stats.reconciled, stats.summary()
    if policy != "coalesce":
        assert stats.queries_coalesced == 0
        assert stats.queries_issued == (
            stats.labels_applied + stats.queries_dropped + stats.queries_lost
        ), stats.summary()


# ---------------------------------------------------------------------------
# Tentpole: multiplexer == N solo runs, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantum", [1, 3])
def test_two_tenants_bit_for_bit_vs_two_solo_runs(quantum):
    """Two tenants with *different* configs multiplexed over one process
    must end in exactly the states (and outputs) two independent
    ``stream.run`` calls produce, zero-latency teacher — at any scheduler
    quantum (the time slice changes interleaving, never results)."""
    cfgs = [_cfg(n_hidden=16, min_trained=4), _cfg(n_hidden=32, min_trained=8)]
    datas = [_stream_data(cfgs[0], 40, 3, seed=1), _stream_data(cfgs[1], 25, 2, seed=2)]

    solo = []
    for cfg, (xs, ys) in zip(cfgs, datas):
        teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=0)
        solo.append(
            stream.run(
                engine.init_fleet(cfg, xs.shape[1]), (x for x in xs), cfg,
                teacher, mode="train_phase",
            )
        )

    tenants = [
        multiplex.Tenant(
            name=f"tenant{i}",
            state=engine.init_fleet(cfg, xs.shape[1]),
            ticks=(x for x in xs),
            cfg=cfg,
            teacher=stream.LatencyTeacher(stream.array_labels(ys), latency=0),
            mode="train_phase",
        )
        for i, (cfg, (xs, ys)) in enumerate(zip(cfgs, datas))
    ]
    results, agg = multiplex.run(tenants, quantum=quantum)

    assert agg.n_tenants == 2
    assert agg.stream_steps == sum(s[2].stream_steps for s in solo)
    for i, (st, outs, stats) in enumerate(solo):
        r = results[f"tenant{i}"]
        _assert_state_equal(st, r.state, msg=f"tenant{i}")
        for name in outs._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(outs, name)),
                np.asarray(getattr(r.outputs, name)),
                err_msg=f"tenant{i} output {name!r} diverged",
            )
        assert r.stats.queries_issued == stats.queries_issued > 0
        assert r.stats.labels_applied == stats.labels_applied
        _assert_reconciled(r.stats)


def test_tenants_with_equal_configs_share_compiled_runners():
    """The whole point of multiplexing fleets over one process: tenants
    whose (cfg, mode, donate) hash equal reuse the same compiled runner
    (LRU hit), never a second executable (miss)."""
    cfg = _cfg(n_hidden=16, min_trained=4)
    xs, ys = _stream_data(cfg, 6, 2, seed=3)

    def tenant(name):
        return multiplex.Tenant(
            name=name,
            state=engine.init_fleet(cfg, xs.shape[1]),
            ticks=(x for x in xs),
            cfg=cfg,
            teacher=stream.LatencyTeacher(stream.array_labels(ys), latency=0),
            mode="train_phase",
        )

    multiplex.run([tenant("warm")])  # compile once
    before = multiplex.cache_stats()
    multiplex.run([tenant("a"), tenant("b"), tenant("c")])
    after = multiplex.cache_stats()
    for runner in ("plan_runner", "learn_runner", "learn_plan_runner"):
        assert after[runner]["misses"] == before[runner]["misses"], runner
    assert after["plan_runner"]["hits"] >= before["plan_runner"]["hits"] + 3


# ---------------------------------------------------------------------------
# Query-accounting reconciliation (satellite 2) — property over fault modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "latency,jitter,loss,partial,outage,capacity,policy",
    [
        (0, 0, 0.0, 0.0, None, 64, "drop_oldest"),  # clean zero-latency
        (2, 5, 0.3, 0.0, None, 4, "drop_oldest"),  # loss + jitter + overflow
        (3, 2, 0.2, 0.3, None, 2, "drop_oldest"),  # + partial answers
        (5, 0, 0.0, 0.5, None, 2, "drop_newest"),  # refuse-new + partial
        (3, 4, 0.2, 0.2, None, 2, "block"),  # deferred asks + loss
        (4, 3, 0.1, 0.25, None, 3, "coalesce"),  # merged asks + partial
        (1, 0, 0.0, 0.0, 5, 8, "drop_oldest"),  # permanent outage
    ],
)
def test_query_accounting_identity(latency, jitter, loss, partial, outage,
                                   capacity, policy):
    """queries_issued == labels_applied + queries_dropped + queries_lost
    (+ queries_coalesced under the coalesce policy) — exactly, under every
    combination of teacher loss, jitter, partial answers, ring overflow,
    and backpressure policy."""
    cfg = _cfg(min_trained=1_000_000)  # cold heads: every tick queries
    t_len, s_len = 40, 4
    xs, ys = _stream_data(cfg, t_len, s_len, seed=7)
    teacher = stream.LatencyTeacher(
        stream.array_labels(ys), latency=latency, jitter=jitter, loss_prob=loss,
        partial_prob=partial, outage_after=outage, seed=11,
    )
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase", capacity=capacity, backpressure=policy,
    )
    assert stats.queries_issued == t_len * s_len
    _assert_reconciled(stats, policy)
    # labels actually applied == trained marks == per-stream counts.
    assert stats.labels_applied == int(np.asarray(st.elm.count).sum())
    assert stats.labels_applied == int(outs.trained.sum())
    if partial and not outage:
        assert stats.queries_lost > 0  # the partial-answer residue is metered


def test_partial_answer_residue_is_metered():
    """A ticket answered for only some of its asked streams applies n labels
    and meters the residue as queries_lost — previously unaccounted."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 20, 6
    xs, ys = _stream_data(cfg, t_len, s_len, seed=8)
    teacher = stream.LatencyTeacher(
        stream.array_labels(ys), latency=1, partial_prob=0.4, seed=9
    )
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase",
    )
    assert 0 < stats.labels_applied < stats.queries_issued
    assert stats.queries_lost > 0
    assert stats.queries_dropped == 0
    _assert_reconciled(stats)


# ---------------------------------------------------------------------------
# Backpressure policies (tentpole)
# ---------------------------------------------------------------------------


def test_drop_newest_keeps_oldest_tickets():
    """drop_newest refuses the new ask when the ring is full: the *first*
    ``capacity`` tickets survive (mirror image of drop_oldest, which keeps
    the last ones — locked by test_stream.py)."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 6, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=10)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=50)
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase", capacity=2, backpressure="drop_newest",
    )
    assert stats.tickets_issued == 2  # refused asks never hit the wire
    assert stats.tickets_dropped == t_len - 2
    assert stats.queries_dropped == (t_len - 2) * s_len
    assert stats.labels_applied == 2 * s_len
    assert stats.replies_orphaned == 0  # nothing evicted -> nothing orphaned
    np.testing.assert_array_equal(outs.trained[:2], np.ones((2, s_len), bool))
    assert not outs.trained[2:].any()
    _assert_reconciled(stats, "drop_newest")


def test_block_defers_asks_and_loses_nothing():
    """block parks the ask host-side until a ring slot frees: with enough
    drain every decided query is eventually asked and answered — zero drops
    despite a ring much smaller than the teacher's latency window."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 12, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=11)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=3)
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase", capacity=2, backpressure="block",
    )
    assert stats.asks_deferred > 0
    assert stats.tickets_issued == t_len  # every ask eventually submitted
    assert stats.queries_dropped == 0
    assert stats.labels_applied == stats.queries_issued == t_len * s_len
    assert outs.trained.all()
    _assert_reconciled(stats, "block")


def test_coalesce_merges_requeries_into_in_flight_ticket():
    """coalesce: a stream re-querying while its query is in flight rides the
    in-flight ticket instead of duplicating teacher traffic — with a
    teacher slower than the whole stream, one ticket serves every tick."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 6, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=12)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=50)
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase", capacity=4, backpressure="coalesce",
    )
    assert stats.tickets_issued == 1  # tick 0; ticks 1..5 fully covered
    assert stats.tickets_coalesced == t_len - 1
    assert stats.queries_coalesced == (t_len - 1) * s_len
    assert stats.labels_applied == s_len  # the one in-flight ticket answers
    assert stats.queries_dropped == 0 and stats.replies_orphaned == 0
    np.testing.assert_array_equal(outs.trained[0], np.ones(s_len, bool))
    assert not outs.trained[1:].any()
    _assert_reconciled(stats, "coalesce")


def test_coalesce_does_not_credit_a_ticket_it_evicts():
    """Regression: when the residual ask of a coalesce submit evicts the
    oldest in-flight ticket (full ring), streams covered only by that
    ticket must ride the new ask — not be credited as coalesced against a
    covering ticket that just became an orphan (they would silently never
    get a label)."""
    cfg = _cfg(min_trained=1_000_000)
    s_len = 2
    xs, ys = _stream_data(cfg, 3, s_len, seed=22)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=50)
    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg, teacher,
        mode="train_phase", capacity=1, backpressure="coalesce",
    )
    # Tick 0: only stream 0 queries -> ticket T0 covers {0}.  Tick 1: both
    # streams query; stream 1 forces a residual ask on the full ring, which
    # evicts T0 — so stream 0's re-query must NOT coalesce into T0.
    sess.stats.queries_issued += 1
    sess._submit(xs[0], np.array([True, False]), None, 0)
    sess.stats.queries_issued += 2
    sess._submit(xs[1], np.array([True, True]), None, 1)
    assert sess.stats.queries_coalesced == 0  # nothing falsely settled
    assert sess.stats.tickets_dropped == 1 and sess.stats.queries_dropped == 1
    (ent,) = sess.ring.entries()  # the surviving ticket carries BOTH streams
    np.testing.assert_array_equal(ent.queried, [True, True])


def test_backpressure_policy_is_validated():
    cfg = _cfg()
    with pytest.raises(ValueError, match="backpressure"):
        stream.StreamSession(
            engine.init_fleet(cfg, 2), cfg,
            stream.LatencyTeacher(lambda t, f: np.zeros(2, np.int32)),
            backpressure="yolo",
        )


# ---------------------------------------------------------------------------
# Drain polls while EITHER ring or in-flight is non-empty (satellite 3)
# ---------------------------------------------------------------------------


class _ScriptedTeacher:
    """Teacher answering ticket i at an explicit due tick (full mask)."""

    def __init__(self, labels_row, dues):
        self.labels_row = np.asarray(labels_row, np.int32)
        self.dues = dues  # ticket -> due tick
        self._pending = {}
        self._next = 0

    def ask(self, feats, mask, tick):
        ticket = self._next
        self._next += 1
        self._pending[ticket] = (self.dues[ticket], np.asarray(mask, bool))
        return ticket

    def poll(self, tick):
        out = []
        for ticket in sorted(self._pending):
            due, mask = self._pending[ticket]
            if due <= tick:
                out.append(stream.TeacherReply(ticket, self.labels_row, mask))
        for r in out:
            del self._pending[r.ticket]
        return out

    def in_flight(self):
        return len(self._pending)


def test_drain_polls_after_ring_empties_so_orphans_are_metered():
    """Regression: the youngest (ring-resident) ticket answers early and the
    evicted tickets answer late — the ring empties mid-drain while replies
    are still in flight.  Draining only while *both* ring and in-flight
    were non-empty silently discarded those replies with replies_orphaned
    staying 0; the fixed loop polls while either holds."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 3, 2
    xs, ys = _stream_data(cfg, t_len, s_len, seed=13)
    # Tickets 0,1 get evicted (capacity 1); ticket 2 survives.  Ticket 2
    # answers first (t=3) — ring empties — tickets 0,1 answer at t=6.
    teacher = _ScriptedTeacher(ys[0], dues={0: 6, 1: 6, 2: 3})
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
        mode="train_phase", capacity=1,
    )
    assert teacher.in_flight() == 0  # the late replies WERE polled
    assert stats.replies_orphaned == 2
    assert stats.labels_applied == s_len
    assert stats.tickets_dropped == 2 and stats.queries_dropped == 2 * s_len
    _assert_reconciled(stats)


# ---------------------------------------------------------------------------
# Serve-path stale-reply semantics (satellite 1)
# ---------------------------------------------------------------------------


def test_delayed_reply_judged_at_plan_time_context_matches_run_fleet():
    """A query's answer that lands after the weights (and the ladder) moved
    must be judged by the *plan-time* pred/confidence/theta — the same
    transition run_fleet makes for that query — not recomputed from the
    current state.  Locks the gate/apply_labels stale-reply fix."""
    cfg = _cfg(min_trained=1_000_000)  # everyone queries, drift irrelevant
    s_len = 2
    x0 = jnp.tanh(jax.random.normal(jax.random.PRNGKey(20), (s_len, cfg.elm.n_in)))

    # Arm the ladder at level 3 so step-ups stay observable throughout.
    st0 = engine.init_fleet(cfg, s_len)
    st0 = st0._replace(prune=st0.prune._replace(level=jnp.full((s_len,), 3, jnp.int32)))

    st1, ctx0 = engine.gate(st0, x0, cfg)
    assert bool(ctx0.queried.all())
    # The teacher will answer class (pred+1) — a plan-time DISAGREEMENT.
    labels0 = jnp.asarray((np.asarray(ctx0.pred) + 1) % cfg.elm.n_out, jnp.int32)

    # run_fleet anchor: same state, same tick, zero-latency labels — the
    # disagreement on a low-confidence query steps theta UP (level - 1).
    ref_st, _ = engine.run_fleet(
        st0, x0[None], labels0[None], cfg, mode="train_phase"
    )
    ref_delta = np.asarray(ref_st.prune.level) - np.asarray(st0.prune.level)
    np.testing.assert_array_equal(ref_delta, [-1, -1])

    # While labels0 is in flight, later replies train the SAME streams until
    # the local prediction flips to agree with labels0 (out-of-order answers
    # landing first — the jitter case).
    st = st1
    for _ in range(8):
        st_probe, ctx_i = engine.gate(st, x0, cfg)
        st = engine.apply_labels(
            st_probe, ctx_i, labels0, jnp.ones((s_len,), bool), cfg
        )
        _, ctx_now = engine.gate(st, x0, cfg)
        if bool(jnp.all(ctx_now.pred == labels0)):
            break
    assert bool(jnp.all(ctx_now.pred == labels0)), "intervening training failed"
    base = np.asarray(st.prune.level)
    assert (base >= 1).all(), "need headroom to observe the step-up"

    mask = jnp.ones((s_len,), bool)
    # Fixed path: plan-time judgment — the delayed disagreement steps the
    # ladder up (level - 1), exactly the run_fleet transition above.
    st_new = engine.apply_labels(st, ctx0, labels0, mask, cfg)
    np.testing.assert_array_equal(
        np.asarray(st_new.prune.level) - base, ref_delta
    )
    # The recompute path (judge against the *current* weights, where the
    # prediction now agrees and the stale judgment would miss the step-up)
    # is gone for good: raw features are rejected outright.
    with pytest.raises(TypeError, match="plan-time"):
        engine.apply_labels(st, ctx0.feats, labels0, mask, cfg)
    # And the fixed path trains on the plan-time activations of x0.
    assert float(jnp.max(jnp.abs(st_new.elm.beta - st.elm.beta))) > 0


def test_serve_mode_plan_learn_is_gate_apply_labels_bit_for_bit():
    """``plan(mode='serve')``/``learn`` must be the same state machine as
    ``gate``/``apply_labels`` — the multiplexed serve driver keeps the live
    drift detector (pruning condition 2) the single-tenant gate path has."""
    cfg = _cfg(min_trained=2)
    s_len = 3
    st_gate = st_plan = engine.init_fleet(cfg, s_len)
    key = jax.random.PRNGKey(21)
    for t in range(12):
        key, kx = jax.random.split(key)
        x = jnp.tanh(jax.random.normal(kx, (s_len, cfg.elm.n_in))) * (1 + t % 3)
        labels = jnp.asarray([t % cfg.elm.n_out] * s_len, jnp.int32)

        st_gate2, gout = engine.gate(st_gate, x, cfg)
        st_gate = engine.apply_labels(st_gate2, gout, labels, gout.queried, cfg)

        st_plan2, pout = engine.plan(st_plan, x, cfg, mode="serve")
        st_plan = engine.learn(
            st_plan2, pout.h, labels, pout.pred, pout.confidence, pout.queried,
            pout.controller_on, cfg, theta=pout.theta,
        )
        np.testing.assert_array_equal(
            np.asarray(gout.queried), np.asarray(pout.queried), err_msg=f"tick {t}"
        )
        _assert_state_equal(st_gate, st_plan, msg=f"tick {t}")
    assert int(np.asarray(st_plan.elm.count).sum()) > 0  # the loop trained


# ---------------------------------------------------------------------------
# RpcTeacher loopback (tentpole) — real socket, timeout -> loss
# ---------------------------------------------------------------------------


def test_rpc_teacher_loopback_roundtrip_through_stream_run():
    """The full runtime against a real TCP label server: every query is
    answered with the server's deterministic labels and the accounting
    reconciles — LatencyTeacher is no longer the only latency model."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 6, 3
    xs, _ = _stream_data(cfg, t_len, s_len, seed=14)
    with rpc.loopback_server(n_out=cfg.elm.n_out) as (host, port):
        with rpc.RpcTeacher(host, port, timeout_s=30.0) as teacher:
            st, outs, stats = stream.run(
                engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
                mode="train_phase",
            )
    assert stats.labels_applied == stats.queries_issued == t_len * s_len
    assert outs.trained.all()
    assert int(np.asarray(st.elm.count).sum()) == t_len * s_len
    _assert_reconciled(stats)


def test_rpc_teacher_timeout_maps_to_loss():
    """A server slower than the client deadline: every ticket expires out of
    in_flight, the ring drains as queries_lost, and the straggler replies
    are never applied."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 3, 2
    xs, _ = _stream_data(cfg, t_len, s_len, seed=15)
    with rpc.loopback_server(n_out=cfg.elm.n_out, delay_s=1.0) as (host, port):
        with rpc.RpcTeacher(host, port, timeout_s=0.05) as teacher:
            st, outs, stats = stream.run(
                engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
                mode="train_phase",
            )
            assert teacher.in_flight() == 0
    assert stats.labels_applied == 0
    assert not outs.trained.any()
    assert stats.queries_lost == stats.queries_issued == t_len * s_len
    assert int(np.asarray(st.elm.count).sum()) == 0  # stragglers never train
    _assert_reconciled(stats)


# ---------------------------------------------------------------------------
# Multiplexed faults: per-tenant isolation of accounting and state
# ---------------------------------------------------------------------------


def test_multiplex_mixed_policies_and_faults_reconcile_per_tenant():
    """Tenants with different backpressure policies and fault models run
    side by side; each tenant's accounting reconciles independently."""
    cfg_a = _cfg(n_hidden=16, min_trained=1_000_000)
    cfg_b = _cfg(n_hidden=32, min_trained=1_000_000)
    xs_a, ys_a = _stream_data(cfg_a, 30, 3, seed=16)
    xs_b, ys_b = _stream_data(cfg_b, 20, 2, seed=17)
    tenants = [
        multiplex.Tenant(
            name="lossy",
            state=engine.init_fleet(cfg_a, 3),
            ticks=(x for x in xs_a),
            cfg=cfg_a,
            teacher=stream.LatencyTeacher(
                stream.array_labels(ys_a), latency=2, jitter=3, loss_prob=0.3,
                partial_prob=0.2, seed=18,
            ),
            mode="train_phase",
            capacity=3,
            backpressure="drop_oldest",
        ),
        multiplex.Tenant(
            name="coalescing",
            state=engine.init_fleet(cfg_b, 2),
            ticks=(x for x in xs_b),
            cfg=cfg_b,
            teacher=stream.LatencyTeacher(
                stream.array_labels(ys_b), latency=6, seed=19
            ),
            mode="train_phase",
            capacity=2,
            backpressure="coalesce",
        ),
    ]
    results, agg = multiplex.run(tenants)
    assert results["lossy"].stats.queries_issued == 30 * 3
    assert results["coalescing"].stats.queries_coalesced > 0
    for name, policy in (("lossy", "drop_oldest"), ("coalescing", "coalesce")):
        _assert_reconciled(results[name].stats, policy)
    assert agg.stream_steps == 30 * 3 + 20 * 2


def test_multiplex_rejects_duplicate_names_and_empty():
    cfg = _cfg()
    with pytest.raises(ValueError, match="at least one"):
        multiplex.run([])
    t = multiplex.Tenant(
        name="dup", state=engine.init_fleet(cfg, 2), ticks=iter(()), cfg=cfg,
        teacher=stream.LatencyTeacher(lambda t_, f: np.zeros(2, np.int32)),
    )
    with pytest.raises(ValueError, match="unique"):
        multiplex.run([t, t])


# ---------------------------------------------------------------------------
# Deficit round robin (ISSUE 4 satellite): size-fair scheduling
# ---------------------------------------------------------------------------


def test_drr_is_bit_for_bit_and_does_not_let_big_tenants_starve_small():
    """DRR charges a tick its stream count: a big tenant advances ~1 tick
    per round while a small one keeps its full quantum — many more scheduler
    rounds than rr's fixed quantum-tick slices (the observable fairness
    property) — and per-tenant results stay bit-for-bit identical to rr
    (scheduling order can never change results)."""
    cfg_small, cfg_big = _cfg(n_hidden=16, min_trained=4), _cfg(n_hidden=16, min_trained=4)
    t_len = 24
    xs_s, ys_s = _stream_data(cfg_small, t_len, 2, seed=30)
    xs_b, ys_b = _stream_data(cfg_big, t_len, 16, seed=31)

    def tenants():
        return [
            multiplex.Tenant(
                name="small", state=engine.init_fleet(cfg_small, 2),
                ticks=(x for x in xs_s), cfg=cfg_small,
                teacher=stream.LatencyTeacher(stream.array_labels(ys_s), latency=0),
                mode="train_phase",
            ),
            multiplex.Tenant(
                name="big", state=engine.init_fleet(cfg_big, 16),
                ticks=(x for x in xs_b), cfg=cfg_big,
                teacher=stream.LatencyTeacher(stream.array_labels(ys_b), latency=0),
                mode="train_phase",
            ),
        ]

    res_rr, agg_rr = multiplex.run(tenants(), sched="rr")

    # Drive drr round by round and watch the big tenant's per-round tick
    # budget while the small tenant is still live.
    mux = multiplex.Multiplexer(tenants(), sched="drr")
    big_while_small_live = []
    while mux.round():
        if mux._slot("small").result is None:
            big_while_small_live.append(mux._slot("big").last_ticks)
    res_drr, agg_drr = mux.results()

    for name in ("small", "big"):
        _assert_state_equal(res_rr[name].state, res_drr[name].state, msg=name)
        for field in res_rr[name].outputs._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res_rr[name].outputs, field)),
                np.asarray(getattr(res_drr[name].outputs, field)),
                err_msg=f"{name} output {field!r} diverged under drr",
            )
        _assert_reconciled(res_drr[name].stats)
    assert agg_drr.stream_steps == agg_rr.stream_steps
    # rr would give the big tenant its full 8-tick slice every round,
    # blocking the small tenant for 8 heavy (8x-S) ticks at a time.  drr's
    # per-round credit is quantum * S_small = one big tick (+carry): while
    # the small tenant is live, the big one never hogs the device — and
    # once the small tenant finishes, drr is work-conserving (the credit
    # recomputes over live tenants, so the big one speeds back up).
    assert big_while_small_live, "small tenant never observed live"
    assert max(big_while_small_live) <= 2, big_while_small_live
    assert agg_drr.rounds >= agg_rr.rounds


def test_scheduler_is_validated():
    cfg = _cfg()
    t = multiplex.Tenant(
        name="t", state=engine.init_fleet(cfg, 2), ticks=iter(()), cfg=cfg,
        teacher=stream.LatencyTeacher(lambda t_, f: np.zeros(2, np.int32)),
    )
    with pytest.raises(ValueError, match="scheduler"):
        multiplex.run([t], sched="fifo")


# ---------------------------------------------------------------------------
# RPC teacher auth (ISSUE 4 satellite): HMAC challenge-response on connect
# ---------------------------------------------------------------------------


def test_rpc_auth_roundtrip_and_rejection():
    """A client with the right secret round-trips labels; the wrong secret
    (or none) gets the connection closed before any label — the asks map to
    timeout->loss and the fleet never trains on an unauthenticated server."""
    cfg = _cfg(min_trained=1_000_000)
    t_len, s_len = 4, 2
    xs, _ = _stream_data(cfg, t_len, s_len, seed=32)
    server = rpc.LabelServer(n_out=cfg.elm.n_out, secret="paper-s3cret").start()
    try:
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=30.0,
                            secret="paper-s3cret") as teacher:
            st, outs, stats = stream.run(
                engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher,
                mode="train_phase",
            )
        assert stats.labels_applied == stats.queries_issued == t_len * s_len
        assert outs.trained.all()
        _assert_reconciled(stats)

        # Wrong secret: the server rejects the digest and closes without
        # proving itself, so the client fails fast at connect.
        with pytest.raises(ConnectionError):
            rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=0.3,
                           secret="wrong")
        # No secret at all: the client skips the handshake, the server
        # closes the unauthenticated connection, and every ask maps to
        # timeout->loss — the fleet never trains.
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=0.3,
                            secret=None) as teacher:
            st, outs, stats = stream.run(
                engine.init_fleet(cfg, s_len), (x for x in xs), cfg,
                teacher, mode="train_phase",
            )
        assert stats.labels_applied == 0
        assert stats.queries_lost == stats.queries_issued == t_len * s_len
        assert int(np.asarray(st.elm.count).sum()) == 0
        _assert_reconciled(stats)
        assert server.auth_failures >= 2
    finally:
        server.close()


def test_rpc_client_refuses_unauthenticated_server():
    """A client configured with a secret must refuse a server that opens
    with no challenge (it is not speaking the authenticated protocol)."""
    server = rpc.LabelServer(n_out=4).start()  # no secret on the server
    try:
        with pytest.raises((ConnectionError, OSError)):
            rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=1.0,
                           connect_timeout_s=0.5, secret="expects-auth")
    finally:
        server.close()


def test_rpc_client_refuses_imposter_server():
    """Auth is mutual: a rogue endpoint that emits a challenge (to fish for
    the client's digest) but cannot answer the client's nonce must be
    refused before any of its labels can train the fleet."""
    import json as json_mod
    import socket
    import threading

    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.bind(("127.0.0.1", 0))
    sock.listen(1)
    port = sock.getsockname()[1]

    def imposter():
        conn, _ = sock.accept()
        with conn, conn.makefile("rwb") as f:
            f.write(b'{"challenge": "00"}\n')
            f.flush()
            f.readline()  # harvest the client's digest...
            # ...but answer the client's nonce with garbage (no secret).
            f.write((json_mod.dumps({"auth_ok": "deadbeef"}) + "\n").encode())
            f.flush()

    t = threading.Thread(target=imposter, daemon=True)
    t.start()
    try:
        with pytest.raises(ConnectionError, match="prove knowledge"):
            rpc.RpcTeacher("127.0.0.1", port, timeout_s=1.0,
                           connect_timeout_s=2.0, secret="the-real-secret")
    finally:
        sock.close()
