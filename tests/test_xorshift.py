"""Xorshift16 PRNG weight tests (paper §2.3, ODLHash)."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only the @given tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import xorshift


def test_stream_matches_bit_level_reference():
    """Sequential generator vs an independent bit-level implementation."""

    def ref_step(x):
        x &= 0xFFFF
        x ^= (x << 7) & 0xFFFF
        x ^= x >> 9
        x ^= (x << 8) & 0xFFFF
        return x & 0xFFFF

    s = 0x1234
    expect = []
    for _ in range(64):
        s = ref_step(s)
        expect.append(s)
    got = xorshift.xorshift16_stream(0x1234, 64)
    np.testing.assert_array_equal(got, np.asarray(expect, np.uint16))


def test_stream_has_long_period():
    """(7,9,8) is a full-period triple: no repeat within 65535 steps."""
    seq = xorshift.xorshift16_stream(1, 65535)
    assert len(np.unique(seq)) == 65535


def test_step_jax_matches_numpy_stream():
    seq = xorshift.xorshift16_stream(42, 100)
    x = jnp.asarray(np.uint16(42))
    got = []
    for _ in range(100):
        x = xorshift.xorshift16_step(x)
        got.append(int(x))
    np.testing.assert_array_equal(np.asarray(got, np.uint16), seq)


def test_u16_to_unit_range():
    xs = jnp.asarray(np.arange(0, 65536, 17, dtype=np.uint16))
    u = xorshift.u16_to_unit(xs)
    assert float(u.min()) >= -1.0 and float(u.max()) < 1.0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16 - 1),
    ro=st.integers(0, 500),
    co=st.integers(0, 500),
)
def test_alpha_hash_tiles_are_consistent(seed, ro, co):
    """Counter-based generation: any tile equals the same slice of the full
    matrix — the property the Pallas kernel relies on (DESIGN.md §2)."""
    full = xorshift.alpha_hash(seed, 64, 640)
    tile = np.asarray(
        xorshift.alpha_hash(seed, 8, 640, row_offset=ro % 56, col_offset=co)
    )
    r, c = ro % 56, co
    np.testing.assert_array_equal(tile[:, : 640 - c], np.asarray(full)[r : r + 8, c:])


def test_alpha_hash_distribution_is_roughly_uniform():
    a = np.asarray(xorshift.alpha_hash(7, 100, 128)).ravel()
    assert abs(a.mean()) < 0.02
    assert abs(a.std() - 1 / np.sqrt(3)) < 0.02  # U[-1,1) std = 1/sqrt(3)
    # No stuck values: almost every entry distinct.
    assert len(np.unique(a)) > 0.9 * a.size


def test_alpha_hash_avoids_zero_fixed_point():
    """Counter values hashing from 0 must not produce the all-zero orbit."""
    a = xorshift.alpha_hash(0, 4, 4)  # seed 0 ^ ctr 1.. includes small values
    assert not np.allclose(np.asarray(a), xorshift.u16_to_unit(jnp.uint16(0)))


def test_alpha_dense_reproducible():
    a1 = xorshift.alpha_dense(5, 10, 12)
    a2 = xorshift.alpha_dense(5, 10, 12)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
