"""Multi-device tests, run in SUBPROCESSES with XLA_FLAGS forcing 8 host
devices (jax locks device count at first init, and the main test process
must keep seeing 1 device — see dry-run rule 0)."""

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(
    os.environ,
    XLA_FLAGS="--xla_force_host_platform_device_count=8",
    PYTHONPATH="src",
    JAX_PLATFORMS="cpu",
)


def _run(code: str, timeout=600):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=ENV,
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """pjit train_step on a 2x4 mesh == single-device train_step."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.base import TrainConfig
        from repro.distributed import sharding
        from repro.launch.mesh import make_dev_mesh
        from repro.models import model as M

        cfg = configs.get_config('qwen3-4b', 'smoke')
        tcfg = TrainConfig(remat=False)
        key = jax.random.PRNGKey(0)
        state = M.init_train_state(cfg, key)
        batch = {
            'tokens': jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
            'labels': jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
            'odl_labels': jax.random.randint(key, (8,), 0, cfg.odl.n_out),
        }
        # Single device reference.
        st1, m1 = jax.jit(lambda s, b: M.train_step(s, b, cfg, tcfg))(state, batch)

        mesh = make_dev_mesh(2, 4)
        with sharding.activate(mesh):
            st2, m2 = jax.jit(lambda s, b: M.train_step(s, b, cfg, tcfg))(state, batch)
        np.testing.assert_allclose(float(m1['loss']), float(m2['loss']), rtol=2e-2)
        a = np.asarray(st1.params['layers']['mlp']['wd'], np.float32)
        b = np.asarray(st2.params['layers']['mlp']['wd'], np.float32)
        np.testing.assert_allclose(a, b, atol=3e-2, rtol=3e-2)
        print('OK')
        """
    )


def test_moe_expert_parallel_runs_sharded():
    """MoE block under EP sharding compiles+runs and matches unsharded."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.distributed import sharding
        from repro.launch.mesh import make_dev_mesh
        from repro.models import model as M
        from repro.models.transformer import lm_hidden

        cfg = configs.get_config('deepseek-moe-16b', 'smoke')
        params = M.layers.init_params(M.build_schema(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
        h1, _ = jax.jit(lambda p, t: lm_hidden(p, t, cfg, remat=False))(params, toks)
        mesh = make_dev_mesh(2, 4)
        with sharding.activate(mesh):
            h2, _ = jax.jit(lambda p, t: lm_hidden(p, t, cfg, remat=False))(params, toks)
        # Top-k routing is a discrete boundary: reduction-order noise can flip
        # near-tie expert choices for a few tokens under sharding, so compare
        # robustly (fraction-close) rather than elementwise-exact.
        d = np.abs(np.asarray(h1, np.float32) - np.asarray(h2, np.float32))
        assert (d < 0.1).mean() > 0.90, f'too many mismatches: {(d >= 0.1).mean():.3f}'
        assert np.median(d) < 0.02  # bulk agrees to bf16 noise
        print('OK')
        """
    )


def test_pipeline_matches_sequential():
    """GPipe stage scan == sequential stage application."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import pipeline

        from repro.launch.mesh import _make_mesh
        mesh = _make_mesh((8,), ('stage',))
        n_stages, m, b, d = 8, 4, 2, 16
        key = jax.random.PRNGKey(0)
        h = jax.random.normal(key, (m, b, d))
        w = jax.random.normal(jax.random.PRNGKey(1), (n_stages, d, d)) / np.sqrt(d)
        params = {'w': w}

        def stage_fn(x, p):
            return jnp.tanh(x @ p['w'])

        got = pipeline.pipeline_forward(h, params, stage_fn, mesh)
        want = pipeline.sequential_reference(h, params, stage_fn, n_stages)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
        assert pipeline.bubble_fraction(8, 4) == 7/11
        print('OK')
        """
    )


def test_elastic_reshard_checkpoint():
    """Save params on a 4x2 mesh, restore onto 2x2 (elastic rescale)."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro import configs
        from repro.distributed import sharding
        from repro.launch.mesh import make_dev_mesh
        from repro.models import model as M, layers
        from repro.runtime import checkpoint
        from repro.runtime.checkpoint import CheckpointManager

        cfg = configs.get_config('qwen3-4b', 'smoke')
        schema = M.build_schema(cfg)
        mesh_a = make_dev_mesh(4, 2)
        with sharding.activate(mesh_a):
            params = layers.init_params(schema, jax.random.PRNGKey(0))
            params = checkpoint.reshard_tree(params, mesh_a, layers.param_specs(schema))

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=1)
            mgr.save(1, params)
            mesh_b = make_dev_mesh(2, 2)  # "half the fleet died"
            step, restored = checkpoint.rescale(mgr, schema, mesh_b)
            assert step == 1
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b))
            # Restored arrays really live on the new mesh.
            leaf = jax.tree.leaves(restored)[0]
            assert leaf.sharding.mesh.shape == {'data': 2, 'model': 2}
        print('OK')
        """
    )


def test_odl_fleet_shards_over_data_axis():
    """The paper's fleet of (beta, P) heads shards across the data axis."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import oselm
        from repro.distributed import sharding
        from repro.launch.mesh import make_dev_mesh

        cfg = oselm.OSELMConfig(n_in=32, n_hidden=16, n_out=4, variant='hash')
        mesh = make_dev_mesh(4, 2)
        fleet = oselm.init_fleet(cfg, 8)
        with sharding.activate(mesh):
            fleet = jax.tree.map(
                lambda a: jax.device_put(a, NamedSharding(mesh, P('data'))), fleet)
            x = jax.device_put(
                jax.random.normal(jax.random.PRNGKey(0), (8, 32)),
                NamedSharding(mesh, P('data')))
            y = jax.nn.one_hot(jnp.arange(8) % 4, 4)
            f2 = jax.jit(lambda f, xx, yy: oselm.fleet_update(f, xx, yy, cfg))(fleet, x, y)
        assert f2.P.shape == (8, 16, 16)
        assert 'data' in str(jax.tree.leaves(f2)[0].sharding.spec)
        print('OK')
        """
    )
