"""Integration tests: the paper's system loop end-to-end (Algorithm 1, §3)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drift as drift_mod
from repro.core import odl_head, oselm, pruning
from repro.data import har


@pytest.fixture(scope="module")
def har_data():
    return har.generate(seed=0)


def _boot_core(har_data, run_seed=0, theta="auto", N=128):
    elm_cfg = oselm.OSELMConfig(
        n_in=561, n_hidden=N, n_out=6, variant="hash", seed=run_seed + 77, ridge=1e-2
    )
    if theta == "auto":
        pcfg = pruning.PruneConfig(min_trained=max(N, 288))
    else:
        pcfg = pruning.PruneConfig(ladder=(theta,), min_trained=max(N, 288))
    cfg = odl_head.ODLCoreConfig(elm=elm_cfg, prune=pcfg)
    st0 = oselm.init_state_batch(
        elm_cfg, jnp.asarray(har_data.train_x), jax.nn.one_hot(har_data.train_y, 6)
    )
    return cfg, odl_head.init_state(cfg)._replace(elm=st0)


def test_odl_recovers_accuracy_after_drift(har_data):
    """Paper Table 3's headline: NoODL drops ~10 pts after drift; ODL recovers."""
    cfg, core = _boot_core(har_data, theta=1.0)
    ox, oy, tx, ty = har.odl_split(har_data, 0.6, 0)

    acc_before_drift = float(
        odl_head.accuracy(core, jnp.asarray(har_data.test0_x), jnp.asarray(har_data.test0_y), cfg)
    )
    acc_noodl = float(odl_head.accuracy(core, jnp.asarray(tx), jnp.asarray(ty), cfg))

    core, _ = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
        core, jnp.asarray(ox), jnp.asarray(oy)
    )
    acc_odl = float(odl_head.accuracy(core, jnp.asarray(tx), jnp.asarray(ty), cfg))

    assert acc_before_drift > 0.90  # paper: 93.1 +- 0.8
    assert acc_noodl < acc_before_drift - 0.05  # the drift hurts (paper: -10.2)
    assert acc_odl > acc_noodl + 0.025  # ODL recovers (paper: +7.8)


def test_auto_pruning_cuts_communication_with_small_accuracy_loss(har_data):
    """Paper Fig. 3 'Auto': large comm reduction, <= ~1% accuracy delta."""
    ox, oy, tx, ty = har.odl_split(har_data, 0.6, 0)

    cfg_full, core_full = _boot_core(har_data, theta=1.0)
    core_full, _ = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg_full))(
        core_full, jnp.asarray(ox), jnp.asarray(oy)
    )
    acc_full = float(odl_head.accuracy(core_full, jnp.asarray(tx), jnp.asarray(ty), cfg_full))
    comm_full = float(pruning.comm_volume_fraction(core_full.prune))

    cfg_auto, core_auto = _boot_core(har_data, theta="auto")
    core_auto, _ = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg_auto))(
        core_auto, jnp.asarray(ox), jnp.asarray(oy)
    )
    acc_auto = float(odl_head.accuracy(core_auto, jnp.asarray(tx), jnp.asarray(ty), cfg_auto))
    comm_auto = float(pruning.comm_volume_fraction(core_auto.prune))

    assert comm_full == 1.0
    assert comm_auto < 0.70  # paper: 0.443; surrogate lands ~0.5
    assert acc_auto > acc_full - 0.02  # paper: -0.9% worst case


def test_comm_volume_monotone_in_theta(har_data):
    """Fig. 3's line graph: lower theta => less communication."""
    ox, oy, _, _ = har.odl_split(har_data, 0.6, 0)
    comms = []
    for theta in (1.0, 0.32, 0.08):
        cfg, core = _boot_core(har_data, theta=theta)
        core, _ = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
            core, jnp.asarray(ox), jnp.asarray(oy)
        )
        comms.append(float(pruning.comm_volume_fraction(core.prune)))
    assert comms[0] > comms[1] > comms[2]


def test_comm_meter_counts_bytes(har_data):
    ox, oy, _, _ = har.odl_split(har_data, 0.6, 0)
    cfg, core = _boot_core(har_data, theta=1.0)
    core, outs = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
        core, jnp.asarray(ox[:50]), jnp.asarray(oy[:50])
    )
    assert float(core.meter.up_bytes) == 50 * 561 * 4
    assert float(core.meter.down_bytes) == 50 * 1


def test_teacher_outage_skips_training(har_data):
    """Paper: 'queries will be retried later or skipped' — an unavailable
    teacher must not corrupt the model (no training on garbage labels)."""
    ox, oy, _, _ = har.odl_split(har_data, 0.6, 0)
    cfg, core = _boot_core(har_data, theta=1.0)
    avail = jnp.zeros(20, jnp.bool_)  # total outage
    core2, outs = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
        core, jnp.asarray(ox[:20]), jnp.asarray(oy[:20]), teacher_available=avail
    )
    np.testing.assert_allclose(core2.elm.beta, core.elm.beta, atol=1e-6)
    assert not bool(jnp.any(outs.queried))
    assert float(core2.meter.total) == 0.0


def test_full_algorithm1_detects_drift_and_enters_training(har_data):
    """Run the full Algorithm-1 loop over a stream that shifts distribution
    mid-way; the detector must enter training mode and query labels."""
    cfg, core = _boot_core(har_data, theta="auto")
    dcfg = drift_mod.DriftConfig(warmup=32, k_sigma=3.0, enter_hits=2)
    cfg = odl_head.ODLCoreConfig(elm=cfg.elm, prune=cfg.prune, drift=dcfg)

    calm = har_data.test0_x[:300]
    # Strong synthetic shift: scaled + offset features (the recalibrated
    # surrogate has small feature magnitudes, so the shift is scaled up).
    shifted = np.clip(har_data.test1_x[:300] * 4.0 + 2.0, -3, 3)
    xs = jnp.asarray(np.concatenate([calm, shifted]))
    ys = jnp.asarray(
        np.concatenate([har_data.test0_y[:300], har_data.test1_y[:300]]).astype(np.int32)
    )
    core, outs = jax.jit(functools.partial(odl_head.run_stream, cfg=cfg))(core, xs, ys)
    training = np.asarray(outs.mode_training)
    assert not training[:200].any()  # calm segment: stays predicting
    assert training[320:].any()  # shift detected -> training mode
    assert np.asarray(outs.queried)[320:].sum() > 0  # labels were acquired
