"""OS-ELM unit + property tests (paper §2.1, Fig. 2(b)/(d))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only the @given tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import oselm


def _data(key, n, n_in, n_out):
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (n, n_in))
    y = jax.nn.one_hot(jax.random.randint(ky, (n,), 0, n_out), n_out)
    return x, y


@pytest.mark.parametrize("variant", ["base", "hash"])
def test_sequential_equals_closed_form(variant):
    """RLS over the stream == ridge regression over the batch (Woodbury)."""
    cfg = oselm.OSELMConfig(n_in=24, n_hidden=16, n_out=4, variant=variant, ridge=1e-2)
    x, y = _data(0, 60, 24, 4)
    st_ = oselm.init_state(cfg)
    for i in range(0, 60, 6):
        st_ = oselm.sequential_update(st_, x[i : i + 6], y[i : i + 6], cfg)
    beta_cf = oselm.fit_closed_form(cfg, x, y)
    np.testing.assert_allclose(st_.beta, beta_cf, rtol=0, atol=5e-3)
    assert int(st_.count) == 60


def test_rank1_equals_rankk():
    """One rank-k update == k rank-1 updates (same P, beta)."""
    cfg = oselm.OSELMConfig(n_in=10, n_hidden=12, n_out=3, ridge=1e-1)
    x, y = _data(1, 8, 10, 3)
    st_k = oselm.sequential_update(oselm.init_state(cfg), x, y, cfg)
    st_1 = oselm.init_state(cfg)
    for i in range(8):
        st_1 = oselm.sequential_update(st_1, x[i], y[i], cfg)
    np.testing.assert_allclose(st_k.beta, st_1.beta, atol=2e-4)
    np.testing.assert_allclose(st_k.P, st_1.P, atol=2e-4)


def test_masked_row_is_identity():
    """A masked (pruned) row must leave (P, beta, count) exactly unchanged."""
    cfg = oselm.OSELMConfig(n_in=10, n_hidden=8, n_out=3)
    x, y = _data(2, 4, 10, 3)
    st0 = oselm.sequential_update(oselm.init_state(cfg), x[:2], y[:2], cfg)
    mask = jnp.array([0.0, 0.0])
    st1 = oselm.sequential_update(st0, x[2:], y[2:], cfg, mask=mask)
    np.testing.assert_allclose(st1.P, st0.P, atol=1e-6)
    np.testing.assert_allclose(st1.beta, st0.beta, atol=1e-6)
    assert int(st1.count) == int(st0.count)


def test_partial_mask_equals_subset():
    """mask=[1,0,1] must equal updating with rows {0, 2} only."""
    cfg = oselm.OSELMConfig(n_in=10, n_hidden=8, n_out=3)
    x, y = _data(3, 3, 10, 3)
    st0 = oselm.init_state(cfg)
    st_m = oselm.sequential_update(st0, x, y, cfg, mask=jnp.array([1.0, 0.0, 1.0]))
    st_s = oselm.sequential_update(st0, x[jnp.array([0, 2])], y[jnp.array([0, 2])], cfg)
    np.testing.assert_allclose(st_m.beta, st_s.beta, atol=1e-4)
    np.testing.assert_allclose(st_m.P, st_s.P, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n_hidden=st.integers(4, 32),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_P_stays_symmetric_psd(n_hidden, k, seed):
    """Property: P is symmetric positive definite after any update sequence
    (it is the inverse of a ridge-regularized Gram matrix)."""
    cfg = oselm.OSELMConfig(n_in=12, n_hidden=n_hidden, n_out=3, ridge=1e-1)
    x, y = _data(seed, k, 12, 3)
    st_ = oselm.sequential_update(oselm.init_state(cfg), x, y, cfg)
    p = np.asarray(st_.P)
    np.testing.assert_allclose(p, p.T, atol=1e-4)
    eig = np.linalg.eigvalsh(p)
    assert eig.min() > 0, f"P lost positive definiteness: min eig {eig.min()}"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_update_monotone_gram(seed):
    """Property: P^{-1} grows by H^T H, so P shrinks (in PSD order):
    v^T P' v <= v^T P v for any direction v."""
    cfg = oselm.OSELMConfig(n_in=12, n_hidden=8, n_out=3, ridge=1e-1)
    x, y = _data(seed, 4, 12, 3)
    st0 = oselm.init_state(cfg)
    st1 = oselm.sequential_update(st0, x, y, cfg)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(8,)).astype(np.float32)
    q0 = float(v @ np.asarray(st0.P) @ v)
    q1 = float(v @ np.asarray(st1.P) @ v)
    assert q1 <= q0 + 1e-4


def test_init_state_batch_matches_closed_form():
    cfg = oselm.OSELMConfig(n_in=16, n_hidden=12, n_out=4, ridge=1e-2)
    x, y = _data(7, 40, 16, 4)
    st_ = oselm.init_state_batch(cfg, x, y)
    beta_cf = oselm.fit_closed_form(cfg, x, y)
    np.testing.assert_allclose(st_.beta, beta_cf, atol=2e-3)


def test_init_batch_then_sequential_equals_full_closed_form():
    """Paper's exact protocol: batch init on half, sequential on the rest."""
    cfg = oselm.OSELMConfig(n_in=16, n_hidden=12, n_out=4, ridge=1e-2)
    x, y = _data(8, 50, 16, 4)
    st_ = oselm.init_state_batch(cfg, x[:25], y[:25])
    for i in range(25, 50, 5):
        st_ = oselm.sequential_update(st_, x[i : i + 5], y[i : i + 5], cfg)
    beta_cf = oselm.fit_closed_form(cfg, x, y)
    np.testing.assert_allclose(st_.beta, beta_cf, atol=5e-3)


def test_learns_separable_problem():
    """End behaviour: OS-ELM reaches high accuracy on a separable problem."""
    key = jax.random.PRNGKey(0)
    centers = jax.random.normal(key, (4, 20)) * 2.0
    labels = jnp.tile(jnp.arange(4), 50)
    x = centers[labels] + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (200, 20))
    y = jax.nn.one_hot(labels, 4)
    cfg = oselm.OSELMConfig(n_in=20, n_hidden=64, n_out=4)
    st_ = oselm.init_state(cfg)
    for i in range(0, 200, 10):
        st_ = oselm.sequential_update(st_, x[i : i + 10], y[i : i + 10], cfg)
    preds, _ = oselm.predict(st_, x, cfg)
    assert float(jnp.mean((preds == labels).astype(jnp.float32))) > 0.95


def test_fleet_vmap_consistency():
    """Fleet update == per-stream updates."""
    cfg = oselm.OSELMConfig(n_in=10, n_hidden=8, n_out=3)
    fleet = oselm.init_fleet(cfg, 4)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 10))
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 0]), 3)
    fleet2 = oselm.fleet_update(fleet, x, y, cfg)
    for s in range(4):
        st_s = oselm.sequential_update(
            jax.tree.map(lambda a: a[s], fleet), x[s], y[s], cfg
        )
        np.testing.assert_allclose(
            jax.tree.map(lambda a: a[s], fleet2).beta, st_s.beta, atol=1e-3
        )


def test_hash_variant_needs_no_alpha_storage():
    """ODLHash predicts identically from config alone (alpha is implicit)."""
    cfg = oselm.OSELMConfig(n_in=10, n_hidden=8, n_out=3, variant="hash")
    assert oselm.make_alpha(cfg) is None
    x, y = _data(5, 6, 10, 3)
    st_ = oselm.sequential_update(oselm.init_state(cfg), x, y, cfg)
    p1, _ = oselm.predict(st_, x, cfg)
    p2, _ = oselm.predict(st_, x, cfg)
    np.testing.assert_array_equal(p1, p2)
