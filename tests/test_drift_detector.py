"""Drift detector unit tests (paper Alg. 1 line 3 / mode switching)."""

import jax.numpy as jnp
import numpy as np

from repro.core import drift


def _run_scores(scores, cfg):
    st = drift.init_state()
    states = []
    for s in scores:
        st = drift.update(st, jnp.asarray(s, jnp.float32), cfg)
        states.append(st)
    return states


def test_no_drift_on_stationary_stream():
    rng = np.random.default_rng(0)
    cfg = drift.DriftConfig(warmup=32, k_sigma=4.0)
    states = _run_scores(rng.normal(1.0, 0.05, 500), cfg)
    assert not any(bool(s.active) for s in states)


def test_detects_sudden_shift_and_recovers():
    rng = np.random.default_rng(1)
    cfg = drift.DriftConfig(warmup=32, k_sigma=3.0, enter_hits=2, exit_calm=16)
    calm = rng.normal(1.0, 0.05, 200)
    shifted = rng.normal(3.0, 0.05, 40)  # sudden drift
    back = rng.normal(1.0, 0.05, 200)
    states = _run_scores(np.concatenate([calm, shifted, back]), cfg)
    active = [bool(s.active) for s in states]
    assert not any(active[:200])
    assert any(active[200:240])  # IsDrift fires
    assert not active[-1]  # IsTrainDone: returns to predicting mode


def test_warmup_suppresses_detection():
    cfg = drift.DriftConfig(warmup=64, k_sigma=3.0, enter_hits=1)
    scores = [1.0] * 10 + [100.0] * 5  # huge outlier inside warmup
    states = _run_scores(scores, cfg)
    assert not any(bool(s.active) for s in states)


def test_score_combines_features_and_confidence():
    cfg = drift.DriftConfig()
    x = jnp.ones((8,))
    conf_hi = jnp.asarray([0.0, 1.0, 0.0])
    conf_lo = jnp.asarray([0.4, 0.5, 0.45])
    s_hi = float(drift.score(x, conf_hi, cfg))
    s_lo = float(drift.score(x, conf_lo, cfg))
    assert s_lo > s_hi  # low confidence -> higher drift score


def test_fleet_update_is_per_stream():
    # enter_hits=2 + k_sigma=4: a lone 3-sigma fluctuation in the calm
    # stream must not trip the detector.
    cfg = drift.DriftConfig(warmup=4, k_sigma=4.0, enter_hits=2)
    fleet = drift.init_fleet(2)
    rng = np.random.default_rng(2)
    for i in range(50):
        s0 = rng.normal(1.0, 0.01)
        s1 = rng.normal(1.0, 0.01) if i < 30 else 50.0  # stream 1 drifts
        fleet = drift.fleet_update(fleet, jnp.asarray([s0, s1], jnp.float32), cfg)
    assert not bool(fleet.active[0])
    assert bool(fleet.active[1])
