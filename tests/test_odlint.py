"""odlint golden tests: fixture pairs, the meta-test, and mutation tests.

Three layers:

* **Fixture pairs** — for every rule a ``<rule>_bad`` tree must fire it
  and the sibling ``<rule>_clean`` tree must not (clean trees also must
  not fire *any* rule — a clean fixture that trips a different rule is
  a fixture bug).
* **Meta-test** — every rule registered in ``ALL_RULES`` has a firing
  fixture.  A rule that cannot fire is dead code wearing a badge.
* **Mutation tests** — copy the *real* sources into a temp tree, delete
  a mirror entry / a handler branch, and assert the cross-file rules
  catch the exact drift they exist for.  This pins the rules to the
  real code's shape, not just to hand-built fixtures.

Pure-stdlib (no jax import) — the whole file runs in milliseconds.
"""

import json
import pathlib
import re
import shutil
import subprocess
import sys

import pytest

from repro.analysis import core
from repro.analysis.rules import ALL_RULES

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures" / "odlint"

RULE_IDS = tuple(r.rule_id for r in ALL_RULES)


def lint_tree(root: pathlib.Path) -> list:
    files = core.collect_files([str(root)])
    assert files, f"no fixture files under {root}"
    project = core.Project.load(files, root=root)
    return core.run_rules(project, ALL_RULES)


def rules_fired(findings) -> set:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Fixture pairs
# ---------------------------------------------------------------------------

# rule id -> fixture stem; ODL000 is the suppression-hygiene meta-rule
# enforced by the framework itself rather than a Rule subclass.
FIXTURE_FOR = {
    "ODL000": "odl000",
    "ODL001": "odl001",
    "ODL002": "odl002",
    "ODL003": "odl003",
    "ODL004": "odl004",
    "ODL005": "odl005",
    "ODL006": "odl006",
}


@pytest.mark.parametrize("rule_id,stem", sorted(FIXTURE_FOR.items()))
def test_bad_fixture_fires(rule_id, stem):
    findings = lint_tree(FIXTURES / f"{stem}_bad")
    assert rule_id in rules_fired(findings), (
        f"{rule_id} did not fire on its bad fixture: {findings}"
    )


@pytest.mark.parametrize("rule_id,stem", sorted(FIXTURE_FOR.items()))
def test_clean_fixture_is_clean(rule_id, stem):
    findings = lint_tree(FIXTURES / f"{stem}_clean")
    assert not findings, (
        f"clean fixture for {rule_id} fired: "
        f"{[f.format_text() for f in findings]}"
    )


def test_every_shipped_rule_has_a_firing_fixture():
    """A rule that can't fire is dead."""
    for rule in ALL_RULES:
        assert rule.rule_id in FIXTURE_FOR, (
            f"{rule.rule_id} has no fixture mapping — add "
            f"tests/fixtures/odlint/<stem>_bad and _clean trees"
        )
    # and the mapping has no stale entries beyond the framework rule
    assert set(FIXTURE_FOR) == set(RULE_IDS) | {"ODL000"}


def test_rules_have_ids_titles_rationales():
    seen = set()
    for rule in ALL_RULES:
        assert re.fullmatch(r"ODL\d{3}", rule.rule_id)
        assert rule.rule_id not in seen, f"duplicate id {rule.rule_id}"
        seen.add(rule.rule_id)
        assert rule.title, rule.rule_id
        assert rule.rationale, rule.rule_id


# ---------------------------------------------------------------------------
# ODL005 fine-grained behaviors
# ---------------------------------------------------------------------------


def test_odl005_flags_all_three_shapes():
    findings = [
        f for f in lint_tree(FIXTURES / "odl005_bad") if f.rule == "ODL005"
    ]
    msgs = "\n".join(f.message for f in findings)
    assert "trace time" in msgs, msgs  # clock in jitted fn
    assert "bare 'except:'" in msgs, msgs
    assert "print()" in msgs, msgs


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


def test_reasoned_suppression_silences_and_bare_does_not(tmp_path):
    bad = FIXTURES / "odl001_bad" / "mod.py"
    src = bad.read_text()

    # same-line reasoned suppression silences the finding
    reasoned = src.replace(
        "self.count = 0  # unguarded write: lost-update race with bump()",
        "self.count = 0  # odlint: disable=ODL001 -- single-threaded teardown",
    )
    d1 = tmp_path / "reasoned"
    d1.mkdir()
    (d1 / "mod.py").write_text(reasoned)
    assert "ODL001" not in rules_fired(lint_tree(d1))

    # a bare suppression does NOT silence it and adds ODL000
    bare = src.replace(
        "self.count = 0  # unguarded write: lost-update race with bump()",
        "self.count = 0  # odlint: disable=ODL001",
    )
    d2 = tmp_path / "bare"
    d2.mkdir()
    (d2 / "mod.py").write_text(bare)
    fired = rules_fired(lint_tree(d2))
    assert "ODL001" in fired and "ODL000" in fired


def test_standalone_suppression_covers_next_code_line(tmp_path):
    src = (FIXTURES / "odl001_bad" / "mod.py").read_text()
    covered = src.replace(
        "        self.count = 0  # unguarded write: lost-update race with bump()",
        "        # odlint: disable=ODL001 -- single-threaded teardown\n"
        "        self.count = 0",
    )
    d = tmp_path / "standalone"
    d.mkdir()
    (d / "mod.py").write_text(covered)
    assert "ODL001" not in rules_fired(lint_tree(d))


# ---------------------------------------------------------------------------
# Mutation tests: the cross-file rules vs the REAL sources
# ---------------------------------------------------------------------------


def _real_tree(tmp_path, files) -> pathlib.Path:
    """Copy real repo modules into a temp repro/ package tree."""
    root = tmp_path / "tree"
    for rel in files:
        dst = root / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / "src" / rel, dst)
    return root


ODL003_FILES = (
    "repro/engine/stream.py",
    "repro/runtime/telemetry.py",
    "repro/runtime/elastic.py",
)
ODL004_FILES = (
    "repro/runtime/elastic.py",
    "repro/runtime/worker.py",
    "repro/engine/rpc.py",
    "repro/engine/snapshot.py",
)


def test_real_tree_subsets_are_clean(tmp_path):
    """Precondition for the mutations: the unmutated copies are clean."""
    root = _real_tree(tmp_path, ODL003_FILES + ODL004_FILES)
    findings = lint_tree(root)
    assert not findings, [f.format_text() for f in findings]


def test_mutation_deleted_mirror_entry_fires_odl003(tmp_path):
    root = _real_tree(tmp_path, ODL003_FILES)
    telem = root / "repro/runtime/telemetry.py"
    src = telem.read_text()
    assert '"tickets_reasked",' in src
    telem.write_text(src.replace('"tickets_reasked",', "", 1))
    findings = [f for f in lint_tree(root) if f.rule == "ODL003"]
    assert any("tickets_reasked" in f.message for f in findings), findings


def test_mutation_new_stats_field_fires_odl003(tmp_path):
    root = _real_tree(tmp_path, ODL003_FILES)
    stream = root / "repro/engine/stream.py"
    src = stream.read_text()
    anchor = "    tickets_reasked: int = 0"
    assert anchor in src
    stream.write_text(
        src.replace(anchor, anchor + "\n    queries_forgotten: int = 0", 1)
    )
    findings = [f for f in lint_tree(root) if f.rule == "ODL003"]
    assert any("queries_forgotten" in f.message for f in findings), findings


def test_mutation_deleted_handler_branch_fires_odl004(tmp_path):
    root = _real_tree(tmp_path, ODL004_FILES)
    worker = root / "repro/runtime/worker.py"
    src = worker.read_text()
    anchor = (
        '                if cmd == "metrics":\n'
        "                    return self._metrics(bool(header.get(\"trace\", False)))\n"
    )
    assert anchor in src, "worker.py metrics branch moved — update the mutation"
    worker.write_text(src.replace(anchor, "", 1))
    findings = [f for f in lint_tree(root) if f.rule == "ODL004"]
    assert any(
        "'metrics'" in f.message and "no handler" in f.message
        for f in findings
    ), findings


def test_mutation_new_sent_kind_fires_odl004(tmp_path):
    root = _real_tree(tmp_path, ODL004_FILES)
    elastic = root / "repro/runtime/elastic.py"
    src = elastic.read_text()
    anchor = 'self._request({"kind": "status"})'
    assert anchor in src, "elastic.py status sender moved — update the mutation"
    elastic.write_text(
        src.replace(
            anchor,
            'self._request({"kind": "pause"}) and ' + anchor,
            1,
        )
    )
    findings = [f for f in lint_tree(root) if f.rule == "ODL004"]
    assert any("'pause'" in f.message for f in findings), findings


def test_mutation_unlocked_write_fires_odl001(tmp_path):
    """Re-break the PR-10 SpanTracer.dropped race: moving the increment
    back outside the lock must fire the lock-discipline rule (the write
    carries a guarded-by annotation)."""
    root = _real_tree(tmp_path, ("repro/runtime/telemetry.py",))
    telem = root / "repro/runtime/telemetry.py"
    src = telem.read_text()
    anchor = "                    self.dropped += 1  # odlint: guarded-by(_lock)"
    assert anchor in src, "telemetry.py dropped increment moved"
    mutated = src.replace(
        anchor,
        "                    pass",
        1,
    ).replace(
        "        return (name, time.monotonic_ns())",
        "        self.dropped += 1  # odlint: guarded-by(_lock)\n"
        "        return (name, time.monotonic_ns())",
        1,
    )
    telem.write_text(mutated)
    findings = [f for f in lint_tree(root) if f.rule == "ODL001"]
    assert any("dropped" in f.message for f in findings), findings


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def run_cli(*args, cwd=None):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "odlint"), *args],
        capture_output=True,
        text=True,
        cwd=cwd or REPO,
        timeout=120,
    )


def test_cli_clean_on_repo_exits_zero():
    proc = run_cli("src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_findings_exit_one_and_json_report(tmp_path):
    out = tmp_path / "report.json"
    proc = run_cli(
        str(FIXTURES / "odl001_bad"), "--format", "json", "--output", str(out)
    )
    assert proc.returncode == 1
    doc = json.loads(out.read_text())
    assert doc["tool"] == "odlint"
    assert any(f["rule"] == "ODL001" for f in doc["findings"])
    assert {r["id"] for r in doc["rules"]} == set(RULE_IDS)


def test_cli_baseline_suppresses_known_findings(tmp_path):
    base = tmp_path / "baseline.json"
    target = str(FIXTURES / "odl001_bad")
    proc = run_cli(target, "--baseline", str(base), "--write-baseline")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # with the baseline in place the same findings no longer block
    proc = run_cli(target, "--baseline", str(base))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # but a different tree's findings still do
    proc = run_cli(str(FIXTURES / "odl002_bad"), "--baseline", str(base))
    assert proc.returncode == 1


def test_cli_rule_selection():
    proc = run_cli(str(FIXTURES / "odl001_bad"), "--rules", "ODL004")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = run_cli(str(FIXTURES / "odl001_bad"), "--rules", "NOPE")
    assert proc.returncode == 2
    proc = run_cli("--list-rules")
    assert proc.returncode == 0
    for rid in RULE_IDS:
        assert rid in proc.stdout


def test_committed_baseline_is_empty():
    """The tree lints clean, so the committed CI baseline must stay
    empty — new findings are fixed or reason-suppressed, not baselined."""
    doc = json.loads((REPO / ".odlint-baseline.json").read_text())
    assert doc["fingerprints"] == []
