"""Fleet telemetry tests (ISSUE 9): registry/tracer/exporter basics, the
StreamStats<->registry cross-check lock for every backpressure policy,
bit-for-bit parity of instrumented vs uninstrumented runs, snapshot
restore semantics for the load-signal gauges vs the trace ring, and the
LabelServer wire ``stats`` scrape."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import rpc, snapshot, stream
from repro.runtime import telemetry


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Telemetry is a process-wide global — every test starts and ends
    with it off so nothing leaks across tests (or into other files)."""
    telemetry.disable()
    yield
    telemetry.disable()


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=16):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return jnp.asarray(xs), ys


# ---------------------------------------------------------------------------
# Registry / tracer / exporter basics
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms_roundtrip_prometheus():
    reg = telemetry.Registry()
    reg.count("odl_mux_rounds", 3, worker="w0")
    reg.count("odl_mux_rounds", 2, worker="w0")
    reg.set_counter("odl_stream_ticks", 41, tenant="t0")
    reg.gauge("odl_stream_tick_rate_ema", 12.5, tenant="t0")
    reg.observe("odl_rpc_batch_occupancy", 3)
    reg.observe("odl_rpc_batch_occupancy", 5)

    assert reg.get_counter("odl_mux_rounds", worker="w0") == 5
    assert reg.get_counter("odl_mux_rounds", worker="nope") == 0
    assert reg.get_gauge("odl_stream_tick_rate_ema", tenant="t0") == 12.5

    text = reg.prometheus_text()
    parsed = telemetry.parse_prometheus(text)
    assert parsed[("odl_mux_rounds", (("worker", "w0"),))] == 5
    assert parsed[("odl_stream_ticks", (("tenant", "t0"),))] == 41
    assert parsed[("odl_stream_tick_rate_ema", (("tenant", "t0"),))] == 12.5
    assert parsed[("odl_rpc_batch_occupancy_count", ())] == 2
    assert parsed[("odl_rpc_batch_occupancy_sum", ())] == 8
    # Integral counters print without a trailing .0 (exact cross-checks).
    assert "odl_stream_ticks{tenant=\"t0\"} 41\n" in text

    snap = reg.snapshot()
    assert snap["counters"]["odl_stream_ticks"] == [
        {"labels": {"tenant": "t0"}, "value": 41.0}
    ]
    assert snap["histograms"]["odl_rpc_batch_occupancy"][0]["max"] == 5.0


def test_prometheus_label_escaping_roundtrips():
    reg = telemetry.Registry()
    reg.set_counter("odl_stream_ticks", 1, tenant='we"ird\\na\nme')
    parsed = telemetry.parse_prometheus(reg.prometheus_text())
    assert parsed[("odl_stream_ticks", (("tenant", 'we"ird\\na\nme'),))] == 1


def test_parse_prometheus_rejects_malformed_lines():
    with pytest.raises(ValueError):
        telemetry.parse_prometheus("justonetoken\n")
    with pytest.raises(ValueError):
        telemetry.parse_prometheus('bad{tenant=unquoted} 1\n')


def test_tracer_spans_events_sampling_and_bounded_ring():
    tr = telemetry.SpanTracer(capacity=4, sample=2)
    for i in range(6):
        tok = tr.begin("stream.tick")
        tr.end(tok, t=i)
    tr.event("rpc.reconnect", endpoint="x:1")
    spans = tr.spans()
    # sample=2 keeps every other begin; capacity=4 bounds the ring.
    assert tr.dropped == 3
    assert len(spans) <= 4
    names = {s[0] for s in spans}
    assert "rpc.reconnect" in names

    trace = tr.chrome_trace()
    phases = {ev["name"]: ev["ph"] for ev in trace["traceEvents"]}
    assert phases["rpc.reconnect"] == "i"  # instant
    assert phases.get("stream.tick", "X") == "X"  # complete span
    jsonl = tr.to_jsonl()
    assert "rpc.reconnect" in jsonl and jsonl.endswith("\n")

    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0


def test_enable_is_idempotent_and_disable_resets():
    assert telemetry.get() is None
    tel = telemetry.enable()
    tel.registry.count("odl_mux_rounds")
    assert telemetry.enable() is tel  # existing instance kept
    assert tel.registry.get_counter("odl_mux_rounds") == 1
    telemetry.disable()
    assert telemetry.get() is None


def test_check_stream_identity_flags_broken_accounting():
    reg = telemetry.Registry()
    telemetry.sync_stream_stats(reg, stream.StreamStats(
        queries_issued=10, labels_applied=6, queries_dropped=2,
        queries_lost=1, queries_coalesced=0), pending=1, tenant="ok")
    telemetry.sync_stream_stats(reg, stream.StreamStats(
        queries_issued=10, labels_applied=6), pending=0, tenant="broken")
    out = telemetry.check_stream_identity(
        telemetry.parse_prometheus(reg.prometheus_text()))
    by_tenant = {dict(k)["tenant"]: v for k, v in out.items()}
    assert by_tenant == {"ok": True, "broken": False}
    # An empty scrape yields an empty dict — callers treat that as failure.
    assert telemetry.check_stream_identity({}) == {}


# ---------------------------------------------------------------------------
# Satellite 1: registry counters identical to StreamStats for every
# backpressure policy, and telemetry never perturbs the run.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", stream.BACKPRESSURE_POLICIES)
def test_registry_mirrors_stream_stats_and_never_perturbs_run(policy):
    """The lock: after a run, every odl_stream_* counter equals the
    StreamStats field verbatim, and the instrumented run's final state is
    bit-for-bit the uninstrumented one (telemetry reads clocks and
    appends to rings; it must never touch the device op sequence)."""
    cfg = _cfg(min_trained=1)
    t_len, s_len = 50, 4
    xs, ys = _stream_data(cfg, t_len, s_len, seed=3)

    def run_once():
        # latency 7 >> capacity 3 saturates the ring so the policy under
        # test actually fires (drops / deferrals / coalescing — not just
        # the happy path).
        teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=7)
        return stream.run(
            engine.init_fleet(cfg, s_len), (xs[t] for t in range(t_len)), cfg,
            teacher, mode="train_phase", capacity=3, backpressure=policy,
        )

    telemetry.disable()
    st_plain, _, stats_plain = run_once()

    tel = telemetry.enable()
    st_instr, _, stats = run_once()

    # Bit-for-bit parity of the instrumented run.
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_plain)[0],
        jax.tree_util.tree_flatten_with_path(st_instr)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"telemetry perturbed state leaf {path} under {policy}",
        )
    for f in telemetry.STREAM_COUNTER_FIELDS:
        assert getattr(stats_plain, f) == getattr(stats, f), f

    # The policy actually exercised its branch.
    if policy in ("drop_oldest", "drop_newest"):
        assert stats.queries_dropped > 0
    elif policy == "block":
        assert stats.asks_deferred > 0
    else:
        assert stats.queries_coalesced > 0

    # Registry view == StreamStats view, field for field.
    for f in telemetry.STREAM_COUNTER_FIELDS:
        assert tel.registry.get_counter(f"odl_stream_{f}") == getattr(stats, f), f
    for f in telemetry.STREAM_GAUGE_FIELDS:
        assert tel.registry.get_gauge(f"odl_stream_{f}") == float(getattr(stats, f)), f

    # And the scraped identity holds after the drain (pending gauge 0).
    checks = telemetry.check_stream_identity(
        telemetry.parse_prometheus(tel.registry.prometheus_text()))
    assert checks and all(checks.values())
    assert tel.registry.get_gauge("odl_stream_queries_pending") == 0
    # The hot path traced ticks too.
    assert any(s[0] == "stream.tick" for s in tel.tracer.spans())


def test_midrun_scrape_identity_includes_pending_queries():
    """Mid-run (ring non-empty) the four terminal buckets do NOT cover
    queries_issued — the exported pending gauge is what closes the
    identity at any instant."""
    cfg = _cfg(min_trained=1)
    t_len, s_len = 12, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=5)
    tel = telemetry.enable()
    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg,
        stream.LatencyTeacher(stream.array_labels(ys), latency=50),
        mode="train_phase", capacity=64,
    )
    sess.telemetry_labels = {"tenant": "t0"}
    sess.start(xs[0])
    for t in range(1, t_len):
        sess.advance(xs[t])
    sess.sync_telemetry()
    assert sess.pending_queries() > 0  # nothing answered yet (latency 50)
    parsed = telemetry.parse_prometheus(tel.registry.prometheus_text())
    checks = telemetry.check_stream_identity(parsed)
    key = (("tenant", "t0"),)
    assert checks[key] is True
    assert parsed[("odl_stream_queries_pending", key)] == sess.pending_queries()
    # Without the pending gauge the identity would be violated mid-run.
    del parsed[("odl_stream_queries_pending", key)]
    assert telemetry.check_stream_identity(parsed)[key] is False
    sess.advance(None)
    sess.finish()


# ---------------------------------------------------------------------------
# Satellite 3: load-signal gauges ride snapshots; the trace ring does not.
# ---------------------------------------------------------------------------


def test_snapshot_restores_load_signal_gauges_but_not_trace_ring():
    cfg = _cfg(min_trained=1)
    t_len, s_len = 20, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=9)
    tel = telemetry.enable()
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=2)
    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg, teacher, mode="train_phase",
        capacity=8,
    )
    sess.start(xs[0])
    for t in range(1, 12):
        sess.advance(xs[t])
    assert sess.stats.tick_rate_ema > 0
    assert sess.stats.ring_occupancy_hwm > 0
    tree = snapshot.capture(sess)

    # The load signals travel in the snapshot meta "stats"...
    meta_stats = snapshot._meta_of(tree)["stats"]
    assert meta_stats["tick_rate_ema"] == sess.stats.tick_rate_ema
    assert meta_stats["ring_occupancy_hwm"] == sess.stats.ring_occupancy_hwm
    # ...while nothing of the telemetry registry/tracer is in the tree.
    assert "telemetry" not in tree
    assert any(s[0] == "snapshot.save" for s in tel.tracer.spans())

    # Simulate landing in a fresh process: new telemetry instance.
    telemetry.disable()
    tel2 = telemetry.enable()
    fresh = stream.LatencyTeacher(stream.array_labels(ys), latency=2)
    sess2 = snapshot.restore(tree, fresh, cfg=cfg)
    assert sess2.stats.tick_rate_ema == sess.stats.tick_rate_ema
    assert sess2.stats.ring_occupancy_hwm == sess.stats.ring_occupancy_hwm
    # The destination tracer carries only what happened here (the restore
    # span) — no stream.tick spans from the source process.
    names = {s[0] for s in tel2.tracer.spans()}
    assert "snapshot.restore" in names
    assert "stream.tick" not in names


def test_all_stream_stats_counters_are_mirrored():
    """Growth guard: every integer accounting counter StreamStats gains
    must be added to STREAM_COUNTER_FIELDS (or STREAM_MIRROR_EXCLUDED).
    The exclusion set lives in telemetry so odlint's ODL003 rule and this
    runtime check enforce the same partition."""
    excluded = set(telemetry.STREAM_MIRROR_EXCLUDED) | set(
        telemetry.STREAM_GAUGE_FIELDS
    )
    fields = {f.name for f in dataclasses.fields(stream.StreamStats)}
    assert fields - excluded == set(telemetry.STREAM_COUNTER_FIELDS)
    assert set(telemetry.STREAM_GAUGE_FIELDS) < fields
    # the three partitions are disjoint
    assert not set(telemetry.STREAM_MIRROR_EXCLUDED) & set(
        telemetry.STREAM_COUNTER_FIELDS
    )
    assert not set(telemetry.STREAM_MIRROR_EXCLUDED) & set(
        telemetry.STREAM_GAUGE_FIELDS
    )


# ---------------------------------------------------------------------------
# Satellite 2: LabelServer counters scraped over the wire.
# ---------------------------------------------------------------------------


def test_label_server_stats_scrape_over_the_wire():
    server = rpc.LabelServer(n_out=4).start()
    try:
        teacher = rpc.RpcTeacher(server.host, server.port, timeout_s=10.0)
        feats = np.zeros((3, 4), np.float32)
        mask = np.array([True, False, True])
        teacher.ask(feats, mask, tick=0)
        replies = []
        import time as _time
        t0 = _time.monotonic()
        while not replies and _time.monotonic() - t0 < 10.0:
            replies = teacher.poll(0)
        teacher.close()
        assert replies

        stats = rpc.server_stats(server.host, server.port)
        assert stats["asks_served"] >= 1
        assert stats["frames_v2"] >= 1
        assert stats["frame_errors"] == 0
        assert stats["thread_count"] >= 0
        assert stats["n_out"] == 4
        # The scrape itself is not an ask.
        again = rpc.server_stats(server.host, server.port)
        assert again["asks_served"] == stats["asks_served"]
        assert again["connections_accepted"] > stats["connections_accepted"]
    finally:
        server.close()


def test_label_server_stats_scrape_respects_hmac_secret():
    server = rpc.LabelServer(n_out=4, secret="s3kr1t").start()
    try:
        stats = rpc.server_stats(server.host, server.port, secret="s3kr1t")
        assert stats["auth_failures"] == 0
        with pytest.raises((ConnectionError, OSError)):
            rpc.server_stats(server.host, server.port, secret="wrong",
                             timeout_s=2.0)
        assert rpc.server_stats(server.host, server.port,
                                secret="s3kr1t")["auth_failures"] >= 1
    finally:
        server.close()


def test_rpc_client_mirrors_wire_meters_into_registry():
    tel = telemetry.enable()
    server = rpc.LabelServer(n_out=4).start()
    try:
        client = rpc.BatchedRpcClient(server.host, server.port,
                                      timeout_s=10.0, batch_window_s=0.0)
        h = client.tenant("t0")
        h.ask(np.zeros((2, 4), np.float32), np.array([True, True]), 0)
        import time as _time
        t0 = _time.monotonic()
        while not h.poll(0) and _time.monotonic() - t0 < 10.0:
            _time.sleep(1e-3)
        client.sync_telemetry()
        ep = f"{server.host}:{server.port}"
        assert tel.registry.get_counter("odl_rpc_wire_messages", endpoint=ep) > 0
        assert tel.registry.get_counter("odl_rpc_wire_bytes", endpoint=ep) > 0
        assert tel.registry.get_counter("odl_rpc_asks_sent", endpoint=ep) >= 1
        # The flush span + batch occupancy histogram landed too.
        assert any(s[0] == "rpc.flush" for s in tel.tracer.spans())
        snap = tel.registry.snapshot()
        assert snap["histograms"]["odl_rpc_batch_occupancy"][0]["count"] >= 1
        client.close()
    finally:
        server.close()
