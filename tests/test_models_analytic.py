"""Memory / power model tests — exact reproduction of paper Tables 1, 2, 4."""

import numpy as np
import pytest

from repro.core import memory_model as mm
from repro.core import power_model as pm


def test_table1_exact():
    """Every entry of paper Table 1, to the published 0.01 kB rounding."""
    got = mm.table1()
    for variant in ("noodl", "base", "hash"):
        np.testing.assert_allclose(
            got[variant], mm.PAPER_TABLE1[variant], atol=0.005, rtol=0
        )


def test_table2_param_counts():
    for N, expect in mm.PAPER_TABLE2.items():
        got = mm.odl_param_count(mm.CoreShape(N=N))
        assert abs(got - expect) / expect < 0.02  # paper rounds to "34k"/"133k"


def test_odlhash_smaller_than_noodl_for_small_N():
    """Paper's headline memory result: ODLHash < NoODL for N <= 256."""
    for N in (32, 64, 128, 256):
        s = mm.CoreShape(N=N)
        assert mm.odlhash_bytes(s) < mm.noodl_bytes(s)
    s = mm.CoreShape(N=512)
    assert mm.odlhash_bytes(s) > mm.noodl_bytes(s)


def test_memory_ratio_128_to_256():
    """Paper §3.1: ODLHash memory grows 3.91x from N=128 to N=256."""
    r = mm.odlhash_bytes(mm.CoreShape(N=256)) / mm.odlhash_bytes(mm.CoreShape(N=128))
    assert abs(r - 3.91) < 0.01


def test_table4_times_reproduced_by_cycle_model():
    s = mm.CoreShape()
    assert abs(pm.predict_time_ms(s) - pm.T_PRED_MS) < 1e-6  # calibrated exact
    assert abs(pm.train_time_ms(s) - pm.T_TRAIN_MS) < 1e-6
    # Sanity: model extrapolates sensibly (times scale ~linearly in N for
    # prediction, ~quadratically for training).
    t64 = pm.train_time_ms(mm.CoreShape(N=64))
    t256 = pm.train_time_ms(mm.CoreShape(N=256))
    assert t64 < pm.T_TRAIN_MS < t256


def test_per_second_operation_feasible():
    """Paper: 171 ms training at 10 MHz is 'fast enough for per-second'."""
    assert pm.train_time_ms(mm.CoreShape()) + pm.predict_time_ms(mm.CoreShape()) < 1000


@pytest.mark.parametrize("period,expect", sorted(pm.PAPER_AUTO_REDUCTION.items()))
def test_fig4_auto_power_reduction(period, expect):
    """Fig. 4 'Auto' bars: one calibrated constant (E_comm) must reproduce
    all three event frequencies.  1 ev/s is the calibration point; 1/5 s and
    1/10 s are genuine predictions of the model."""
    got = pm.power_reduction_pct(pm.PAPER_AUTO_COMM_VOLUME, period)
    assert abs(got - expect) < 0.5, f"period {period}s: {got:.1f}% vs paper {expect}%"


def test_power_monotone_in_query_rate():
    ps = [pm.avg_power_mw(q, 1.0) for q in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a < b for a, b in zip(ps, ps[1:]))


def test_raw_ble_energy_is_much_smaller_than_calibrated():
    """Documents the calibration: protocol overhead dominates payload."""
    assert pm.raw_ble_energy_uj() < 0.1 * pm.E_COMM_UJ
