"""Streaming async-teacher runtime tests: zero-latency bit-for-bit parity
with run_fleet, out-of-order deferred labels, ring overflow, permanent
teacher outage, and the scalar-API confinement rule."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import stream


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=16):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0, shift_at=None):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    if shift_at is not None:
        sev = np.linspace(2.0, 4.0, s)[None, :, None]
        xs[shift_at:] = np.clip(xs[shift_at:] * sev + 0.5 * sev, -4, 4)
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return jnp.asarray(xs), ys


@pytest.mark.parametrize("mode", ["algo1", "train_phase"])
def test_zero_latency_matches_run_fleet_bit_for_bit(mode):
    """stream.run with an instant teacher IS run_fleet: every output field
    and every leaf of the final state must match bit-for-bit (plan/learn
    are the exact two halves of fleet_step)."""
    cfg = _cfg()
    t_len, s_len = 90, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=1, shift_at=40)

    st_f, out_f = engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, jnp.asarray(ys), cfg, mode=mode
    )

    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=0)
    st_s, out_s, stats = stream.run(
        engine.init_fleet(cfg, s_len), (xs[t] for t in range(t_len)), cfg,
        teacher, mode=mode,
    )

    for name in out_f._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(out_f, name)), np.asarray(getattr(out_s, name)),
            err_msg=f"output field {name!r} diverged",
        )
    for (path_a, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_f)[0],
        jax.tree_util.tree_flatten_with_path(st_s)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"state leaf {path_a} diverged"
        )
    assert stats.ticks == t_len
    assert stats.labels_applied == stats.queries_issued > 0
    assert stats.tickets_dropped == stats.tickets_lost == stats.replies_orphaned == 0
    assert stats.label_latency_p95 == 0.0


def test_deferred_out_of_order_labels_train_on_query_time_features():
    """Jittered latency delivers answers out of order; every answered query
    must still train (count increments) and ``trained`` marks the tick the
    query was issued at, never a tick that was not queried."""
    cfg = _cfg(min_trained=1)
    t_len, s_len = 40, 4
    xs, ys = _stream_data(cfg, t_len, s_len, seed=2)

    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=2, jitter=5, seed=3)
    st0 = engine.init_fleet(cfg, s_len)
    st, outs, stats = stream.run(
        st0, (xs[t] for t in range(t_len)), cfg, teacher, mode="train_phase",
    )

    assert stats.labels_applied > 0
    assert stats.labels_applied == int(np.asarray(st.elm.count).sum())
    assert stats.labels_applied == int(outs.trained.sum())
    # trained ⊆ queried, per tick (labels only ever apply to asked samples).
    assert not np.any(outs.trained & ~outs.queried)
    # The jitter actually exercised the out-of-order path.
    lat = np.asarray(stats.label_latency_ticks)
    assert lat.min() >= 2 and lat.max() > lat.min()
    assert stats.tickets_lost == 0 and len(teacher._inbox) == 0


def test_ring_overflow_drops_oldest_and_meters_it():
    """With capacity 2 and a teacher slower than the stream, only the two
    youngest tickets survive; evictions and orphaned replies are counted."""
    cfg = _cfg(min_trained=1_000_000)  # cold heads: every tick queries
    t_len, s_len = 6, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=4)

    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=50)
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, s_len), (xs[t] for t in range(t_len)), cfg,
        teacher, mode="train_phase", capacity=2,
    )

    assert stats.tickets_issued == t_len
    assert stats.tickets_dropped == t_len - 2
    assert stats.queries_dropped == (t_len - 2) * s_len
    # The drain waits out the latency: the 2 surviving tickets apply, the
    # 4 evicted tickets' late answers arrive as orphans.
    assert stats.labels_applied == 2 * s_len
    assert stats.replies_orphaned == t_len - 2
    assert stats.tickets_lost == 0
    np.testing.assert_array_equal(outs.trained.sum(axis=0), [2, 2, 2])
    np.testing.assert_array_equal(outs.trained[-2:], np.ones((2, s_len), bool))


def test_permanent_outage_leaves_heads_identical_to_never_queried():
    """A teacher that never answers must leave every head bit-identical to
    a run where the teacher was known-unavailable (no training on garbage),
    while the queries it swallowed are still metered as lost."""
    cfg = _cfg(min_trained=1)
    t_len, s_len = 30, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=5)

    dead = stream.LatencyTeacher(stream.array_labels(ys), latency=0, outage_after=0)
    st_out, outs_out, stats = stream.run(
        engine.init_fleet(cfg, s_len), (xs[t] for t in range(t_len)), cfg,
        dead, mode="train_phase",
    )

    st_ref, outs_ref = engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, jnp.asarray(ys), cfg,
        mode="train_phase",
        teacher_available=jnp.zeros((t_len, s_len), jnp.bool_),
    )

    for a, b in zip(jax.tree.leaves(st_out.elm), jax.tree.leaves(st_ref.elm)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(outs_out.pred, np.asarray(outs_ref.pred))
    assert stats.labels_applied == 0
    assert not outs_out.trained.any()
    assert stats.tickets_lost == stats.tickets_issued > 0
    assert stats.queries_issued > 0  # queries were issued (and metered) ...
    assert float(jnp.sum(st_out.meter.total)) > 0  # ... bytes left the edge


def test_deferred_ladder_judges_against_query_time_theta():
    """A disagreeing low-confidence query whose answer arrives after the
    ladder stepped down must still raise theta (paper §2.2: a query
    revealing disagreement steps UP) — the runtime passes the plan-time
    threshold into the deferred controller update."""
    cfg = pruning.PruneConfig()  # ladder (1.0, .64, .32, .16, .08)
    st = pruning.init_fleet(1)._replace(level=jnp.asarray([2]))  # theta now 0.32
    conf = jnp.asarray([0.5], jnp.float32)  # below theta=0.64 at query time
    q = jnp.asarray([True])
    disagree = jnp.asarray([False])
    # Judged at the current (post-step-down) theta the mismatch is masked...
    cur = pruning.update(st, q, disagree, conf, cfg)
    assert int(cur.level[0]) == 2
    # ...but judged at the query-time theta it steps the ladder back up.
    deferred = pruning.update(st, q, disagree, conf, cfg, theta=jnp.asarray([0.64]))
    assert int(deferred.level[0]) == 1


def test_runner_caches_are_bounded_with_counters():
    """The compiled-runner caches must be bounded (no leak per retired
    config in a long-lived server) and expose hit/miss counters."""
    info = stream.cache_stats()
    for name in ("chunk_runner", "plan_runner", "learn_runner"):
        assert info[name]["maxsize"] == engine.fleet.RUNNER_CACHE_SIZE
        assert {"hits", "misses", "size"} <= set(info[name])
    cfg = _cfg()
    xs, ys = _stream_data(cfg, 4, 2, seed=6)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=0)
    before = stream.cache_stats()["plan_runner"]
    stream.run(engine.init_fleet(cfg, 2), (xs[t] for t in range(4)), cfg,
               teacher, mode="train_phase")
    after = stream.cache_stats()["plan_runner"]
    # 4 ticks -> one miss (first compile) plus hits, all visible in counters.
    assert after["misses"] >= before["misses"]
    assert after["hits"] > before["hits"]


def test_scalar_api_confined_to_engine():
    """ISSUE 2 acceptance: no module outside core/odl_head.py (the alias)
    and repro/engine may import the scalar ODL API."""
    root = pathlib.Path(__file__).resolve().parent.parent
    allowed = {
        root / "src" / "repro" / "core" / "odl_head.py",
        # The core package re-exports its own alias submodule so the
        # original ``repro.core.odl_head`` import path keeps resolving.
        root / "src" / "repro" / "core" / "__init__.py",
    }
    offenders = []
    for base in ("src", "benchmarks", "examples"):
        for p in sorted((root / base).rglob("*.py")):
            if p in allowed or (root / "src" / "repro" / "engine") in p.parents:
                continue
            text = p.read_text()
            if "odl_head" in text or "engine.scalar" in text or "engine import scalar" in text:
                offenders.append(str(p.relative_to(root)))
    assert not offenders, f"scalar ODL API imported outside the alias: {offenders}"
