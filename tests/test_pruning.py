"""P1P2 auto data pruning tests (paper §2.2)."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only the @given tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import pruning


def _state(level=0, streak=0):
    s = pruning.init_state()
    return s._replace(
        level=jnp.asarray(level, jnp.int32), streak=jnp.asarray(streak, jnp.int32)
    )


CFG = pruning.PruneConfig(min_trained=10)
T = jnp.asarray(True)
F = jnp.asarray(False)


def test_confidence_is_top2_gap_clamped():
    o = jnp.asarray([0.1, 0.8, 0.05, 0.02, 0.02, 0.01])
    assert np.isclose(float(pruning.confidence(o)), 0.7)
    o2 = jnp.asarray([2.0, -1.0, 0.0])  # regression outputs can exceed [0,1]
    assert float(pruning.confidence(o2)) == 1.0


def test_theta_ladder_walk():
    st_ = _state(level=0)
    assert float(pruning.theta_of(st_, CFG)) == 1.0
    st_ = _state(level=4)
    assert np.isclose(float(pruning.theta_of(st_, CFG)), 0.08)


def test_should_query_conditions():
    """All three paper conditions must hold to prune."""
    st_ = _state(level=4)  # theta = 0.08
    conf_hi = jnp.asarray([0.0, 0.9, 0.0])

    # high conf + warm + no drift -> prune (no query)
    assert not bool(pruning.should_query(st_, conf_hi, jnp.asarray(100), F, CFG))
    # cold -> query
    assert bool(pruning.should_query(st_, conf_hi, jnp.asarray(3), F, CFG))
    # drift active -> query
    assert bool(pruning.should_query(st_, conf_hi, jnp.asarray(100), T, CFG))
    # low confidence -> query
    conf_lo = jnp.asarray([0.5, 0.45, 0.0])
    assert bool(pruning.should_query(st_, conf_lo, jnp.asarray(100), F, CFG))


def test_theta_decreases_after_x_consecutive_successes():
    cfg = pruning.PruneConfig(min_trained=0, x_consec=3)
    st_ = _state(level=1)  # theta = 0.64
    hi = jnp.asarray(0.9)
    for _ in range(3):  # three skipped high-confidence samples
        st_ = pruning.update(st_, F, F, hi, cfg)
    assert int(st_.level) == 2  # theta stepped down 0.64 -> 0.32
    assert int(st_.streak) == 0


def test_theta_descends_from_startup_via_agreeing_queries():
    """At theta = 1 (startup) conf > theta is impossible (clamped), so the
    only way down is X consecutive agreeing queries — matching the paper's
    'theta is set to a high value at the startup time' then relaxed."""
    cfg = pruning.PruneConfig(min_trained=0, x_consec=3)
    st_ = _state(level=0)
    for _ in range(3):
        st_ = pruning.update(st_, T, T, jnp.asarray(0.5), cfg)
    assert int(st_.level) == 1


def test_theta_increases_on_low_conf_disagreement():
    cfg = pruning.PruneConfig(min_trained=0)
    st_ = _state(level=3, streak=5)
    st_ = pruning.update(st_, T, F, jnp.asarray(0.05), cfg)  # queried, c != t
    assert int(st_.level) == 2  # up the ladder (more conservative)
    assert int(st_.streak) == 0


def test_forced_highconf_disagreement_does_not_raise_theta():
    """Paper rule 3 applies only 'when querying (p1-p2 <= theta)': a forced
    query (warm-up/drift) with HIGH confidence that disagrees is still a
    clause-1 success."""
    cfg = pruning.PruneConfig(min_trained=0)
    st_ = _state(level=3)
    st2 = pruning.update(st_, T, F, jnp.asarray(0.99), cfg)  # conf > 0.16
    assert int(st2.level) == 3
    assert int(st2.streak) == 1


def test_agreement_on_query_counts_toward_streak():
    cfg = pruning.PruneConfig(min_trained=0, x_consec=2)
    st_ = _state(level=1)
    st_ = pruning.update(st_, T, T, jnp.asarray(0.1), cfg)  # query agrees
    st_ = pruning.update(st_, T, T, jnp.asarray(0.1), cfg)
    assert int(st_.level) == 2


def test_level_saturates_at_ladder_ends():
    cfg = pruning.PruneConfig(min_trained=0, x_consec=1)
    st_ = _state(level=4)
    st_ = pruning.update(st_, F, F, jnp.asarray(0.99), cfg)
    assert int(st_.level) == 4  # can't go below the floor
    st_ = _state(level=0)
    st_ = pruning.update(st_, T, F, jnp.asarray(0.0), cfg)
    assert int(st_.level) == 0  # can't go above the start


def test_comm_volume_fraction():
    st_ = pruning.init_state()._replace(
        queries=jnp.asarray(25, jnp.int32), skips=jnp.asarray(75, jnp.int32)
    )
    assert np.isclose(float(pruning.comm_volume_fraction(st_)), 0.25)


@settings(max_examples=25, deadline=None)
@given(
    level=st.integers(0, 4),
    queried=st.booleans(),
    agree=st.booleans(),
    conf=st.floats(0.0, 1.0),
)
def test_update_invariants(level, queried, agree, conf):
    """Property: level stays in range; counters are monotone; a step changes
    level by at most 1."""
    cfg = pruning.PruneConfig(min_trained=0)
    st_ = _state(level=level, streak=cfg.x_consec - 1)
    st2 = pruning.update(
        st_, jnp.asarray(queried), jnp.asarray(agree), jnp.asarray(conf, jnp.float32), cfg
    )
    assert 0 <= int(st2.level) <= 4
    assert abs(int(st2.level) - level) <= 1
    assert int(st2.queries) + int(st2.skips) == int(st_.queries) + int(st_.skips) + 1


def test_disabled_pruning_always_queries():
    cfg = pruning.PruneConfig(min_trained=0, enabled=False)
    st_ = _state(level=4)
    assert bool(
        pruning.should_query(st_, jnp.asarray([0.0, 1.0, 0.0]), jnp.asarray(10**6), F, cfg)
    )
