"""Fleet engine tests: batched Algorithm 1 vs the scalar shim, chunking,
kernel routing, and the serving gate/apply split."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import odl_head, oselm, pruning
from repro.kernels import ops


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=16, use_kernel=False):
    return odl_head.ODLCoreConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash",
            ridge=1e-2, use_kernel=use_kernel,
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0, shift_at=None):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    if shift_at is not None:
        # Per-stream severities so drift fires at different times per stream.
        sev = np.linspace(2.0, 4.0, s)[None, :, None]
        xs[shift_at:] = np.clip(xs[shift_at:] * sev + 0.5 * sev, -4, 4)
    ys = jax.random.randint(ky, (t, s), 0, cfg.elm.n_out)
    return jnp.asarray(xs), ys


@pytest.mark.parametrize("mode", ["algo1", "train_phase"])
def test_run_fleet_matches_independent_scalar_runs(mode):
    """(T, S) fleet == S independent scalar runs: control signals (theta
    trajectory, query decisions, drift mode, counts) must match bit-for-bit.

    beta/P are compared to 1e-3: the batched (S, n_in) matmuls round
    differently from the S = 1 shim's at f32 epsilon, and the RLS recursion
    amplifies that over T updates — the *decisions* stay identical, which is
    what the controller semantics require.
    """
    cfg = _cfg()
    t_len, s_len = 120, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=1, shift_at=60)

    fstate, fouts = engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, ys, cfg, mode=mode, chunk=40
    )

    scalar_run = odl_head.run_stream if mode == "algo1" else odl_head.run_training_phase
    for s in range(s_len):
        st, outs = scalar_run(odl_head.init_state(cfg), xs[:, s], ys[:, s], cfg)
        # Control trajectory: bit-for-bit.
        np.testing.assert_array_equal(np.asarray(outs.theta), np.asarray(fouts.theta[:, s]))
        np.testing.assert_array_equal(
            np.asarray(outs.queried), np.asarray(fouts.queried[:, s])
        )
        np.testing.assert_array_equal(
            np.asarray(outs.mode_training), np.asarray(fouts.mode_training[:, s])
        )
        # Counters: exact.
        assert int(st.prune.queries) == int(fstate.prune.queries[s])
        assert int(st.prune.skips) == int(fstate.prune.skips[s])
        assert int(st.elm.count) == int(fstate.elm.count[s])
        assert float(st.meter.total) == float(fstate.meter.total[s])
        # Weights: float tolerance (see docstring).
        np.testing.assert_allclose(
            np.asarray(st.elm.beta), np.asarray(fstate.elm.beta[s]), atol=1e-3
        )


def test_teacher_outage_is_identity_per_stream():
    """Streams with an unavailable teacher must not train or charge comms."""
    cfg = _cfg()
    t_len, s_len = 12, 4
    xs, ys = _stream_data(cfg, t_len, s_len, seed=2)
    avail = jnp.zeros((t_len, s_len), jnp.bool_).at[:, ::2].set(True)

    st0 = engine.init_fleet(cfg, s_len)
    st, outs = engine.run_fleet(
        st0, xs, ys, cfg, mode="train_phase", teacher_available=avail
    )
    dead = np.arange(s_len)[1::2]
    np.testing.assert_allclose(
        np.asarray(st.elm.beta[dead]), np.asarray(st0.elm.beta[dead]), atol=1e-6
    )
    assert not bool(outs.queried[:, dead].any())
    assert float(jnp.sum(st.meter.total[dead])) == 0.0
    assert bool(outs.queried[:, ::2].any())  # live streams did query


def test_chunk_boundaries_do_not_recompile_or_change_results():
    """Chunked run == single-dispatch run, and every same-shape chunk reuses
    one compiled executable (the donation/no-recompile smoke test)."""
    cfg = _cfg(n_hidden=8, n_in=12)
    t_len, s_len = 48, 2
    xs, ys = _stream_data(cfg, t_len, s_len, seed=3)

    engine.fleet._chunk_runner.cache_clear()
    st_a, out_a = engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, ys, cfg, mode="train_phase", chunk=12
    )
    runner = engine.fleet._chunk_runner(cfg, "train_phase", False)
    assert runner._cache_size() == 1  # 4 chunk dispatches, one executable

    # A second run with the same chunk shape must not add compilations.
    engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, ys, cfg, mode="train_phase", chunk=12
    )
    assert runner._cache_size() == 1

    st_b, out_b = engine.run_fleet(
        engine.init_fleet(cfg, s_len), xs, ys, cfg, mode="train_phase"
    )
    np.testing.assert_allclose(
        np.asarray(st_a.elm.beta), np.asarray(st_b.elm.beta), atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(out_a.queried), np.asarray(out_b.queried))


def test_fleet_kernel_matches_jnp_path():
    """use_kernel=True (batched Pallas RLS) == einsum path, per stream."""
    cfg = _cfg(n_hidden=16)
    s_len = 5
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (s_len, cfg.elm.n_in))
    y = jax.nn.one_hot(jnp.arange(s_len) % cfg.elm.n_out, cfg.elm.n_out)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])

    st = engine.init_fleet(cfg, s_len).elm
    a = oselm.fleet_rank1_update(st, x, y, cfg.elm, mask=mask, use_kernel=False)
    b = oselm.fleet_rank1_update(st, x, y, cfg.elm, mask=mask, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a.beta), np.asarray(b.beta), atol=1e-4)
    np.testing.assert_allclose(np.asarray(a.P), np.asarray(b.P), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))


def test_fleet_kernel_entry_matches_scalar_kernel():
    """oselm_rls_update_fleet == the scalar fused kernel run per stream,
    including ragged N (padding) and rank-k > 1."""
    s_len, n, k, m = 3, 20, 2, 4
    key = jax.random.PRNGKey(5)
    p0 = jnp.eye(n) * 0.5 + 0.01 * jax.random.normal(key, (s_len, n, n))
    p0 = 0.5 * (p0 + p0.transpose(0, 2, 1))
    beta = 0.1 * jax.random.normal(key, (s_len, n, m))
    h = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(6), (s_len, k, n)))
    y = jax.nn.one_hot(jnp.arange(s_len * k).reshape(s_len, k) % m, m)

    pf, bf = ops.oselm_rls_update_fleet(p0, beta, h, y)
    for s in range(s_len):
        ps, bs = ops.oselm_rls_update(p0[s], beta[s], h[s], y[s])
        np.testing.assert_allclose(np.asarray(pf[s]), np.asarray(ps), atol=1e-5)
        np.testing.assert_allclose(np.asarray(bf[s]), np.asarray(bs), atol=1e-5)


def test_gate_and_apply_labels_roundtrip():
    """Serving split: gate meters queries; apply_labels trains only the
    masked streams and leaves the rest untouched."""
    cfg = _cfg(min_trained=1_000_000)  # cold heads: everyone must query
    s_len = 4
    x = jnp.tanh(jax.random.normal(jax.random.PRNGKey(7), (s_len, cfg.elm.n_in)))
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)

    st0 = engine.init_fleet(cfg, s_len)
    st1, out = engine.gate(st0, x, cfg)
    assert bool(out.queried.all())
    np.testing.assert_allclose(
        np.asarray(st1.meter.up_bytes), np.full(s_len, cfg.elm.n_in * 4.0)
    )

    mask = jnp.asarray([True, True, False, False])
    st2 = engine.apply_labels(st1, out, labels, mask, cfg)
    np.testing.assert_array_equal(np.asarray(st2.elm.count), [1, 1, 0, 0])
    np.testing.assert_allclose(
        np.asarray(st2.elm.beta[2:]), np.asarray(st1.elm.beta[2:]), atol=1e-6
    )
    assert float(jnp.max(jnp.abs(st2.elm.beta[:2] - st1.elm.beta[:2]))) > 0


def test_broadcast_and_slice_roundtrip():
    cfg = _cfg()
    scalar = odl_head.init_state(cfg)
    fleet = engine.broadcast_streams(scalar, 3)
    back = engine.stream_slice(fleet, 1)
    for a, b in zip(jax.tree.leaves(scalar), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
