"""Snapshot wire codec tests: a full session snapshot tree encoded to one
v2 binary frame (``engine.snapshot.encode_snapshot``) and decoded back must
be bitwise identical; truncated/corrupted/mis-versioned frames must be
rejected loudly — a migration must never restore silently-corrupt state."""

import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import rpc, snapshot, stream


def _cfg():
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=24, n_hidden=16, n_out=4, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=1_000_000),
        drift=drift_mod.DriftConfig(),
    )


def _assert_trees_bitwise(a, b, path=""):
    if isinstance(a, (dict, list, tuple)):
        # Container structure must match exactly; leaves are compared as
        # arrays (a python/numpy scalar decodes as its 0-d array, exactly
        # like the np.save/np.load checkpoint path).
        assert type(a) is type(b), f"{path}: {type(a)} != {type(b)}"
    if isinstance(a, dict):
        assert sorted(a) == sorted(b), f"{path}: keys differ"
        for k in a:
            _assert_trees_bitwise(a[k], b[k], f"{path}/{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), f"{path}: length differs"
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_trees_bitwise(x, y, f"{path}[{i}]")
    else:
        xa, xb = np.asarray(a), np.asarray(b)
        assert xa.dtype == xb.dtype, f"{path}: dtype {xa.dtype} != {xb.dtype}"
        assert xa.shape == xb.shape, f"{path}: shape {xa.shape} != {xb.shape}"
        assert xa.tobytes() == xb.tobytes(), f"{path}: bytes differ"


def test_roundtrip_all_leaf_dtypes():
    """Every dtype the snapshot tree actually carries — floats, ints,
    bools, and the 0-d unicode JSON meta leaf — survives bitwise."""
    tree = {
        "meta": np.asarray('{"v": 1, "t": 17}'),  # 0-d <U17
        "f32": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        "f64": np.array([np.pi, -0.0, np.inf], np.float64),
        "i32": np.arange(-3, 3, dtype=np.int32),
        "i64": np.array([2**40, -(2**40)], np.int64),
        "u8": np.arange(256, dtype=np.uint8),
        "bool": np.array([True, False, True]),
        "scalar": np.float32(0.25),
        "nested": {"ring": [np.zeros((2, 2), np.float32),
                            (np.int32(7), np.ones(3, bool))]},
        "empty": np.zeros((0, 4), np.float32),
    }
    out = snapshot.decode_snapshot(snapshot.encode_snapshot(tree))
    _assert_trees_bitwise(tree, out)
    # NaN payloads must survive too (checksums compare bytes, not values).
    nan_tree = {"x": np.array([np.nan, 1.0], np.float32)}
    out = snapshot.decode_snapshot(snapshot.encode_snapshot(nan_tree))
    assert np.isnan(out["x"][0]) and out["x"][1] == 1.0


def test_roundtrip_real_session_snapshot():
    """A live mid-stream session's full snapshot tree (engine state,
    pending ring, teacher RNG, stats) roundtrips bitwise over the wire."""
    cfg = _cfg()
    rng = np.random.default_rng(3)
    teacher = stream.LatencyTeacher(
        label_fn=lambda tick, feats: rng.integers(
            0, 4, size=np.asarray(feats).shape[0]
        ),
        latency=2, jitter=2, loss_prob=0.2, seed=5,
    )
    sess = stream.StreamSession(
        engine.init_fleet(cfg, 4), cfg, teacher, mode="train_phase",
        capacity=4, backpressure="coalesce",
    )
    xs = np.tanh(rng.normal(size=(40, 4, 24))).astype(np.float32)
    sess.start(xs[0])
    for x in xs[1:]:
        sess.advance(x)
    tree = sess.snapshot()
    wire = snapshot.encode_snapshot(tree)
    assert isinstance(wire, bytes) and wire[0] == rpc.WIRE_V2
    _assert_trees_bitwise(tree, snapshot.decode_snapshot(wire))
    # In-flight ring state really was mid-flight (the interesting case).
    assert len(sess.ring) > 0


def test_truncated_frame_rejected():
    wire = snapshot.encode_snapshot({"a": np.arange(8, dtype=np.float32)})
    for cut in (0, 3, 5, len(wire) // 2, len(wire) - 1):
        with pytest.raises(EOFError):
            snapshot.decode_snapshot(wire[:cut])


def test_corrupt_payload_rejected_by_checksum():
    wire = bytearray(
        snapshot.encode_snapshot({"w": np.ones((4, 4), np.float32)})
    )
    wire[-1] ^= 0xFF  # flip one payload byte
    with pytest.raises(ValueError, match="checksum"):
        snapshot.decode_snapshot(bytes(wire))
    # The error names the leaf so the operator knows what rotted.
    with pytest.raises(ValueError, match="'w'"):
        snapshot.decode_snapshot(bytes(wire))


def test_corrupt_header_rejected():
    wire = bytearray(snapshot.encode_snapshot({"a": np.zeros(2, np.float32)}))
    wire[7] ^= 0xFF  # inside the JSON header
    with pytest.raises((ValueError, EOFError)):
        snapshot.decode_snapshot(bytes(wire))


def test_version_byte_mismatch_rejected():
    wire = bytearray(snapshot.encode_snapshot({"a": np.zeros(2, np.float32)}))
    wire[0] = 0x01  # v1 frame byte on a snapshot frame
    with pytest.raises(ValueError, match="version byte"):
        snapshot.decode_snapshot(bytes(wire))


def test_wrong_frame_kind_rejected():
    """A well-formed v2 frame that is not a snapshot (e.g. an RPC teacher
    frame) must be refused, not misparsed."""
    frame = rpc._encode_frame({"kind": "ask", "payload_len": 4}, b"\0\0\0\0")
    with pytest.raises(ValueError):
        snapshot.decode_snapshot(frame)
