"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement), plus a decode
step against the prefill cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.models import model as model_lib

B, S = 2, 32


def _batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "odl_labels": jax.random.randint(k, (B,), 0, cfg.odl.n_out),
    }
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(k, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = configs.get_config(arch, "smoke")
    state = model_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    state2, metrics = jax.jit(
        lambda st, b: model_lib.train_step(st, b, cfg, TrainConfig(microbatches=1))
    )(state, batch)

    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss is not finite"
    assert loss > 0
    # Params must have moved and stayed finite.
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))), state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: non-finite params"
    # The ODL head trained (paper's technique is in the step).
    assert int(state2.odl.elm.count) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_with_microbatches(arch):
    cfg = configs.get_config(arch, "smoke")
    state = model_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    _, m1 = jax.jit(
        lambda st, b: model_lib.train_step(st, b, cfg, TrainConfig(microbatches=2))
    )(state, batch)
    assert np.isfinite(float(m1["loss"]))


@pytest.mark.parametrize(
    "arch",
    [a for a in configs.ARCH_IDS if a != "whisper-small"],
)
def test_prefill_then_decode_smoke(arch):
    """Prefill a prompt, decode one token; logits finite and shaped (B, V)."""
    cfg = configs.get_config(arch, "smoke")
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    hidden, serve_state = jax.jit(
        lambda p, t: model_lib.prefill(p, t, cfg, max_len=S + 8)
    )(params, tokens)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    nxt = jnp.full((B, 1), 3, jnp.int32)
    logits, serve_state2, odl_out = jax.jit(
        lambda p, st, t: model_lib.serve_step(p, st, t, cfg)
    )(params, serve_state, nxt)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert odl_out.queried.shape == (B,)
    assert int(serve_state2.pos[0]) == S + 1


def test_whisper_prefill_decode():
    cfg = configs.get_config("whisper-small", "smoke")
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), jax.random.PRNGKey(1))
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    enc, caches = jax.jit(lambda p, f: model_lib.encdec_prefill(p, f, cfg, max_len=16))(
        params, frames
    )
    assert enc.shape == (B, S, cfg.d_model)
    from repro.models import encdec

    tok = jnp.full((B, 1), 5, jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    h, caches2 = jax.jit(lambda p, t, c, q: encdec.decode_step(p, t, c, q, cfg))(
        params, tok, caches, pos
    )
    logits = encdec.logits(params, h)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_decode_matches_prefill_dense():
    """Decode of token t must equal the prefill hidden at position t (GQA)."""
    cfg = configs.get_config("qwen3-4b", "smoke")
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    # Full forward over S tokens.
    from repro.models import transformer

    hidden_all, _ = transformer.lm_hidden(params, tokens, cfg, remat=False)

    # Prefill S-1 tokens, then decode token S-1.
    _, st = model_lib.prefill(params, tokens[:, : S - 1], cfg, max_len=S)
    logits, st2, _ = model_lib.serve_step(params, st, tokens[:, S - 1 :], cfg)
    full_logits = transformer.lm_logits(params, hidden_all, cfg)[:, -1]
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=0.15,  # bf16 accumulation over different contraction orders
        rtol=0.05,
    )


def test_long_500k_skip_policy():
    """DESIGN.md §4: exactly h2o-danube, mamba2, recurrentgemma run long_500k."""
    runnable = {
        a: [c for c in configs.cells(a) if c[0].name == "long_500k"][0][1]
        for a in configs.ARCH_IDS
    }
    assert runnable == {
        "deepseek-moe-16b": False,
        "deepseek-v2-236b": False,
        "h2o-danube-1.8b": True,
        "deepseek-coder-33b": False,
        "mistral-nemo-12b": False,
        "qwen3-4b": False,
        "mamba2-780m": True,
        "recurrentgemma-9b": True,
        "chameleon-34b": False,
        "whisper-small": False,
    }
