"""Checkpoint/restart + fault-tolerance tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import fault
from repro.runtime.checkpoint import CheckpointManager


def _tree(x=1.0):
    return {
        "params": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
        "opt": (jnp.asarray(3, jnp.int32), [jnp.ones((2,))]),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, _tree(2.5))
    step, tree = mgr.restore()
    assert step == 7
    np.testing.assert_allclose(tree["params"]["w"], 2.5)
    assert isinstance(tree["opt"], tuple)
    assert int(tree["opt"][0]) == 3


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.all_steps() == [3, 4]


def test_async_save_is_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(5, _tree(5.0))
    mgr.wait()
    step, tree = mgr.restore()
    assert step == 5
    np.testing.assert_allclose(tree["params"]["w"], 5.0)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_partial_checkpoint_is_ignored(tmp_path):
    """A crash mid-write must not corrupt restore (atomic publish)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1.0))
    # Simulate a crashed write: tmp dir exists, never renamed.
    os.makedirs(tmp_path / "step_000000002.tmp")
    (tmp_path / "step_000000002.tmp" / "garbage.npy").write_bytes(b"junk")
    assert mgr.latest_step() == 1
    step, _ = mgr.restore()
    assert step == 1


def test_crashed_tmp_with_full_contents_is_skipped(tmp_path):
    """A kill landing between the last leaf write and the atomic rename
    leaves a *complete-looking* .tmp (MANIFEST included).  latest_step must
    still fall back to the previous published step, and a later successful
    save of the same step must replace the stale staging dir."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(3, _tree(3.0))
    # Stage step 4 fully, crash before rename: copy a real checkpoint's
    # contents into the .tmp so only the missing rename distinguishes it.
    mgr.save(4, _tree(4.0))
    os.rename(tmp_path / "step_000000004", tmp_path / "step_000000004.tmp")
    assert mgr.all_steps() == [3]
    assert mgr.latest_step() == 3
    step, tree = mgr.restore()
    assert step == 3
    np.testing.assert_allclose(tree["params"]["w"], 3.0)
    # The retried save wins and clears the stale staging dir.
    mgr.save(4, _tree(4.5))
    assert mgr.latest_step() == 4
    assert not (tmp_path / "step_000000004.tmp").exists()
    np.testing.assert_allclose(mgr.restore()[1]["params"]["w"], 4.5)


def test_keep_k_gc_ignores_crashed_tmp_and_restores_explicit_step(tmp_path):
    """GC counts only *published* steps — a crashed .tmp neither consumes a
    keep slot nor gets resurrected — and restore(step=) still reaches any
    surviving published step, not just the latest."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2):
        mgr.save(s, _tree(float(s)))
    os.makedirs(tmp_path / "step_000000099.tmp")  # crashed write, never published
    mgr.save(3, _tree(3.0))  # triggers GC
    assert mgr.all_steps() == [2, 3]
    assert not (tmp_path / "step_000000001").exists()
    assert (tmp_path / "step_000000099.tmp").exists()  # GC leaves staging alone
    step, tree = mgr.restore(step=2)
    assert step == 2
    np.testing.assert_allclose(tree["params"]["w"], 2.0)
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path / "empty")).restore()


def test_save_async_overlapping_saves_serialize(tmp_path):
    """save_async waits out the previous write before snapshotting the next
    tree: back-to-back async saves must all publish, in order."""
    mgr = CheckpointManager(str(tmp_path), keep=10)
    for s in range(5):
        mgr.save_async(s, _tree(float(s)))
    mgr.wait()
    assert mgr.all_steps() == [0, 1, 2, 3, 4]
    for s in (0, 4):
        np.testing.assert_allclose(mgr.restore(step=s)[1]["params"]["w"], float(s))


def test_nan_guard_rolls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(10, _tree(1.0))
    guard = fault.NaNGuard(mgr)
    state = _tree(9.9)
    new_state, step, rolled = guard.check(11, {"loss": jnp.asarray(float("nan"))}, state)
    assert rolled and step == 10
    np.testing.assert_allclose(new_state["params"]["w"], 1.0)
    # Finite loss: no rollback.
    st2, step2, rolled2 = guard.check(12, {"loss": jnp.asarray(1.0)}, state)
    assert not rolled2 and st2 is state


def test_deadline_teacher_skips_on_outage():
    calls = {"n": 0}

    def teacher(idx, x):
        return jnp.asarray(3)

    lat = iter([0.0, 1.0, 1.0, 0.0])  # ok, slow, slow, ok

    dt = fault.DeadlineTeacher(teacher, deadline_s=0.5, max_retries=0, latency_fn=lambda: next(lat))
    out, ok = dt(0, None)
    assert ok and int(out) == 3
    out, ok = dt(1, None)
    assert not ok and out is None  # outage -> skip (paper's policy)
    assert dt.outages == 1


def test_run_with_restarts_resumes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    attempts = {"n": 0}

    def make_state():
        return _tree(0.0)

    def run(state, start_step):
        for step in range(start_step + 1, 6):
            state = jax.tree.map(lambda a: a + 1 if a.dtype != jnp.int32 else a, state)
            mgr.save(step, state)
            if step == 3 and attempts["n"] == 0:
                attempts["n"] += 1
                raise RuntimeError("simulated node failure")
        return state, 5

    state, last = fault.run_with_restarts(make_state, run, mgr, max_restarts=2)
    assert last == 5
    assert attempts["n"] == 1
    # Work after restart continued from step 3's checkpoint, not from scratch.
    np.testing.assert_allclose(state["params"]["w"], 5.0)


def test_token_stream_determinism_and_sharding():
    from repro.data.tokens import TokenStream, TokenStreamConfig

    cfg = TokenStreamConfig(vocab_size=128, seq_len=16, global_batch=8)
    a = TokenStream(cfg).batch(3)
    b = TokenStream(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # seekable/deterministic
    assert a["tokens"].shape == (8, 16)
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()

    # Host sharding: two hosts' batches differ, shapes halve.
    h0 = TokenStream(TokenStreamConfig(128, 16, 8, n_hosts=2, host=0)).batch(3)
    h1 = TokenStream(TokenStreamConfig(128, 16, 8, n_hosts=2, host=1)).batch(3)
    assert h0["tokens"].shape == (4, 16)
    assert not (h0["tokens"] == h1["tokens"]).all()


# ---------------------------------------------------------------------------
# Incremental (delta) checkpoints
# ---------------------------------------------------------------------------


def _npy_names(tmp_path, step):
    d = tmp_path / f"step_{step:09d}"
    return sorted(n for n in os.listdir(d) if n.endswith(".npy"))


def test_delta_save_writes_only_changed_leaves(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=4, full_every=4)
    t1 = _tree(1.0)
    mgr.save(1, t1)
    assert len(_npy_names(tmp_path, 1)) == 4  # first save of a process: full
    # Change just one leaf: the delta ships one file, not four.
    t2 = jax.tree.map(lambda a: a, t1)
    t2["params"]["b"] = jnp.ones((4,))
    mgr.save(2, t2)
    assert _npy_names(tmp_path, 2) == ["params__b.npy"]
    # Restore composes base+delta transparently.
    step, tree = mgr.restore()
    assert step == 2
    np.testing.assert_allclose(tree["params"]["b"], 1.0)
    np.testing.assert_allclose(tree["params"]["w"], 1.0)
    assert isinstance(tree["opt"], tuple)


def test_delta_chain_and_periodic_full(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10, full_every=3)
    tree = _tree(0.0)
    for s in range(1, 7):
        tree = jax.tree.map(
            lambda a: a + 1 if a.dtype != jnp.int32 else a, tree
        )
        mgr.save(s, tree)
    # full, delta, delta, full, delta, delta
    kinds = [mgr._manifest(s)["kind"] for s in range(1, 7)]
    assert kinds == ["full", "delta", "delta", "full", "delta", "delta"]
    for s in range(1, 7):
        step, t = mgr.restore(step=s)
        np.testing.assert_allclose(t["params"]["w"], float(s))


def test_delta_gc_protects_base_chain(tmp_path):
    """keep-k must never collect a base a kept delta still needs."""
    mgr = CheckpointManager(str(tmp_path), keep=2, full_every=10)
    tree = _tree(0.0)
    for s in range(1, 6):
        tree = jax.tree.map(
            lambda a: a + 1 if a.dtype != jnp.int32 else a, tree
        )
        mgr.save(s, tree)
    steps = mgr.all_steps()
    # The kept window is [4, 5]; their delta chains reach back through
    # every prior delta to the full at step 1, so nothing was collected.
    assert steps == [1, 2, 3, 4, 5]
    step, t = mgr.restore()
    assert step == 5
    np.testing.assert_allclose(t["params"]["w"], 5.0)


def test_delta_rewind_forces_full(tmp_path):
    """Re-saving an already-published step must not become its own base."""
    mgr = CheckpointManager(str(tmp_path), keep=3, full_every=8)
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    assert mgr._manifest(2)["kind"] == "delta"
    mgr.save(2, _tree(3.0))  # rewind/re-save
    assert mgr._manifest(2)["kind"] == "full"
    step, t = mgr.restore()
    np.testing.assert_allclose(t["params"]["w"], 3.0)


def test_unchanged_tree_delta_is_manifest_only(tmp_path):
    """The motivating case: nothing learned since the last save, so the
    cadence snapshot ships zero leaf bytes."""
    mgr = CheckpointManager(str(tmp_path), keep=4, full_every=4)
    t = _tree(1.0)
    mgr.save(1, t)
    mgr.save(2, t)
    assert _npy_names(tmp_path, 2) == []
    step, tree = mgr.restore()
    assert step == 2
    np.testing.assert_allclose(tree["params"]["w"], 1.0)
