"""Explicit-EP MoE (shard_map + all-to-all) == dense pjit MoE (subprocess)."""

import os
import subprocess
import sys
import textwrap

ENV = dict(
    os.environ,
    XLA_FLAGS="--xla_force_host_platform_device_count=8",
    PYTHONPATH="src",
    JAX_PLATFORMS="cpu",
)


def test_ep_matches_dense():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.distributed import sharding
    from repro.launch.mesh import make_dev_mesh
    from repro.models import model as M, moe

    # Generous capacity so neither impl drops tokens -> outputs must match
    # up to routing-order float noise.
    cfg = configs.get_config('deepseek-moe-16b', 'smoke').replace(
        capacity_factor=4.0, n_experts=8)
    params = M.layers.init_params(M.build_schema(cfg), jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params['layers'])['moe']
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)

    y_dense, aux_dense = moe._moe_block_dense(x, lp, cfg)

    mesh = make_dev_mesh(2, 4)
    with sharding.activate(mesh):
        y_ep, aux_ep = jax.jit(lambda xx, pp: moe.moe_block_ep(xx, pp, cfg, mesh))(x, lp)

    d = np.abs(np.asarray(y_dense, np.float32) - np.asarray(y_ep, np.float32))
    assert (d < 5e-2).mean() > 0.98, f'mismatch frac {(d >= 5e-2).mean():.3f}'
    assert np.median(d) < 5e-3
    np.testing.assert_allclose(float(aux_dense), float(aux_ep), rtol=0.05)
    print('OK')
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=ENV, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"


def test_ep_train_step_runs_sharded():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.configs.base import TrainConfig
    from repro.distributed import sharding
    from repro.launch.mesh import make_dev_mesh
    from repro.models import model as M

    cfg = configs.get_config('deepseek-moe-16b', 'smoke').replace(moe_impl='ep')
    state = M.init_train_state(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {
        'tokens': jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        'labels': jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        'odl_labels': jnp.zeros((8,), jnp.int32),
    }
    mesh = make_dev_mesh(2, 4)
    with sharding.activate(mesh):
        st2, m = jax.jit(lambda s, b: M.train_step(s, b, cfg, TrainConfig(remat=False)))(state, batch)
    assert np.isfinite(float(m['loss']))
    for leaf in jax.tree.leaves(st2.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    print('OK')
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=ENV, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
