"""HAR surrogate dataset properties (paper §3 protocol invariants)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only the @given tests skip
    from _hypothesis_stub import given, settings, st

from repro.data import har


@pytest.fixture(scope="module")
def splits():
    return har.generate(seed=0)


def test_shapes_and_ranges(splits):
    for x in (splits.train_x, splits.test0_x, splits.test1_x):
        assert x.shape[1] == har.N_FEATURES == 561
        assert np.abs(x).max() <= 1.0  # tanh-bounded like the real dataset
    for y in (splits.train_y, splits.test0_y, splits.test1_y):
        assert set(np.unique(y)) <= set(range(6))


def test_drift_split_is_disjoint_and_exact(splits):
    """test1 = exactly the 5 held-out subjects' samples (paper protocol)."""
    n_total = 30 * 6 * 56
    assert len(splits.train_x) + len(splits.test0_x) + len(splits.test1_x) == n_total
    assert len(splits.test1_x) == 5 * 6 * 56  # subjects {9,14,16,19,25}


def test_all_classes_present_in_every_split(splits):
    for y in (splits.train_y, splits.test0_y, splits.test1_y):
        assert len(np.unique(y)) == 6


def test_generation_deterministic():
    a = har.generate(seed=3)
    b = har.generate(seed=3)
    np.testing.assert_array_equal(a.train_x, b.train_x)
    np.testing.assert_array_equal(a.test1_y, b.test1_y)


def test_odl_split_stream_has_bouts(splits):
    """The retraining stream must be temporally coherent (activity bouts) —
    the property that makes auto-theta streaks attainable (DESIGN.md §5)."""
    ox, oy, tx, ty = har.odl_split(splits, 0.6, seed=0, bout_len=70)
    runs = np.diff(oy) != 0
    n_runs = 1 + int(runs.sum())
    avg_run = len(oy) / n_runs
    assert avg_run > 20  # bouts, not i.i.d. shuffle (expected ~70)
    # Split sizes: 60/40.
    assert abs(len(ox) - 0.6 * len(splits.test1_x)) < 2
    assert len(ox) + len(tx) == len(splits.test1_x)


def test_odl_split_partition_is_exact(splits):
    """Stream + holdout partition test1 exactly (no leakage)."""
    ox, oy, tx, ty = har.odl_split(splits, 0.6, seed=1)
    joined = np.concatenate([ox, tx])
    assert joined.shape == splits.test1_x.shape
    # Same multiset of rows (sort by a hash of each row).
    h1 = np.sort((joined * 1000).sum(axis=1))
    h2 = np.sort((splits.test1_x * 1000).sum(axis=1))
    np.testing.assert_allclose(h1, h2, atol=1e-3)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 100))
def test_drifted_subjects_are_out_of_distribution(seed):
    """Property: held-out subjects sit measurably farther from the train
    centroid than in-distribution test0 (the drift is real)."""
    s = har.generate(seed=seed)
    mu = s.train_x.mean(axis=0)
    d0 = np.linalg.norm(s.test0_x - mu, axis=1).mean()
    d1 = np.linalg.norm(s.test1_x - mu, axis=1).mean()
    assert d1 > d0 * 1.02
