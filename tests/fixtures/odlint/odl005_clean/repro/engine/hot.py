"""ODL005 clean fixture: clock passed in, typed except, no stdout."""

import socket

import jax


@jax.jit
def plan(state, x, now):
    return state + x, now


def serve(conn: socket.socket):
    try:
        conn.sendall(b"ok")
    except OSError:
        pass
