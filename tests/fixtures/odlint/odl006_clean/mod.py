"""ODL006 clean fixture: shard-local work sits under deactivate()."""

from repro.distributed import sharding


# odlint: shard-local
def advance_shard(session, x):
    return session.step(x)


def run(mesh, sessions, xs):
    with sharding.activate(mesh):
        with sharding.deactivate():
            for sess, x in zip(sessions, xs):
                advance_shard(sess, x)
