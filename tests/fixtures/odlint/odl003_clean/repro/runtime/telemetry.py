"""Mirror side of the ODL003 clean fixture."""

STREAM_COUNTER_FIELDS = ("ticks", "queries_issued")

STREAM_GAUGE_FIELDS = ()

STREAM_MIRROR_EXCLUDED = ("wall_s",)
