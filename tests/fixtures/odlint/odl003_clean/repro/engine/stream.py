"""ODL003 clean fixture: every field mirrored or explicitly excluded."""

import dataclasses


@dataclasses.dataclass
class StreamStats:
    ticks: int = 0
    queries_issued: int = 0
    wall_s: float = 0.0
