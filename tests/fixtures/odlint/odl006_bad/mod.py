"""ODL006 firing fixture: shard-local dispatch under an active mesh."""

from repro.distributed import sharding


# odlint: shard-local
def advance_shard(session, x):
    return session.step(x)


def run(mesh, sessions, xs):
    with sharding.activate(mesh):
        for sess, x in zip(sessions, xs):
            advance_shard(sess, x)  # inherits the mesh scope: constraint leak
