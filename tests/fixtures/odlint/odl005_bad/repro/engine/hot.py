"""ODL005 firing fixture: clock in a jitted fn, bare except, engine print."""

import socket
import time

import jax


@jax.jit
def plan(state, x):
    t0 = time.time()  # frozen at trace time — every call sees the same t0
    return state + x, t0


def serve(conn: socket.socket):
    try:
        conn.sendall(b"ok")
    except:  # swallows KeyboardInterrupt on the serving thread
        pass
    print("served")  # library code talking to stdout
