"""ODL004 firing fixture: the client sends a kind the worker never handles."""


class WorkerClient:
    def _request(self, header, payload=b""):
        return header, payload

    def status(self):
        return self._request({"kind": "status"})

    def pause(self):
        # no worker branch handles "pause" — fails on first use
        return self._request({"kind": "pause"})
