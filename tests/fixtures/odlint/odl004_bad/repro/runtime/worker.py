"""Handler side of the ODL004 firing fixture."""


class Worker:
    def _handle(self, header, payload):
        cmd = header.get("kind")
        if cmd == "status":
            return {"kind": "status_ok"}, b""
        return {"kind": "error"}, b""
