"""ODL000 clean fixture: reasons make suppressions auditable."""


def f():
    # odlint: disable=ODL005 -- demo CLI output, not library code
    print("suppressed with a reason")
