"""ODL004 clean fixture: sent and handled kinds agree exactly."""


class WorkerClient:
    def _request(self, header, payload=b""):
        return header, payload

    def status(self):
        return self._request({"kind": "status"})

    def pause(self):
        return self._request({"kind": "pause"})
