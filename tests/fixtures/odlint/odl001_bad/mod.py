"""ODL001 firing fixture: counter written with and without its lock."""

import threading


class Meter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def reset(self):
        self.count = 0  # unguarded write: lost-update race with bump()
