"""ODL000 firing fixture: a suppression with no reason is a finding."""


def f():
    # odlint: disable=ODL005
    print("suppressed without a reason")
