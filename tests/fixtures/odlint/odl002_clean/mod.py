"""ODL002 clean fixture: the donated name is rebound by the same call."""

import jax


def _step_runner(cfg):
    def step(state, x):
        return state + x

    return jax.jit(step, donate_argnums=(0,))


def run(state, xs, cfg):
    step = _step_runner(cfg)
    for x in xs:
        state = step(state, x)  # rebinding revives the name
    return state
