"""ODL002 firing fixture: reading a buffer after donating it."""

import jax


def _step_runner(cfg):
    def step(state, x):
        return state + x

    return jax.jit(step, donate_argnums=(0,))


def run(state, xs, cfg):
    step = _step_runner(cfg)
    for x in xs:
        new_state = step(state, x)
        print(state.sum())  # state's buffer was donated to step()
        state = new_state
    return state
