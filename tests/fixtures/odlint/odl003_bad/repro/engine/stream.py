"""ODL003 firing fixture: a StreamStats counter the mirror never learned."""

import dataclasses


@dataclasses.dataclass
class StreamStats:
    ticks: int = 0
    queries_issued: int = 0
    queries_forgotten: int = 0  # new counter, never mirrored or excluded
