"""Mirror side of the ODL003 firing fixture."""

STREAM_COUNTER_FIELDS = ("ticks", "queries_issued")

STREAM_GAUGE_FIELDS = ()

STREAM_MIRROR_EXCLUDED = ()
