"""ODL001 clean fixture: every write holds the lock (or is annotated)."""

import threading


class Meter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def reset(self):
        with self._lock:
            self.count = 0

    def _drain_locked(self):  # odlint: holds-lock(_lock)
        self.count = 0
