"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes (aligned + ragged) and dtypes per the repo convention: every
kernel asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.oselm_update import oselm_rls_update
from repro.kernels.xorshift_proj import xorshift_projection


# ---------------------------------------------------------------------------
# xorshift_projection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,n_in,n_hidden",
    [
        (8, 128, 128),  # exactly one tile
        (8, 256, 384),  # multi-tile K and N
        (3, 561, 128),  # the paper's HAR shape (ragged K, ragged B)
        (130, 100, 72),  # everything ragged
        (1, 16, 16),  # tiny
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xorshift_projection_matches_ref(b, n_in, n_hidden, dtype):
    x = jax.random.normal(jax.random.PRNGKey(b * 7 + n_in), (b, n_in)).astype(dtype)
    got = xorshift_projection(x, seed=0x2D2A, n_hidden=n_hidden, interpret=True)
    want = ref.xorshift_projection_ref(x, 0x2D2A, n_hidden)
    np.testing.assert_allclose(got, want, atol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("activation", ["sigmoid", "relu", "identity"])
def test_xorshift_projection_activations(activation):
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 96))
    got = xorshift_projection(x, 7, 64, activation=activation, interpret=True)
    want = ref.xorshift_projection_ref(x, 7, 64, activation=activation)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_xorshift_projection_tile_independence():
    """Different tile sizes must give bit-identical alpha (counter-based)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 200))
    a = xorshift_projection(x, 3, 160, tb=8, tn=32, tk=64, interpret=True)
    b = xorshift_projection(x, 3, 160, tb=16, tn=128, tk=128, interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_xorshift_projection_scale():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    got = xorshift_projection(x, 11, 32, scale=0.5, interpret=True)
    want = ref.xorshift_projection_ref(x, 11, 32, scale=0.5)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_ops_wrapper_handles_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 48))
    got = ops.xorshift_projection(x, 5, 32)
    want = ref.xorshift_projection_ref(x, 5, 32)
    assert got.shape == (2, 5, 32)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# oselm_rls_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,k,m",
    [
        (128, 1, 6),  # paper serving shape: rank-1, HAR head
        (128, 8, 6),  # rank-k batch
        (256, 32, 6),
        (200, 4, 10),  # ragged N
        (64, 64, 3),  # k == tile
    ],
)
def test_oselm_rls_update_matches_ref(n, k, m):
    key = jax.random.PRNGKey(n + k)
    k1, k2, k3 = jax.random.split(key, 3)
    # Build a genuine SPD P (inverse Gram of random features + ridge).
    f = jax.random.normal(k1, (3 * n, n)) / np.sqrt(n)
    P = jnp.linalg.inv(f.T @ f + 0.1 * jnp.eye(n))
    beta = jax.random.normal(k2, (n, m)) * 0.1
    H = jax.nn.sigmoid(jax.random.normal(k3, (k, n)))
    Y = jax.nn.one_hot(jax.random.randint(key, (k,), 0, m), m)

    p_got, b_got = oselm_rls_update(P, beta, H, Y, interpret=True)
    p_want, b_want = ref.oselm_rls_update_ref(P, beta, H, Y)
    np.testing.assert_allclose(p_got, p_want, atol=2e-5)
    np.testing.assert_allclose(b_got, b_want, atol=2e-4)


def test_oselm_rls_update_tile_sweep():
    """Tile size must not change the result."""
    n, k, m = 96, 4, 6
    key = jax.random.PRNGKey(9)
    f = jax.random.normal(key, (2 * n, n)) / np.sqrt(n)
    P = jnp.linalg.inv(f.T @ f + 0.1 * jnp.eye(n))
    beta = jnp.zeros((n, m))
    H = jax.nn.sigmoid(jax.random.normal(key, (k, n)))
    Y = jax.nn.one_hot(jnp.arange(k) % m, m)
    outs = [
        oselm_rls_update(P, beta, H, Y, tn=tn, interpret=True) for tn in (32, 48, 128)
    ]
    for p2, b2 in outs[1:]:
        np.testing.assert_allclose(outs[0][0], p2, atol=1e-5)
        np.testing.assert_allclose(outs[0][1], b2, atol=1e-5)


def test_kernel_path_equals_oselm_module():
    """oselm.sequential_update(use_kernel=True) == pure-jnp module path."""
    from repro.core import oselm

    cfg = oselm.OSELMConfig(n_in=48, n_hidden=64, n_out=5, variant="hash", seed=3)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 48))
    y = jax.nn.one_hot(jnp.arange(8) % 5, 5)
    st0 = oselm.init_state(cfg)
    st_jnp = oselm.sequential_update(st0, x, y, cfg)
    st_krn = oselm.sequential_update(st0, x, y, cfg, use_kernel=True)
    # P starts at I/ridge = 100*I: values ~1e2 with heavy cancellation, so
    # compare relatively (f32 accumulation order differs between paths).
    np.testing.assert_allclose(st_krn.P, st_jnp.P, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_krn.beta, st_jnp.beta, rtol=2e-3, atol=2e-3)


def test_fused_head_composition():
    """Projection kernel + RLS kernel == fused oracle."""
    n_in, n, m, k = 100, 64, 6, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (k, n_in))
    f = jax.random.normal(jax.random.PRNGKey(2), (2 * n, n)) / np.sqrt(n)
    P = jnp.linalg.inv(f.T @ f + 0.1 * jnp.eye(n))
    beta = jnp.zeros((n, m))
    Y = jax.nn.one_hot(jnp.arange(k) % m, m)

    h = xorshift_projection(x, 5, n, interpret=True)
    p_got, b_got = oselm_rls_update(P, beta, h, Y, interpret=True)
    h_want, p_want, b_want = ref.fused_elm_head_ref(x, P, beta, Y, 5)
    np.testing.assert_allclose(h, h_want, atol=1e-5)
    np.testing.assert_allclose(p_got, p_want, atol=2e-5)
    np.testing.assert_allclose(b_got, b_want, atol=2e-4)
