"""Durable sessions (ISSUE 4): bit-for-bit snapshot/restore parity for every
backpressure policy, kill-mid-write recovery, crash-restart supervision, and
live tenant migration with the query-accounting identity intact."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import durable, multiplex, snapshot, stream
from repro.runtime.checkpoint import CheckpointManager

# Deterministic stats fields a resumed run must reproduce exactly (the
# wall-clock ones — wall_s, tick_ms — obviously cannot match).
DETERMINISTIC_STATS = (
    "ticks", "stream_steps", "tickets_issued", "queries_issued",
    "labels_applied", "tickets_dropped", "queries_dropped",
    "replies_orphaned", "tickets_lost", "queries_lost",
    "tickets_coalesced", "queries_coalesced", "asks_deferred",
)


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=1_000_000):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return xs, ys


def _assert_state_equal(a, b, msg=""):
    for (path, la), (_, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=f"{msg} leaf {path} diverged"
        )


def _assert_stats_equal(a, b, msg=""):
    for f in DETERMINISTIC_STATS:
        assert getattr(a, f) == getattr(b, f), (
            f"{msg}: stats.{f} diverged: {getattr(a, f)} != {getattr(b, f)}"
        )
    assert list(a.label_latency_ticks) == list(b.label_latency_ticks), msg


def _lossy_teacher(ys):
    return stream.LatencyTeacher(
        stream.array_labels(ys), latency=2, jitter=3, loss_prob=0.2,
        partial_prob=0.2, seed=11,
    )


def _drive(sess, xs, start):
    """The stream.run drive loop from tick ``start`` (resume-aware)."""
    it = (xs[i] for i in range(start, len(xs)))
    if not sess.started():
        x0 = next(it, None)
        if x0 is not None:
            sess.start(x0)
    while sess._p is not None:
        sess.advance(next(it, None))
    return sess.finish()


# ---------------------------------------------------------------------------
# Tentpole: bit-for-bit resume parity, every backpressure policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", stream.BACKPRESSURE_POLICIES)
def test_resume_parity_bit_for_bit(policy, tmp_path):
    """A session snapshotted at tick k, published through CheckpointManager,
    and restored into a fresh session + fresh (state-restored) teacher must
    reproduce the uninterrupted run's final EngineState, outputs, and
    deterministic stats exactly — under latency + jitter + loss + partial
    answers, for every backpressure policy."""
    cfg = _cfg()
    t_len, s_len, k = 40, 4, 17
    xs, ys = _stream_data(cfg, t_len, s_len, seed=7)

    ref_state, ref_outs, ref_stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, _lossy_teacher(ys),
        mode="train_phase", capacity=3, backpressure=policy,
    )

    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg, _lossy_teacher(ys),
        mode="train_phase", capacity=3, backpressure=policy,
    )
    it = iter(xs)
    sess.start(next(it))
    for _ in range(k):
        sess.advance(next(it, None))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(sess.t, sess.snapshot())
    consumed = snapshot.ticks_consumed(mgr.restore()[1])
    assert consumed == k + 1
    del sess, it  # the "crashed process"

    step, tree = mgr.restore()
    assert step == k
    fresh_teacher = _lossy_teacher(ys)  # state overwritten by the restore
    sess2 = stream.StreamSession.restore(tree, fresh_teacher, cfg=cfg)
    st2, outs2, stats2 = _drive(sess2, xs, consumed)

    _assert_state_equal(ref_state, st2, msg=policy)
    _assert_stats_equal(ref_stats, stats2, msg=policy)
    assert stats2.reconciled, stats2.summary()
    assert stats2.tickets_reasked == 0  # the teacher state came along
    for name in ref_outs._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref_outs, name)),
            np.asarray(getattr(outs2, name)),
            err_msg=f"{policy}: output {name!r} diverged",
        )


def test_restore_without_teacher_state_reasks_in_flight(tmp_path):
    """A teacher that cannot be snapshot (sockets): restore re-asks every
    in-flight ring entry through the fresh teacher — metered, original
    ticket order preserved, and the accounting identity still reconciles."""
    cfg = _cfg()
    t_len, s_len = 12, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=8)

    class NoSnapshotTeacher:
        """LatencyTeacher minus the snapshot support."""

        def __init__(self):
            self.inner = stream.LatencyTeacher(stream.array_labels(ys), latency=4)

        def ask(self, feats, mask, tick):
            return self.inner.ask(feats, mask, tick)

        def poll(self, tick):
            return self.inner.poll(tick)

        def in_flight(self):
            return self.inner.in_flight()

    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg, NoSnapshotTeacher(),
        mode="train_phase", capacity=8,
    )
    it = iter(xs)
    sess.start(next(it))
    for _ in range(5):
        sess.advance(next(it, None))
    tree = sess.snapshot()
    in_flight = len(sess.ring)
    assert in_flight > 0  # latency 4 > ticks run: queries still pending
    issued_before = sess.stats.tickets_issued

    fresh = NoSnapshotTeacher()
    sess2 = stream.StreamSession.restore(tree, fresh, cfg=cfg)
    assert sess2.stats.tickets_reasked == in_flight
    assert sess2.stats.tickets_issued == issued_before + in_flight
    assert fresh.in_flight() == in_flight  # the re-asks actually hit the wire
    st2, outs2, stats2 = _drive(sess2, xs, snapshot.ticks_consumed(tree))
    assert stats2.reconciled, stats2.summary()
    assert stats2.labels_applied == stats2.queries_issued == t_len * s_len
    assert outs2.trained.all()  # every re-asked query eventually trained

    # pending="drop": the in-flight queries become terminal losses instead.
    sess3 = stream.StreamSession.restore(
        tree, NoSnapshotTeacher(), cfg=cfg, pending="drop"
    )
    assert sess3.stats.tickets_reasked == 0
    assert sess3.stats.queries_lost >= in_flight
    st3, _, stats3 = _drive(sess3, xs, snapshot.ticks_consumed(tree))
    assert stats3.reconciled, stats3.summary()


def test_kill_mid_write_recovers_previous_good_snapshot(tmp_path):
    """A crash mid-snapshot-write leaves a .tmp staging dir; restore must
    fall back to the previous published step and resume losslessly."""
    cfg = _cfg()
    t_len, s_len = 30, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=9)

    ref_state, _, ref_stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, _lossy_teacher(ys),
        mode="train_phase", capacity=4,
    )

    sess = stream.StreamSession(
        engine.init_fleet(cfg, s_len), cfg, _lossy_teacher(ys),
        mode="train_phase", capacity=4,
    )
    it = iter(xs)
    sess.start(next(it))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for _ in range(10):
        sess.advance(next(it, None))
    mgr.save(sess.t, sess.snapshot())  # the good snapshot (tick 10)
    for _ in range(5):
        sess.advance(next(it, None))
    # The crashed write: a later step staged but never atomically renamed.
    crashed = tmp_path / "step_000000015.tmp"
    os.makedirs(crashed)
    (crashed / "MANIFEST.json").write_text("{\"step\": 15}")
    (crashed / "meta.npy").write_bytes(b"truncated garbage")
    del sess

    assert mgr.latest_step() == 10  # the .tmp is invisible
    step, tree = mgr.restore()
    sess2 = stream.StreamSession.restore(tree, _lossy_teacher(ys), cfg=cfg)
    st2, _, stats2 = _drive(sess2, xs, snapshot.ticks_consumed(tree))
    _assert_state_equal(ref_state, st2, msg="kill-mid-write")
    _assert_stats_equal(ref_stats, stats2, msg="kill-mid-write")
    assert stats2.reconciled


# ---------------------------------------------------------------------------
# Multiplexer durability: cadence snapshots, resume, supervision
# ---------------------------------------------------------------------------


def _tenants(cfg, datas, make_teacher, **kw):
    return [
        multiplex.Tenant(
            name=f"tenant{i}",
            state=engine.init_fleet(cfg, xs.shape[1]),
            ticks=snapshot.array_ticks(xs),
            cfg=cfg,
            teacher=make_teacher(i),
            mode="train_phase",
            capacity=4,
            collect=False,
            **kw,
        )
        for i, (xs, ys) in enumerate(datas)
    ]


def test_multiplex_resume_matches_uninterrupted(tmp_path):
    """Kill the multiplexer after some rounds; a resumed run restores every
    tenant from its latest published snapshot and finishes with exactly the
    states an uninterrupted multiplexed run produces."""
    cfg = _cfg()
    datas = [_stream_data(cfg, 40, 3, seed=20), _stream_data(cfg, 30, 2, seed=21)]

    def make_teacher(i, datas=datas):
        return stream.LatencyTeacher(
            stream.array_labels(datas[i][1]), latency=2, jitter=2,
            loss_prob=0.2, seed=30 + i,
        )

    ref, _ = multiplex.run(_tenants(cfg, datas, make_teacher))

    snap_dir = str(tmp_path / "snaps")
    mux = multiplex.Multiplexer(
        _tenants(cfg, datas, make_teacher),
        snapshot_dir=snap_dir, snapshot_every=6,
    )
    for _ in range(4):  # run a few rounds, then "crash" (abandon the object)
        mux.round()
    for name in ("tenant0", "tenant1"):
        latest = CheckpointManager(os.path.join(snap_dir, name)).latest_step()
        assert latest is not None and latest > 0, name
    del mux

    results, agg = multiplex.run(
        _tenants(cfg, datas, make_teacher),
        snapshot_dir=snap_dir, snapshot_every=6, resume=True,
    )
    for name in ref:
        _assert_state_equal(ref[name].state, results[name].state, msg=name)
        _assert_stats_equal(ref[name].stats, results[name].stats, msg=name)
        assert results[name].stats.reconciled
    assert agg.snapshots > 0


def test_run_supervised_crash_restart(tmp_path):
    """The fault.run_with_restarts supervisor around the durable
    multiplexer: an injected mid-run crash restarts the attempt, which
    resumes from the published snapshots and still matches the
    uninterrupted run bit-for-bit."""
    cfg = _cfg()
    datas = [_stream_data(cfg, 36, 3, seed=22), _stream_data(cfg, 24, 2, seed=23)]
    crash = {"armed": True}

    class CrashingTeacher:
        """Delegates to a LatencyTeacher; raises once at tick >= 20."""

        def __init__(self, i):
            self.inner = stream.LatencyTeacher(
                stream.array_labels(datas[i][1]), latency=1, jitter=1,
                loss_prob=0.1, seed=40 + i,
            )

        def ask(self, feats, mask, tick):
            if crash["armed"] and tick >= 20:
                crash["armed"] = False
                raise RuntimeError("injected node failure")
            return self.inner.ask(feats, mask, tick)

        def poll(self, tick):
            return self.inner.poll(tick)

        def in_flight(self):
            return self.inner.in_flight()

        def snapshot_state(self):
            return self.inner.snapshot_state()

        def restore_snapshot(self, tree):
            self.inner.restore_snapshot(tree)

    def make_plain(i):
        return stream.LatencyTeacher(
            stream.array_labels(datas[i][1]), latency=1, jitter=1,
            loss_prob=0.1, seed=40 + i,
        )

    ref, _ = multiplex.run(_tenants(cfg, datas, make_plain))

    results, agg = multiplex.run_supervised(
        lambda: _tenants(cfg, datas, CrashingTeacher),
        snapshot_dir=str(tmp_path / "snaps"),
        snapshot_every=5,
        max_restarts=2,
    )
    assert not crash["armed"]  # the crash really fired
    for name in ref:
        _assert_state_equal(ref[name].state, results[name].state, msg=name)
        _assert_stats_equal(ref[name].stats, results[name].stats, msg=name)
        assert results[name].stats.reconciled


# ---------------------------------------------------------------------------
# Live tenant migration
# ---------------------------------------------------------------------------


def test_live_migration_preserves_accounting_identity(tmp_path):
    """Quiesce → snapshot → extract a tenant mid-stream and restore it into
    a second multiplexer behind a FRESH teacher (quiesce disabled so
    in-flight tickets must be re-asked): the migrated tenant completes and
    the accounting identity reconciles across the move; the tenant left
    behind is untouched (bit-for-bit vs its solo run)."""
    cfg = _cfg()
    datas = [_stream_data(cfg, 30, 3, seed=24), _stream_data(cfg, 30, 2, seed=25)]

    def make_teacher(i):
        return stream.LatencyTeacher(
            stream.array_labels(datas[i][1]), latency=3, seed=50 + i
        )

    solo1_state, _, solo1_stats = stream.run(
        engine.init_fleet(cfg, 2), (x for x in datas[1][0]), cfg,
        make_teacher(1), mode="train_phase", capacity=4, collect=False,
    )

    mux = multiplex.Multiplexer(_tenants(cfg, datas, make_teacher))
    while mux.round():
        if mux.session("tenant0").t >= 15:
            break
    # quiesce_ticks=0: leave the in-flight tickets pending so the restore
    # MUST re-ask them through the new teacher.
    tree, rest_ticks = mux.extract("tenant0", quiesce_ticks=0)
    in_flight = len(tree["ring"])
    assert in_flight > 0
    results_a, _ = mux.run()

    # pending="reask": the new host's teacher starts fresh even though a
    # LatencyTeacher could technically restore — this is the
    # migrated-to-a-different-teacher path, so in-flight tickets re-ask.
    mux_b = multiplex.Multiplexer([], pending="reask")
    fresh = stream.LatencyTeacher(
        stream.array_labels(datas[0][1]), latency=3, seed=99
    )
    mux_b.admit(
        multiplex.Tenant(
            name="tenant0", state=None, ticks=rest_ticks, cfg=cfg,
            teacher=fresh, mode="train_phase", capacity=4, collect=False,
        ),
        snapshot=tree,
    )
    results_b, _ = mux_b.run()

    mig = results_b["tenant0"].stats
    assert mig.ticks == 30
    assert mig.tickets_reasked == in_flight
    assert mig.queries_issued == 30 * 3
    assert mig.reconciled, mig.summary()
    # The stay-behind tenant is oblivious to the migration.
    _assert_state_equal(solo1_state, results_a["tenant1"].state, msg="tenant1")
    _assert_stats_equal(solo1_stats, results_a["tenant1"].stats, msg="tenant1")


def test_migration_with_restorable_teacher_is_bit_for_bit(tmp_path):
    """When the destination teacher CAN restore the snapshot state (same
    LatencyTeacher semantics), migration is invisible: the migrated tenant
    finishes exactly like an unmigrated multiplexed/solo run."""
    cfg = _cfg()
    t_len, s_len = 30, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=26)

    def make_teacher():
        return stream.LatencyTeacher(
            stream.array_labels(ys), latency=2, jitter=2, loss_prob=0.2, seed=60
        )

    ref_state, _, ref_stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, make_teacher(),
        mode="train_phase", capacity=4, collect=False,
    )

    mux = multiplex.Multiplexer([
        multiplex.Tenant(
            name="t", state=engine.init_fleet(cfg, s_len),
            ticks=snapshot.array_ticks(xs), cfg=cfg, teacher=make_teacher(),
            mode="train_phase", capacity=4, collect=False,
        )
    ])
    while mux.round():
        if mux.session("t").t >= 13:
            break
    tree, rest = mux.extract("t", quiesce_ticks=0)

    mux_b = multiplex.Multiplexer([])
    mux_b.admit(
        multiplex.Tenant(
            name="t", state=None, ticks=rest, cfg=cfg, teacher=make_teacher(),
            mode="train_phase", capacity=4, collect=False,
        ),
        snapshot=tree,
    )
    results, _ = mux_b.run()
    _assert_state_equal(ref_state, results["t"].state, msg="migrated")
    _assert_stats_equal(ref_stats, results["t"].stats, msg="migrated")
    assert results["t"].stats.tickets_reasked == 0


# ---------------------------------------------------------------------------
# Durable single-session driver + misc contracts
# ---------------------------------------------------------------------------


def test_run_durable_resume_parity(tmp_path):
    """durable.run_durable: run to completion once; then run with a tick
    budget cut short (simulated crash via a truncated source), resume, and
    match the full run bit-for-bit."""
    cfg = _cfg()
    t_len, s_len = 32, 3
    xs, ys = _stream_data(cfg, t_len, s_len, seed=27)

    def teacher():
        return stream.LatencyTeacher(
            stream.array_labels(ys), latency=1, jitter=2, loss_prob=0.1, seed=70
        )

    ref_state, ref_outs, ref_stats = stream.run(
        engine.init_fleet(cfg, s_len), (x for x in xs), cfg, teacher(),
        mode="train_phase", capacity=4,
    )

    d = str(tmp_path / "snaps")
    # "Crashed" first run: the source dies at tick 19 (mid-stream) — the
    # exception fires after several snapshots were published.
    def dying(start):
        for t in range(start, t_len):
            if t == 19:
                raise RuntimeError("simulated ingest crash")
            yield xs[t]

    with pytest.raises(RuntimeError, match="ingest crash"):
        durable.run_durable(
            engine.init_fleet(cfg, s_len), snapshot.ResumableTicks(dying),
            cfg, teacher(), snapshot_dir=d, snapshot_every=5,
            mode="train_phase", capacity=4,
        )
    mgr = CheckpointManager(d)
    assert (mgr.latest_step() or 0) >= 5

    st2, outs2, stats2 = durable.run_durable(
        None, snapshot.array_ticks(xs), cfg, teacher(),
        snapshot_dir=d, snapshot_every=5, resume=True,
        mode="train_phase", capacity=4,
    )
    _assert_state_equal(ref_state, st2, msg="run_durable")
    _assert_stats_equal(ref_stats, stats2, msg="run_durable")
    for name in ref_outs._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref_outs, name)),
            np.asarray(getattr(outs2, name)),
            err_msg=f"run_durable output {name!r}",
        )


def test_resume_requires_seekable_ticks(tmp_path):
    cfg = _cfg()
    xs, ys = _stream_data(cfg, 8, 2, seed=28)
    d = str(tmp_path / "snaps")
    durable.run_durable(
        engine.init_fleet(cfg, 2), snapshot.array_ticks(xs), cfg,
        stream.LatencyTeacher(stream.array_labels(ys), latency=0),
        snapshot_dir=d, snapshot_every=3, mode="train_phase",
    )
    with pytest.raises(ValueError, match="seekable"):
        durable.run_durable(
            None, (x for x in xs), cfg,
            stream.LatencyTeacher(stream.array_labels(ys), latency=0),
            snapshot_dir=d, snapshot_every=3, resume=True, mode="train_phase",
        )


def test_snapshot_contract_validation():
    cfg = _cfg()
    xs, ys = _stream_data(cfg, 4, 2, seed=29)
    sess = stream.StreamSession(
        engine.init_fleet(cfg, 2), cfg,
        stream.LatencyTeacher(stream.array_labels(ys), latency=0),
        mode="train_phase",
    )
    it = iter(xs)
    sess.start(next(it))
    sess.advance(next(it))
    tree = sess.snapshot()
    with pytest.raises(ValueError, match="pending"):
        stream.StreamSession.restore(
            tree, stream.LatencyTeacher(stream.array_labels(ys)), pending="yolo"
        )
    # Snapshotting a finished session is meaningless and refused.
    while sess._p is not None:
        sess.advance(next(it, None))
    sess.finish()
    with pytest.raises(RuntimeError, match="finished"):
        sess.snapshot()
