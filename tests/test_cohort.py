"""Cohort fusion — ISSUE 6.

Same-shaped tenants stack into one batched plan/learn dispatch per
quantum (``repro.engine.cohort``) with everything tenant-visible kept
per-tenant.  Covers: fused == unfused == solo bit-for-bit (both
schedulers, several quanta, faulty teachers, unequal stream lengths so
members detach mid-run), mixed-shape packing into separate cohorts,
migration OUT of a live fused cohort and restore INTO a cohort slot
(exact accounting reconciliation), mid-stream admission into a running
fused multiplexer, and the patch-learn runner's bitwise identity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import cohort, fleet, multiplex, snapshot, stream

DETERMINISTIC_STATS = (
    "ticks", "stream_steps", "tickets_issued", "queries_issued",
    "labels_applied", "tickets_dropped", "queries_dropped",
    "replies_orphaned", "tickets_lost", "queries_lost",
    "tickets_coalesced", "queries_coalesced", "asks_deferred",
    "tickets_reasked",
)


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=4):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return xs, ys


def _lossy_teacher(ys, seed=11):
    return stream.LatencyTeacher(
        stream.array_labels(ys), latency=2, jitter=2, loss_prob=0.15,
        partial_prob=0.15, seed=seed,
    )


def _assert_state_equal(a, b, msg=""):
    for (path, la), (_, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=f"{msg} leaf {path} diverged"
        )


def _assert_stats_equal(a, b, msg=""):
    for f in DETERMINISTIC_STATS:
        assert getattr(a, f) == getattr(b, f), (
            f"{msg}: stats.{f} diverged: {getattr(a, f)} != {getattr(b, f)}"
        )
    assert list(a.label_latency_ticks) == list(b.label_latency_ticks), msg


def _assert_outputs_equal(a, b, msg=""):
    for name in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg} output {name!r} diverged",
        )


def _solo(cfg, xs, teacher, **kw):
    return stream.run(
        engine.init_fleet(cfg, xs.shape[1]), (x for x in xs), cfg, teacher,
        mode="train_phase", **kw,
    )


def _tenant(name, cfg, xs, teacher, **kw):
    return multiplex.Tenant(
        name=name, state=engine.init_fleet(cfg, xs.shape[1]),
        ticks=(x for x in xs), cfg=cfg, teacher=teacher,
        mode="train_phase", **kw,
    )


# ---------------------------------------------------------------------------
# Tentpole acceptance: fused == unfused == solo, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", ["rr", "drr"])
@pytest.mark.parametrize("quantum", [1, 3])
def test_fused_matches_unfused_and_solo_bit_for_bit(sched, quantum):
    """Four same-shaped tenants under faulty teachers, UNEQUAL stream
    lengths (members exhaust and detach mid-run, the cohort restacks and
    finally dissolves): the fused multiplexer must reproduce both the
    unfused multiplexer and four solo runs exactly — states, collected
    outputs, every deterministic counter, label-latency histogram."""
    cfg = _cfg()
    lens = [30, 30, 22, 14]  # detach + dissolve paths, not just lockstep
    datas = [_stream_data(cfg, t, 4, seed=70 + i) for i, t in enumerate(lens)]

    solo = [
        _solo(cfg, xs, _lossy_teacher(ys, seed=80 + i), capacity=4,
              backpressure="coalesce")
        for i, (xs, ys) in enumerate(datas)
    ]

    def tenants():
        return [
            _tenant(f"tenant{i}", cfg, xs, _lossy_teacher(ys, seed=80 + i),
                    capacity=4, backpressure="coalesce")
            for i, (xs, ys) in enumerate(datas)
        ]

    unfused, _ = multiplex.run(tenants(), sched=sched, quantum=quantum,
                               fuse=False)

    mux = multiplex.Multiplexer(tenants(), sched=sched, quantum=quantum,
                                fuse=True)
    assert mux.round()  # one scheduling round forms the cohort...
    assert any(u.slots for u in mux._cohorts.values()), (
        "4 same-shaped tenants must actually fuse (else this test "
        "proves nothing)"
    )
    fused, agg = mux.run()

    assert agg.n_tenants == 4
    for i, (st, outs, stats) in enumerate(solo):
        for label, results in (("fused", fused), ("unfused", unfused)):
            r = results[f"tenant{i}"]
            _assert_state_equal(st, r.state, msg=f"{label} tenant{i}")
            _assert_outputs_equal(outs, r.outputs, msg=f"{label} tenant{i}")
            _assert_stats_equal(stats, r.stats, msg=f"{label} tenant{i}")
            assert r.stats.reconciled, r.stats.summary()


# ---------------------------------------------------------------------------
# Mixed shapes: separate cohorts, same results
# ---------------------------------------------------------------------------


def test_mixed_shapes_pack_into_separate_cohorts():
    """Different (cfg) pairs land in different cohorts; a tenant with a
    different stream width joins neither.  Everyone still matches solo."""
    cfg_a, cfg_b = _cfg(n_hidden=16), _cfg(n_hidden=32)
    specs = [  # (name, cfg, S)
        ("a0", cfg_a, 3), ("a1", cfg_a, 3),
        ("b0", cfg_b, 3), ("b1", cfg_b, 3),
        ("w", cfg_a, 2),  # same cfg as a*, different width: stays solo
    ]
    datas = {
        name: _stream_data(c, 18, s, seed=110 + i)
        for i, (name, c, s) in enumerate(specs)
    }

    solo = {
        name: _solo(c, datas[name][0], _lossy_teacher(datas[name][1], seed=5))
        for name, c, s in specs
    }

    mux = multiplex.Multiplexer([
        _tenant(name, c, datas[name][0], _lossy_teacher(datas[name][1], seed=5))
        for name, c, s in specs
    ], fuse=True)
    assert mux.round()
    live_cohorts = [u for u in mux._cohorts.values() if u.slots]
    assert len(live_cohorts) == 2
    fused_names = sorted(
        s.tenant.name for u in live_cohorts for s in u.slots
    )
    assert fused_names == ["a0", "a1", "b0", "b1"]  # "w" fused nowhere
    results, _ = mux.run()

    for name, c, s in specs:
        st, outs, stats = solo[name]
        _assert_state_equal(st, results[name].state, msg=name)
        _assert_outputs_equal(outs, results[name].outputs, msg=name)
        _assert_stats_equal(stats, results[name].stats, msg=name)


# ---------------------------------------------------------------------------
# Migration out of a fused cohort / restore into a cohort slot
# ---------------------------------------------------------------------------


def test_migrate_out_of_fused_cohort_and_restore_into_cohort_slot():
    """Mid-stream, extract a tenant OUT of a live fused cohort (quiesce
    disabled: its pending ring travels with the snapshot), restore it
    into a second fused multiplexer where it lands in a new cohort slot
    (its restored in-flight solo plans ride the patch path), and finish.
    Both the migrated tenant and every stay-behind must be bit-for-bit
    equal to uninterrupted solo runs, with exact accounting."""
    cfg = _cfg()
    t_len, s_len = 30, 3
    datas = [_stream_data(cfg, t_len, s_len, seed=90 + i) for i in range(3)]
    xs_d, ys_d = _stream_data(cfg, t_len, s_len, seed=94)  # mux_b companion

    def teacher(i):
        return stream.LatencyTeacher(
            stream.array_labels(datas[i][1] if i < 3 else ys_d),
            latency=2, jitter=2, loss_prob=0.2, seed=60 + i,
        )

    solo = [
        _solo(cfg, datas[i][0], teacher(i), capacity=4, collect=False)
        for i in range(3)
    ]
    solo_d = _solo(cfg, xs_d, teacher(3), capacity=4, collect=False)

    mux = multiplex.Multiplexer([
        multiplex.Tenant(
            name=f"t{i}", state=engine.init_fleet(cfg, s_len),
            ticks=snapshot.array_ticks(datas[i][0]), cfg=cfg,
            teacher=teacher(i), mode="train_phase", capacity=4, collect=False,
        )
        for i in range(3)
    ], fuse=True)
    while mux.round():
        if mux.session("t0").t >= 13:
            break
    assert mux._slot("t0").unit is not None, "t0 must be fused when extracted"
    tree, rest = mux.extract("t0", quiesce_ticks=0)
    results_a, _ = mux.run()  # t1/t2 keep going (re-fused as a pair)

    mux_b = multiplex.Multiplexer([
        multiplex.Tenant(
            name="d", state=engine.init_fleet(cfg, s_len),
            ticks=(x for x in xs_d), cfg=cfg, teacher=teacher(3),
            mode="train_phase", capacity=4, collect=False,
        )
    ], fuse=True)
    mux_b.admit(
        multiplex.Tenant(
            name="t0", state=None, ticks=rest, cfg=cfg, teacher=teacher(0),
            mode="train_phase", capacity=4, collect=False,
        ),
        snapshot=tree,
        positioned=True,  # rest is extract()'s live iterator
    )
    assert mux_b.round()
    assert mux_b._slot("t0").unit is not None, (
        "the restored tenant must land in a cohort slot"
    )
    results_b, _ = mux_b.run()

    _assert_state_equal(solo[0][0], results_b["t0"].state, msg="migrated t0")
    _assert_stats_equal(solo[0][2], results_b["t0"].stats, msg="migrated t0")
    assert results_b["t0"].stats.tickets_reasked == 0  # teacher state travelled
    assert results_b["t0"].stats.reconciled, results_b["t0"].stats.summary()
    _assert_state_equal(solo_d[0], results_b["d"].state, msg="companion d")
    _assert_stats_equal(solo_d[2], results_b["d"].stats, msg="companion d")
    for i in (1, 2):
        _assert_state_equal(solo[i][0], results_a[f"t{i}"].state, msg=f"t{i}")
        _assert_stats_equal(solo[i][2], results_a[f"t{i}"].stats, msg=f"t{i}")
        assert results_a[f"t{i}"].stats.reconciled


# ---------------------------------------------------------------------------
# Mid-stream admission into a running fused multiplexer
# ---------------------------------------------------------------------------


def test_admit_into_running_fused_mux_joins_cohort_and_matches_solo():
    cfg = _cfg()
    datas = [_stream_data(cfg, 24, 3, seed=100 + i) for i in range(3)]
    solo = [
        _solo(cfg, xs, _lossy_teacher(ys, seed=50 + i))
        for i, (xs, ys) in enumerate(datas)
    ]

    mux = multiplex.Multiplexer([
        _tenant(f"t{i}", cfg, datas[i][0], _lossy_teacher(datas[i][1], seed=50 + i))
        for i in range(2)
    ], fuse=True, quantum=2)
    for _ in range(4):
        assert mux.round()
    mux.admit(_tenant("t2", cfg, datas[2][0],
                      _lossy_teacher(datas[2][1], seed=52)))
    assert mux.round()
    assert mux._slot("t2").unit is not None, "late tenant must join the cohort"
    results, _ = mux.run()

    for i, (st, outs, stats) in enumerate(solo):
        _assert_state_equal(st, results[f"t{i}"].state, msg=f"t{i}")
        _assert_outputs_equal(outs, results[f"t{i}"].outputs, msg=f"t{i}")
        _assert_stats_equal(stats, results[f"t{i}"].stats, msg=f"t{i}")


# ---------------------------------------------------------------------------
# Patch-learn runner: bitwise unit identity
# ---------------------------------------------------------------------------


def test_patch_learn_runner_is_bitwise_solo_learn_on_the_slice():
    """``fleet._patch_learn_runner(cfg, lo, hi)`` == slice the stacked
    state, run the solo masked learn, write the slice back — bitwise,
    and rows outside [lo, hi) are untouched."""
    cfg = _cfg(min_trained=1)
    total, lo, hi = 7, 2, 5
    key = jax.random.PRNGKey(3)
    x = jnp.tanh(jax.random.normal(key, (total, cfg.elm.n_in)))
    state, p = fleet.plan(
        engine.init_fleet(cfg, total), x, cfg, mode="train_phase"
    )
    labels = jnp.asarray(np.arange(total) % cfg.elm.n_out, jnp.int32)
    mask = jnp.asarray(np.array([True, False, True]), bool)  # width hi-lo

    # Reference: the solo session's own jitted learn dispatch on the
    # slice, written back — the exact dispatch a solo run would make.
    sub = fleet.slice_streams(state, lo, hi)
    sub_p_h = p.h[lo:hi]
    new_elm_s, new_prune_s = stream._learn_runner(cfg, False)(
        sub.elm, sub.prune, sub.drift, sub.meter,
        sub_p_h, labels[lo:hi], p.pred[lo:hi], p.confidence[lo:hi],
        mask, p.controller_on[lo:hi], p.theta[lo:hi],
    )
    want = state._replace(
        elm=jax.tree.map(lambda f, s: f.at[lo:hi].set(s), state.elm, new_elm_s),
        prune=jax.tree.map(lambda f, s: f.at[lo:hi].set(s), state.prune,
                           new_prune_s),
    )

    runner = fleet._patch_learn_runner(cfg, lo, hi, False)
    new_elm, new_prune = runner(
        state.elm, state.prune, state.drift, state.meter,
        sub_p_h, labels[lo:hi], p.pred[lo:hi], p.confidence[lo:hi],
        mask, p.controller_on[lo:hi], p.theta[lo:hi],
    )
    got = state._replace(elm=new_elm, prune=new_prune)
    _assert_state_equal(want, got, msg="patch-learn")
    # Rows outside the patch are bit-identical to the pre-learn state.
    for side in ((0, lo), (hi, total)):
        _assert_state_equal(
            fleet.slice_streams(state.elm, *side),
            fleet.slice_streams(got.elm, *side),
            msg=f"rows {side} must be untouched",
        )


# ---------------------------------------------------------------------------
# CohortSession contract checks
# ---------------------------------------------------------------------------


def test_cohort_rejects_mismatched_members():
    cfg_a, cfg_b = _cfg(n_hidden=16), _cfg(n_hidden=32)
    xs_a, ys_a = _stream_data(cfg_a, 4, 2, seed=1)
    xs_b, ys_b = _stream_data(cfg_b, 4, 2, seed=2)

    def sess(cfg, ys, mode="train_phase"):
        return stream.StreamSession(
            engine.init_fleet(cfg, 2), cfg,
            stream.LatencyTeacher(stream.array_labels(ys)), mode=mode,
        )

    with pytest.raises(ValueError):
        cohort.CohortSession([sess(cfg_a, ys_a), sess(cfg_b, ys_b)])
    with pytest.raises(ValueError):
        cohort.CohortSession(
            [sess(cfg_a, ys_a), sess(cfg_a, ys_a, mode="serve")]
        )
    with pytest.raises(ValueError):
        cohort.CohortSession([])  # a cohort can't be empty
