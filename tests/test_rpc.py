"""Batched binary RPC teacher transport — ISSUE 5.

Covers the v2 length-prefixed framing codec, v1↔v2 wire interop, the
shared-connection ``BatchedRpcClient`` (batched-vs-solo bit-for-bit
parity, cross-tenant demux, accounting under loss/jitter/timeout), and
the transport bugfixes: the label server's bounded thread list, the
write lock (concurrent asks never tear a frame), and dead-connection
marking after a mid-frame write failure.
"""

import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import multiplex, rpc, stream


def _cfg(n_in=24, n_hidden=16, n_out=4, min_trained=16):
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=n_in, n_hidden=n_hidden, n_out=n_out, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=min_trained),
        drift=drift_mod.DriftConfig(warmup=16, k_sigma=3.0, enter_hits=2, exit_calm=16),
    )


def _stream_data(cfg, t, s, seed=0):
    kx = jax.random.PRNGKey(seed)
    return np.array(jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))


def _assert_state_equal(a, b, msg=""):
    for (path, la), (_, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=f"{msg} leaf {path} diverged"
        )


def _assert_reconciled(stats):
    assert stats.reconciled, stats.summary()


class _SyncTeacher:
    """Waits out each ask's reply before returning: collapses wall-clock
    nondeterminism so two transports apply labels on identical ticks —
    the labels themselves are deterministic (``expected_label``), so the
    runs become bit-for-bit comparable."""

    def __init__(self, inner, timeout=20.0):
        self.inner = inner
        self.timeout = timeout

    def ask(self, feats, mask, tick):
        ticket = self.inner.ask(feats, mask, tick)
        deadline = time.monotonic() + self.timeout
        while self.inner.in_flight() > 0 and time.monotonic() < deadline:
            time.sleep(2e-4)
        return ticket

    def poll(self, tick):
        return self.inner.poll(tick)

    def in_flight(self):
        return self.inner.in_flight()


@pytest.fixture()
def server():
    srv = rpc.LabelServer(n_out=4).start()
    yield srv
    srv.close()


# ---------------------------------------------------------------------------
# v2 framing codec
# ---------------------------------------------------------------------------


def test_codec_roundtrips_asks_and_replies():
    rng = np.random.default_rng(0)
    asks = [
        (7, 3, np.array([True, False, True]), rng.normal(size=(3, 5)).astype(np.float32)),
        (9, 4, np.ones(2, bool), rng.normal(size=(2, 8)).astype(np.float32)),
    ]
    frame = rpc.encode_asks(asks)
    assert frame[0] == rpc.WIRE_V2
    import io

    got = list(rpc._iter_wire(io.BufferedReader(io.BytesIO(frame))))
    assert len(got) == 1 and got[0][0] == "v2"
    decoded = rpc.decode_asks(got[0][1], got[0][2])
    assert len(decoded) == 2
    for (t0, k0, m0, f0), (t1, k1, m1, f1) in zip(asks, decoded):
        assert (t0, k0) == (t1, k1)
        np.testing.assert_array_equal(np.asarray(m0, bool), m1)
        np.testing.assert_array_equal(f0, f1)

    replies = [
        (7, np.array([True, False, True]), np.array([1, 0, 3], np.int32)),
        (9, np.zeros(2, bool), np.zeros(2, np.int32)),
    ]
    back = rpc.decode_replies(*list(
        rpc._iter_wire(io.BufferedReader(io.BytesIO(rpc.encode_replies(replies))))
    )[0][1:])
    assert [r.ticket for r in back] == [7, 9]
    np.testing.assert_array_equal(back[0].labels, [1, 0, 3])
    np.testing.assert_array_equal(back[0].answered, [True, False, True])
    assert back[0].labels.dtype == np.int32 and back[0].answered.dtype == bool


def test_non_object_frame_header_is_a_frame_error_not_a_crash(server):
    """A v2 frame whose header is valid JSON but not an object (e.g. a
    list) has no knowable payload length: the server must meter it as a
    frame error and drop the connection — not crash the worker thread."""
    frame = bytes([rpc.WIRE_V2]) + len(b"[1,2]").to_bytes(4, "little") + b"[1,2]"
    conn = socket.create_connection(("127.0.0.1", server.port), timeout=5.0)
    try:
        conn.sendall(frame)
        deadline = time.monotonic() + 5.0
        while server.frame_errors == 0 and time.monotonic() < deadline:
            time.sleep(5e-3)
        assert server.frame_errors == 1
        assert conn.recv(1) == b""  # server dropped the connection
    finally:
        conn.close()
    # The server survives: a well-formed client still gets labels.
    with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=10.0) as teacher:
        teacher.ask(np.zeros((2, 3), np.float32), np.ones(2, bool), tick=1)
        assert _drain(teacher)


def test_v1_and_v2_clients_interoperate_on_one_server(server):
    """The upgraded server answers each request in its own wire format;
    both clients get the same deterministic labels."""
    feats = np.zeros((3, 4), np.float32)
    mask = np.ones(3, bool)
    want = [rpc.expected_label(5, s, server.n_out) for s in range(3)]
    for wire in rpc.WIRE_FORMATS:
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=10.0,
                            wire=wire) as teacher:
            teacher.ask(feats, mask, tick=5)
            replies = _drain(teacher)
            assert replies and replies[0].labels.tolist() == want, wire
            assert replies[0].answered.all()
    assert server.requests_v1 == 1
    assert server.frames_v2 == 1
    assert server.frame_errors == 0


def _drain(teacher, timeout=10.0):
    deadline = time.monotonic() + timeout
    replies = []
    while not replies and time.monotonic() < deadline:
        replies = teacher.poll(0)
        if not replies and teacher.in_flight() == 0:
            replies = teacher.poll(0)
            break
        time.sleep(1e-3)
    return replies


# ---------------------------------------------------------------------------
# Batched shared-connection client (tentpole)
# ---------------------------------------------------------------------------


def test_batched_client_coalesces_tenants_into_one_frame(server):
    """Two tenants' asks inside the flush window ride ONE wire message;
    the batched reply is demuxed back to the handle that asked."""
    feats = np.zeros((2, 4), np.float32)
    mask = np.ones(2, bool)
    with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=10.0,
                              batch_window_s=0.25) as client:
        a = client.tenant("a")
        b = client.tenant("b")
        ta = a.ask(feats, mask, tick=1)
        tb = b.ask(feats, mask, tick=2)
        ra, rb = _drain(a), _drain(b)
    assert client.wire_messages == 1 and client.asks_sent == 2
    assert server.frames_v2 == 1 and server.asks_served == 2
    # Demux: each handle sees exactly its own ticket, with the labels of
    # the tick IT asked about.
    assert [r.ticket for r in ra] == [ta]
    assert [r.ticket for r in rb] == [tb]
    assert ra[0].labels.tolist() == [rpc.expected_label(1, s, 4) for s in range(2)]
    assert rb[0].labels.tolist() == [rpc.expected_label(2, s, 4) for s in range(2)]


def test_batch_max_flushes_before_the_window(server):
    feats = np.zeros((1, 2), np.float32)
    mask = np.ones(1, bool)
    with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=10.0,
                              batch_window_s=30.0, batch_max=4) as client:
        t = client.tenant()
        for k in range(4):  # hits batch_max: flushes NOW, not in 30s
            t.ask(feats, mask, tick=k)
        replies = []
        deadline = time.monotonic() + 10.0
        while len(replies) < 4 and time.monotonic() < deadline:
            replies += t.poll(0)
            time.sleep(1e-3)
    assert len(replies) == 4
    assert client.wire_messages == 1 and client.asks_sent == 4


def test_batched_vs_solo_parity_bit_for_bit(server):
    """A tenant behind the shared batched transport reproduces its
    per-tenant-connection ``RpcTeacher`` results bit-for-bit (labels are
    deterministic; the sync wrapper pins the application schedule)."""
    cfg = _cfg(min_trained=2)
    t_len, s_len = 8, 3
    xs = _stream_data(cfg, t_len, s_len, seed=21)

    def run_with(teacher):
        return stream.run(
            engine.init_fleet(cfg, s_len), (x for x in xs), cfg,
            _SyncTeacher(teacher), mode="train_phase",
        )

    with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=20.0) as solo:
        st_solo, outs_solo, stats_solo = run_with(solo)
    with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=20.0,
                              batch_window_s=1e-3) as client:
        st_b, outs_b, stats_b = run_with(client.tenant())

    _assert_state_equal(st_solo, st_b, msg="batched-vs-solo")
    for name in outs_solo._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(outs_solo, name)), np.asarray(getattr(outs_b, name)),
            err_msg=f"output {name!r} diverged",
        )
    assert stats_b.labels_applied == stats_solo.labels_applied == t_len * s_len
    _assert_reconciled(stats_solo)
    _assert_reconciled(stats_b)


def test_multiplexed_tenants_on_shared_client_match_solo_runs(server):
    """Two multiplexed tenants sharing ONE batched connection each
    reproduce their solo per-tenant-connection run bit-for-bit — the
    demux never leaks a label across tenants."""
    cfg = _cfg(min_trained=2)
    datas = [_stream_data(cfg, 8, 3, seed=31), _stream_data(cfg, 6, 3, seed=32)]

    solo = []
    for xs in datas:
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=20.0) as teacher:
            solo.append(stream.run(
                engine.init_fleet(cfg, xs.shape[1]), (x for x in xs), cfg,
                _SyncTeacher(teacher), mode="train_phase",
            ))

    with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=20.0,
                              batch_window_s=1e-3) as client:
        tenants = [
            multiplex.Tenant(
                name=f"tenant{i}",
                state=engine.init_fleet(cfg, xs.shape[1]),
                ticks=(x for x in xs),
                cfg=cfg,
                teacher=_SyncTeacher(client.tenant(f"tenant{i}")),
                mode="train_phase",
            )
            for i, xs in enumerate(datas)
        ]
        results, _ = multiplex.run(tenants)

    for i, (st, outs, stats) in enumerate(solo):
        r = results[f"tenant{i}"]
        _assert_state_equal(st, r.state, msg=f"tenant{i}")
        for name in outs._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(outs, name)), np.asarray(getattr(r.outputs, name)),
                err_msg=f"tenant{i} output {name!r} diverged",
            )
        assert r.stats.labels_applied == stats.labels_applied > 0
        _assert_reconciled(r.stats)


def test_batched_accounting_reconciles_under_loss_jitter_timeout():
    """Per-tenant query accounting holds exactly across batching when the
    server loses asks out of batched frames, jitters replies, and the
    client deadline converts silence to loss."""
    server = rpc.LabelServer(n_out=4, loss_prob=0.3, jitter_s=2e-3, seed=7).start()
    try:
        cfg = _cfg(min_trained=1_000_000)
        datas = [_stream_data(cfg, 20, 3, seed=41), _stream_data(cfg, 15, 2, seed=42)]
        with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=0.5,
                                  batch_window_s=1e-3) as client:
            tenants = [
                multiplex.Tenant(
                    name=f"tenant{i}",
                    state=engine.init_fleet(cfg, xs.shape[1]),
                    ticks=(x for x in xs),
                    cfg=cfg,
                    teacher=client.tenant(f"tenant{i}"),
                    mode="train_phase",
                )
                for i, xs in enumerate(datas)
            ]
            results, _ = multiplex.run(tenants)
            for i, xs in enumerate(datas):
                s = results[f"tenant{i}"].stats
                assert s.queries_issued == xs.shape[0] * xs.shape[1]
                assert s.queries_lost > 0  # P[no loss in 20 asks] ~ 0.7^20
                assert s.labels_applied > 0
                assert s.queries_issued == s.labels_applied + s.queries_dropped + s.queries_lost
                _assert_reconciled(s)
            assert client.timed_out > 0  # the deadline did the loss mapping
    finally:
        server.close()


def test_shared_rpc_teachers_dedups_by_endpoint():
    s1 = rpc.LabelServer(n_out=4).start()
    s2 = rpc.LabelServer(n_out=4).start()
    try:
        teachers, clients = multiplex.shared_rpc_teachers(
            [("127.0.0.1", s1.port), ("127.0.0.1", s1.port),
             ("127.0.0.1", s2.port)],
            timeout_s=5.0,
        )
        assert len(teachers) == 3 and len(clients) == 2
        assert teachers[0]._client is teachers[1]._client  # same endpoint
        assert teachers[2]._client is not teachers[0]._client
        for c in clients:
            c.close()
    finally:
        s1.close()
        s2.close()


def test_shared_rpc_teachers_closes_partial_clients_on_failure():
    """A later endpoint's failed dial must not leak the clients already
    built (their sockets and reader/flusher threads outlive the call)."""
    s1 = rpc.LabelServer(n_out=4).start()
    tmp = socket.socket()
    tmp.bind(("127.0.0.1", 0))
    dead_port = tmp.getsockname()[1]
    tmp.close()  # nothing listens here anymore
    try:
        with pytest.raises(OSError):
            multiplex.shared_rpc_teachers(
                [("127.0.0.1", s1.port), ("127.0.0.1", dead_port)],
                timeout_s=1.0, connect_timeout_s=1.0,
            )
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            with s1._tlock:
                if not s1._conns:
                    break
            time.sleep(5e-3)
        with s1._tlock:  # the good client's connection was torn down
            assert not s1._conns
    finally:
        s1.close()


# ---------------------------------------------------------------------------
# Bugfix: the label server's thread list stays bounded
# ---------------------------------------------------------------------------


def test_burst_of_connections_keeps_server_thread_list_bounded():
    """One thread per accepted connection, pruned on accept and joined on
    close — a long-running server must not accumulate dead threads."""
    server = rpc.LabelServer(n_out=4).start()
    try:
        feats = np.zeros((1, 2), np.float32)
        mask = np.ones(1, bool)
        for _ in range(40):
            with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=5.0) as t:
                t.ask(feats, mask, tick=0)
                assert _drain(t)
            time.sleep(2e-3)
        # One more accept prunes whatever died above.
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=5.0):
            time.sleep(0.05)
            with server._tlock:
                n_tracked = len(server._threads)
        # Pre-fix this was ~42 (one dead entry per past connection).
        assert n_tracked <= 10, n_tracked
    finally:
        server.close()
    assert server.thread_count() == 0  # close() joined every worker


# ---------------------------------------------------------------------------
# Bugfix: socket writes are serialized (no torn frames)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["solo", "batched"])
def test_concurrent_asks_never_tear_a_frame(server, transport):
    """Many threads hammering one shared connection: every frame must hit
    the wire intact (no interleaved partial writes), so every ask gets its
    reply and the server sees zero framing errors.  Load-bearing for the
    batched client, where N tenants genuinely share one socket."""
    n_threads, n_asks, s_len = 8, 25, 3
    feats = np.zeros((s_len, 4), np.float32)
    mask = np.ones(s_len, bool)
    if transport == "solo":
        teacher = rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=30.0)
        handles = [teacher] * n_threads
        closer = teacher
    else:
        client = rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=30.0,
                                      batch_window_s=5e-4, batch_max=7)
        handles = [client.tenant(f"h{i}") for i in range(n_threads)]
        closer = client
    try:
        def worker(h):
            for k in range(n_asks):
                h.ask(feats, mask, tick=k)

        threads = [threading.Thread(target=worker, args=(h,)) for h in handles]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        want = n_threads * n_asks
        replies = []
        deadline = time.monotonic() + 20.0
        while len(replies) < want and time.monotonic() < deadline:
            for h in set(handles):
                replies += h.poll(0)
            time.sleep(1e-3)
        assert len(replies) == want, (len(replies), want)
        for r in replies:  # every reply is a well-formed, correct frame
            assert r.labels.shape == (s_len,) and r.answered.all()
        assert server.frame_errors == 0
    finally:
        closer.close()


# ---------------------------------------------------------------------------
# Bugfix: a mid-frame write failure poisons the connection
# ---------------------------------------------------------------------------


class _DeadFile:
    """A write file that fails mid-frame, like a peer reset under a
    half-flushed buffer."""

    def __init__(self):
        self.write_calls = 0

    def write(self, data):
        self.write_calls += 1
        raise OSError("connection reset mid-frame")

    def flush(self):
        pass

    def close(self):
        pass


def test_write_failure_marks_solo_connection_dead(server):
    feats = np.zeros((2, 3), np.float32)
    mask = np.ones(2, bool)
    teacher = rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=0.2)
    dead = _DeadFile()
    teacher._conn.wfile = dead
    t0 = teacher.ask(feats, mask, 0)  # write fails -> connection poisoned
    t1 = teacher.ask(feats, mask, 1)  # must NOT touch the wire again
    assert teacher.broken
    assert dead.write_calls == 1, "an ask wrote after the stream desynchronized"
    assert t0 != t1
    assert teacher.in_flight() == 2  # both map to timeout -> loss...
    time.sleep(0.25)
    assert teacher.in_flight() == 0
    assert teacher.poll(0) == []
    assert teacher.timed_out == 2  # ...exactly like any other timeout


def test_write_failure_marks_batched_connection_dead():
    """When the peer is gone for good, the single lazy reconnect attempt
    fails and the old mapping applies: every pending ask → timeout → loss."""
    server = rpc.LabelServer(n_out=4).start()
    feats = np.zeros((2, 3), np.float32)
    mask = np.ones(2, bool)
    client = rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=0.2,
                                  batch_window_s=0.0)  # inline flush
    server.close()  # nothing left to reconnect to
    a, b = client.tenant("a"), client.tenant("b")
    dead = _DeadFile()
    client._conn.wfile = dead
    a.ask(feats, mask, 0)
    b.ask(feats, mask, 1)  # broken: the one reconnect attempt fails (refused)
    assert client.broken
    assert client.reconnects == 0 and client.asks_reasked == 0
    assert dead.write_calls == 1
    assert not client._queue, "a dead connection must not accumulate asks"
    time.sleep(0.25)
    assert a.in_flight() == 0 and b.in_flight() == 0
    assert a.poll(0) == [] and b.poll(0) == []
    assert a.timed_out == 1 and b.timed_out == 1
    client.close()


def test_batched_client_reconnects_once_and_reasks_in_flight(server):
    """A poisoned connection earns ONE lazy reconnect at the next flush:
    in-flight asks ride the fresh connection and get answered, instead of
    every later ask mapping straight to timeout → loss.  A later poisoning
    earns its own single attempt."""
    feats = np.zeros((2, 3), np.float32)
    mask = np.ones(2, bool)
    client = rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=10.0,
                                  batch_window_s=0.0)  # inline flush
    a, b = client.tenant("a"), client.tenant("b")
    client._conn.wfile = _DeadFile()
    ta = a.ask(feats, mask, 0)  # write fails -> poisoned, ticket pending
    tb = b.ask(feats, mask, 1)  # next flush: reconnect, re-ask BOTH tickets
    assert not client.broken
    assert client.reconnects == 1
    assert client.asks_reasked == 2
    ra, rb = _drain(a), _drain(b)
    assert [r.ticket for r in ra] == [ta]
    assert [r.ticket for r in rb] == [tb]
    assert ra[0].labels.tolist() == [rpc.expected_label(0, s, 4) for s in range(2)]
    assert rb[0].labels.tolist() == [rpc.expected_label(1, s, 4) for s in range(2)]
    assert client.timed_out == 0
    assert a.timed_out == 0 and b.timed_out == 0
    # A second poisoning is not starved by the first attempt.
    client._conn.wfile = _DeadFile()
    tc = a.ask(feats, mask, 2)
    td = a.ask(feats, mask, 3)
    assert not client.broken
    assert client.reconnects == 2
    assert client.asks_reasked == 4
    replies = []
    deadline = time.monotonic() + 10.0
    while len(replies) < 2 and time.monotonic() < deadline:
        replies += a.poll(0)
        time.sleep(1e-3)
    assert sorted(r.ticket for r in replies) == sorted([tc, td])
    assert client.timed_out == 0
    client.close()


def test_stream_run_survives_a_poisoned_connection(server):
    """End to end: the runtime keeps ticking over a dead teacher socket —
    every query meters as lost, accounting exact, no exception."""
    cfg = _cfg(min_trained=1_000_000)
    xs = _stream_data(cfg, 5, 2, seed=51)
    teacher = rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=0.2)
    teacher._conn.wfile = _DeadFile()
    st, outs, stats = stream.run(
        engine.init_fleet(cfg, 2), (x for x in xs), cfg, teacher,
        mode="train_phase",
    )
    assert stats.labels_applied == 0
    assert stats.queries_lost == stats.queries_issued == 5 * 2
    assert int(np.asarray(st.elm.count).sum()) == 0
    _assert_reconciled(stats)
    teacher.close()


# ---------------------------------------------------------------------------
# zlib-compressed envelopes (0x03)
# ---------------------------------------------------------------------------


def test_compressed_roundtrip_is_answered_in_kind_and_metered(server):
    """A 0x03 envelope carries one whole v2 frame; the server serves it
    transparently, replies in a 0x03 envelope, and meters wire-vs-raw
    bytes in both directions."""
    s = 32
    feats = np.zeros((s, 6), np.float32)
    with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=10.0,
                        compress=True) as teacher:
        ticket = teacher.ask(feats, np.ones(s, bool), tick=2)
        replies = _drain(teacher)
    assert replies and replies[0].ticket == ticket
    want = [rpc.expected_label(2, i, server.n_out) for i in range(s)]
    assert replies[0].labels.tolist() == want
    assert server.frames_compressed == 1
    assert server.frames_v2 == 1  # the inner frame still counts as v2
    assert server.raw_bytes_in > server.compressed_bytes_in > 0
    assert server.raw_bytes_out >= server.compressed_bytes_out > 0
    # The client's wire counter saw the envelope, not the raw frame.
    frame = rpc.encode_asks([(ticket, 2, np.ones(s, bool), feats)])
    assert server.compressed_bytes_in < len(frame)


def test_uncompressed_client_pays_no_compression_tax(server):
    """compress=False (the default) never emits a 0x03 byte and the
    server's compression counters stay untouched."""
    with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=10.0) as teacher:
        teacher.ask(np.zeros((2, 3), np.float32), np.ones(2, bool), tick=1)
        assert _drain(teacher)
    assert server.frames_compressed == 0
    assert server.compressed_bytes_in == 0


def test_compress_requires_v2_wire():
    with pytest.raises(ValueError, match="v2"):
        rpc.RpcTeacher("127.0.0.1", 1, wire="v1", compress=True)


def test_handshake_negotiates_compression():
    """With a secret, compression rides the HMAC handshake: the server
    echoes the grant and both directions travel as 0x03 envelopes."""
    server = rpc.LabelServer(n_out=4, secret="s3").start()
    try:
        with rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=10.0,
                            secret="s3", compress=True) as teacher:
            assert teacher._conn.compress_granted
            teacher.ask(np.zeros((4, 3), np.float32), np.ones(4, bool), tick=0)
            assert _drain(teacher)
        assert server.frames_compressed == 1
    finally:
        server.close()


def test_corrupt_zlib_envelope_is_a_frame_error(server):
    """Garbage inside a 0x03 envelope must meter as a frame error and
    drop the connection — never crash the worker thread."""
    bad = b"not-zlib-data"
    envelope = bytes([rpc.WIRE_V3_ZLIB]) + len(bad).to_bytes(4, "little") + bad
    conn = socket.create_connection(("127.0.0.1", server.port), timeout=5.0)
    try:
        conn.sendall(envelope)
        deadline = time.monotonic() + 5.0
        while server.frame_errors == 0 and time.monotonic() < deadline:
            time.sleep(5e-3)
        assert server.frame_errors == 1
        assert conn.recv(1) == b""
    finally:
        conn.close()


def test_batched_client_compresses_shared_frames(server):
    """The shared-connection client wraps its batched frames: two tenants,
    one socket, one compressed envelope carrying both asks."""
    feats = np.zeros((8, 4), np.float32)
    mask = np.ones(8, bool)
    with rpc.BatchedRpcClient("127.0.0.1", server.port, timeout_s=10.0,
                              batch_window_s=0.2, compress=True) as client:
        a, b = client.tenant("a"), client.tenant("b")
        a.ask(feats, mask, 3)
        b.ask(feats, mask, 3)
        ra, rb = _drain(a), _drain(b)
    assert ra and rb
    assert client.wire_messages == 1 and client.asks_sent == 2
    assert server.frames_compressed == 1
    assert server.asks_served == 2
