"""Fallback when ``hypothesis`` is not installed: property tests skip,
example-based tests in the same module still run.

Usage (the four property-test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # degrade gracefully: only @given tests skip
        from _hypothesis_stub import given, settings, st
"""

import pytest


class _AnyStrategy:
    """Accepts any strategies.<name>(...) chain at decoration time."""

    def __getattr__(self, name):
        return lambda *a, **k: _AnyStrategy()

    def __call__(self, *a, **k):
        return _AnyStrategy()


st = _AnyStrategy()


def settings(*args, **kwargs):
    if args and callable(args[0]):  # bare @settings
        return args[0]
    return lambda f: f


def given(*args, **kwargs):
    return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)
