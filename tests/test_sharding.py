"""Unit tests for the logical-axis sharding layer (distributed/sharding).

Two halves:

* In-process tests against the no-mesh / 1-device behavior (identity
  constraints, ``activate``/``deactivate`` scope restore semantics) —
  these must not force a multi-device jax init in the main test process.
* One subprocess (8 forced host devices, same rule as
  tests/test_multidevice.py) covering spec resolution that needs a real
  multi-device mesh: ``fleet_sharding`` over leaf ndims 1-3, the
  non-divisible-S replication fallback, ``ensure_axis_sharded`` edge
  cases, ``fleet_axis_size``, and spec stability under nested
  ``activate`` scopes.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding

ENV = dict(
    os.environ,
    XLA_FLAGS="--xla_force_host_platform_device_count=8",
    PYTHONPATH="src",
    JAX_PLATFORMS="cpu",
)


def _run(code: str, timeout=600):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=ENV,
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def _one_dev_mesh():
    return Mesh(np.asarray(jax.devices()[:1]), ("fleet",))


def test_no_mesh_is_identity():
    x = jax.numpy.ones((4, 3))
    assert sharding.mesh_or_none() is None
    assert sharding.constrain(x, "stream", None) is x
    assert sharding.named_sharding("stream", None) is None
    assert sharding.fleet_sharding(2, shape=(4, 3)) is None
    assert sharding.fleet_axis_size() == 1
    assert sharding.constrain_fleet({"a": x})["a"] is x


def test_deactivate_restores_activate_scope():
    mesh = _one_dev_mesh()
    with sharding.activate(mesh):
        assert sharding.mesh_or_none() is mesh
        assert sharding.fleet_axis_size() == 1  # 1-device fleet axis
        with sharding.deactivate():
            # Fully inactive inside: constraints become identities.
            assert sharding.mesh_or_none() is None
            assert sharding.named_sharding("stream") is None
            assert sharding.fleet_axis_size() == 1
        # ...and the enclosing scope comes back intact.
        assert sharding.mesh_or_none() is mesh
        assert sharding.named_sharding("stream") is not None
    assert sharding.mesh_or_none() is None


def test_deactivate_restores_on_exception():
    mesh = _one_dev_mesh()
    with sharding.activate(mesh):
        try:
            with sharding.deactivate():
                raise RuntimeError("boom")
        except RuntimeError:
            pass
        assert sharding.mesh_or_none() is mesh


def test_nested_activate_restores_outer_rules():
    mesh = _one_dev_mesh()
    with sharding.activate(mesh):
        outer = sharding.resolve("stream")
        with sharding.activate(mesh, rules={"stream": None}):
            assert sharding.resolve("stream") == P(None)
        assert sharding.resolve("stream") == outer


def test_resolve_unknown_and_none_axes():
    with sharding.activate(_one_dev_mesh()):
        assert sharding.resolve(None, "no_such_axis") == P(None, None)


def test_multi_device_spec_resolution():
    _run(
        """
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding
        from repro.launch.mesh import make_fleet_mesh

        mesh = make_fleet_mesh()
        assert int(mesh.devices.size) == 8

        with sharding.activate(mesh):
            assert sharding.fleet_axis_size() == 8

            # fleet_sharding over leaf ndims 1-3: leading axis on the
            # fleet rule, everything else replicated.
            assert sharding.fleet_sharding(1).spec == P('fleet')
            assert sharding.fleet_sharding(2).spec == P('fleet', None)
            assert sharding.fleet_sharding(3).spec == P('fleet', None, None)

            # Divisible S shards; non-divisible S degrades to replication
            # (resolve drops mesh axes that do not divide the dim).
            assert sharding.fleet_sharding(2, shape=(64, 16)).spec == \\
                P('fleet', None)
            assert sharding.fleet_sharding(2, shape=(100, 16)).spec == \\
                P(None, None)
            assert sharding.fleet_sharding(1, shape=(8,)).spec == P('fleet')
            assert sharding.fleet_sharding(1, shape=(7,)).spec == P(None)

            # ensure_axis_sharded: adds the axis to the LARGEST divisible
            # unsharded dim...
            assert sharding.ensure_axis_sharded(P(None, None), (16, 8),
                                                'fleet') == P('fleet', None)
            assert sharding.ensure_axis_sharded(P(None, None), (8, 64),
                                                'fleet') == P(None, 'fleet')
            # ...extends a too-short spec...
            assert sharding.ensure_axis_sharded(P(), (16, 8), 'fleet') == \\
                P('fleet', None)
            # ...is a no-op when the axis is already used, when no dim
            # divides, and for absent mesh axes.
            spec = P('fleet', None)
            assert sharding.ensure_axis_sharded(spec, (16, 8), 'fleet') is spec
            assert sharding.ensure_axis_sharded(P(None,), (7,), 'fleet') == \\
                P(None)
            assert sharding.ensure_axis_sharded(spec, (16, 8), 'model') is spec

            # Spec stability under nested activate scopes: re-activating
            # the same mesh (or a rule override) must not perturb the
            # outer resolution once the inner scope exits.
            outer = sharding.fleet_sharding(2, shape=(64, 16)).spec
            with sharding.activate(mesh):
                assert sharding.fleet_sharding(2, shape=(64, 16)).spec == outer
            with sharding.activate(mesh, rules={'stream': None}):
                assert sharding.fleet_sharding(2, shape=(64, 16)).spec == \\
                    P(None, None)
            assert sharding.fleet_sharding(2, shape=(64, 16)).spec == outer

            with sharding.deactivate():
                assert sharding.fleet_sharding(2) is None
                assert sharding.fleet_axis_size() == 1
            assert sharding.fleet_axis_size() == 8

        assert sharding.fleet_sharding(2) is None
        print('OK')
        """
    )
