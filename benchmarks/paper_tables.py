"""One benchmark per paper table/figure (DESIGN.md §7 index).

Each function returns a list of CSV rows ``(name, value, derived)`` and
prints a human-readable block.  ``benchmarks.run`` aggregates them.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import memory_model as mm
from repro.core import power_model as pm


def table1_memory():
    """Paper Table 1: ODL core memory size [kB] vs hidden nodes N."""
    got = mm.table1()
    rows = []
    print("\n== Table 1: memory size [kB] (n=561, m=6) ==")
    print(f"{'N':>5} {'NoODL':>9} {'ODLBase':>9} {'ODLHash':>9}   (paper values in parens)")
    for i, n in enumerate(got["hidden"]):
        print(
            f"{n:>5} {got['noodl'][i]:>9.2f} {got['base'][i]:>9.2f} {got['hash'][i]:>9.2f}"
            f"   ({mm.PAPER_TABLE1['noodl'][i]} / {mm.PAPER_TABLE1['base'][i]} / {mm.PAPER_TABLE1['hash'][i]})"
        )
        for var in ("noodl", "base", "hash"):
            rows.append((f"table1/{var}/N{n}_kB", got[var][i],
                         f"paper={mm.PAPER_TABLE1[var][i]}"))
    return rows


def table2_params(trials: int = 3):
    """Paper Table 2: parameter count + accuracy of ODLHash."""
    rows = []
    print("\n== Table 2: params + accuracy ==")
    for n_hidden, paper_acc in ((128, 93.67), (256, 95.51)):
        params = mm.odl_param_count(mm.CoreShape(N=n_hidden))
        accs = [
            common.drift_trial(s, theta=1.0, n_hidden=n_hidden)["before"]
            for s in range(trials)
        ]
        acc = 100 * float(np.mean(accs))
        print(f"ODLHash N={n_hidden}: params={params/1000:.0f}k acc={acc:.2f}% "
              f"(paper: {mm.PAPER_TABLE2[n_hidden]/1000:.0f}k, {paper_acc}%)")
        rows.append((f"table2/N{n_hidden}/params", params, f"paper~{mm.PAPER_TABLE2[n_hidden]}"))
        rows.append((f"table2/N{n_hidden}/acc_pct", acc, f"paper={paper_acc}"))
    return rows


PAPER_TABLE3 = {
    ("noodl", 128): (92.9, 82.9), ("base", 128): (93.4, 90.8), ("hash", 128): (93.1, 90.7),
    ("noodl", 256): (95.1, 83.7), ("base", 256): (95.2, 92.5), ("hash", 256): (95.1, 92.3),
}


def table3_drift(trials: int = 5):
    """Paper Table 3: accuracy before/after drift, ODL variants vs NoODL."""
    rows = []
    print("\n== Table 3: accuracy before/after drift [%] ==")
    for n_hidden in (128, 256):
        for variant in ("base", "hash"):
            runs = [common.drift_trial(s, 1.0, n_hidden, variant) for s in range(trials)]
            b_m, b_s = common.mean_std(runs, "before")
            a_m, a_s = common.mean_std(runs, "after")
            no_m, _ = common.mean_std(runs, "noodl_after")
            pb, pa = PAPER_TABLE3[(variant, n_hidden)]
            pno = PAPER_TABLE3[("noodl", n_hidden)][1]
            print(
                f"ODL{variant.capitalize():<5} N={n_hidden}: before {100*b_m:.1f}±{100*b_s:.1f}"
                f" after {100*a_m:.1f}±{100*a_s:.1f} | NoODL after {100*no_m:.1f}"
                f"   (paper {pb}/{pa}, NoODL {pno})"
            )
            rows.append((f"table3/{variant}/N{n_hidden}/before_pct", 100 * b_m, f"paper={pb}"))
            rows.append((f"table3/{variant}/N{n_hidden}/after_pct", 100 * a_m, f"paper={pa}"))
            rows.append((f"table3/noodl/N{n_hidden}/after_pct", 100 * no_m, f"paper={pno}"))
    return rows


def fig3_pruning(trials: int = 5):
    """Paper Fig. 3: comm volume + accuracy vs theta (incl. auto)."""
    rows = []
    print("\n== Fig. 3: data pruning sweep (N=128, ODLHash) ==")
    base_after = None
    for theta in (1.0, 0.64, 0.32, 0.16, 0.08, 0.01, "auto"):
        runs = [common.drift_trial(s, theta) for s in range(trials)]
        a_m, a_s = common.mean_std(runs, "after")
        c_m, _ = common.mean_std(runs, "comm")
        if theta == 1.0:
            base_after = a_m
        tag = f"theta={theta}"
        extra = ""
        if theta == "auto":
            extra = (f"  comm reduction {100*(1-c_m):.1f}% (paper 55.7%), "
                     f"acc delta {100*(a_m-base_after):+.1f}% (paper -0.9%)")
        print(f"{tag:>12}: after {100*a_m:.1f}±{100*a_s:.1f}%  comm {100*c_m:.1f}%{extra}")
        rows.append((f"fig3/{theta}/after_pct", 100 * a_m, ""))
        rows.append((f"fig3/{theta}/comm_pct", 100 * c_m, ""))
    return rows


def fig4_power(trials: int = 3):
    """Paper Fig. 4: training-mode power vs theta at 1/5/10 s event periods."""
    rows = []
    print("\n== Fig. 4: power consumption vs theta ==")
    for theta in (1.0, 0.32, 0.16, 0.08, "auto"):
        runs = [common.drift_trial(s, theta) for s in range(trials)]
        comm, _ = common.mean_std(runs, "comm")
        line = f"theta={theta:>5}: comm={100*comm:5.1f}%"
        for period in (1.0, 5.0, 10.0):
            mw = pm.avg_power_mw(comm, period)
            red = pm.power_reduction_pct(comm, period)
            line += f"  | {period:>4.0f}s: {mw:6.3f} mW (-{red:4.1f}%)"
            rows.append((f"fig4/{theta}/{int(period)}s_mw", mw, f"reduction={red:.1f}%"))
        print(line)
    print(f"(paper Auto reductions: {pm.PAPER_AUTO_REDUCTION})")
    return rows


def table4_core():
    """Paper Table 4: execution time/power of the core (calibrated model)."""
    rows = []
    print("\n== Table 4: ODL core @10 MHz (cycle/power model) ==")
    s = mm.CoreShape()
    ours = {
        "predict_ms": pm.predict_time_ms(s),
        "train_ms": pm.train_time_ms(s),
        "predict_mw": pm.P_PRED_MW,
        "train_mw": pm.P_TRAIN_MW,
        "idle_mw": pm.P_IDLE_MW,
        "sleep_mw": pm.P_SLEEP_MW,
    }
    for k, v in ours.items():
        print(f"{k:>12}: {v:8.2f}   (paper {pm.PAPER_TABLE4[k]})")
        rows.append((f"table4/{k}", v, f"paper={pm.PAPER_TABLE4[k]}"))
    # Model extrapolations beyond the paper's single published point:
    for n_hidden in (64, 256):
        sh = mm.CoreShape(N=n_hidden)
        rows.append((f"table4/predict_ms_N{n_hidden}", pm.predict_time_ms(sh), "model extrapolation"))
        rows.append((f"table4/train_ms_N{n_hidden}", pm.train_time_ms(sh), "model extrapolation"))
    return rows
