"""Multi-tenant multiplexer vs N sequential stream.run calls — and cohort
fusion (ISSUE 6) vs both.

Measures aggregate stream-steps/second for N independent same-shaped
fleets (tenants) of S streams over T ticks each:

  * ``sequential`` — N back-to-back ``stream.run`` calls, one per tenant
    (the no-multiplexer baseline: each fleet waits for the previous one).
  * ``unfused``    — ``engine.multiplex.run(fuse=False)`` interleaving the
    same N tenants round-robin, one jitted dispatch per tenant per tick.
  * ``fused``      — ``fuse=True``: same-shaped tenants stack into one
    cohort (``engine.cohort``) and advance with ONE batched dispatch per
    tick for the whole group.

With identical tenant configs the unfused multiplexer pays only scheduler
overhead (executables are shared through the runner LRUs), so it holds
>= ~90% of sequential.  The fused path's acceptance bar is stronger: at
N >= 8 it must *clearly beat* sequential — per-dispatch overhead is paid
once per cohort instead of once per tenant — while staying bit-for-bit
identical to the unfused run (asserted here on every iteration, and
locked structurally by tests/test_cohort.py).  Best-of-N interleaved wall
time (same protocol as stream_bench).

Full mode sweeps N in {2, 4, 8, 16}; ``--quick`` is the CI smoke: 4
same-shaped lossy tenants at S=16, fused, written to the bench artifact
dir instead of the committed baseline (benchmarks.common.bench_out_path).

Run:  PYTHONPATH=src python benchmarks/multiplex_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time

import jax
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import multiplex, stream

try:
    from benchmarks import common
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import common

N_IN, N_HIDDEN, N_OUT = 64, 64, 6

PARITY_STATS = (
    "ticks", "stream_steps", "queries_issued", "labels_applied",
    "queries_dropped", "queries_lost", "queries_coalesced",
)


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.asarray(jax.numpy.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return [x for x in xs], ys


def _teacher(ys, latency, loss):
    return stream.LatencyTeacher(
        stream.array_labels(ys), latency=latency, loss_prob=loss, seed=0
    )


def _sequential_once(cfg, tenant_data, latency, loss, capacity):
    t0 = time.perf_counter()
    last = None
    for xs_host, ys in tenant_data:
        state, _, stats = stream.run(
            engine.init_fleet(cfg, xs_host[0].shape[0]),
            (x for x in xs_host),
            cfg, _teacher(ys, latency, loss), mode="train_phase",
            capacity=capacity, collect=False,
        )
        assert stats.reconciled, stats.summary()
        last = state
    jax.block_until_ready(last.elm.beta)
    return time.perf_counter() - t0


def _multiplex_once(cfg, tenant_data, latency, loss, capacity, backpressure,
                    fuse):
    tenants = [
        multiplex.Tenant(
            name=f"tenant{i}",
            state=engine.init_fleet(cfg, xs_host[0].shape[0]),
            ticks=(x for x in xs_host),
            cfg=cfg,
            teacher=_teacher(ys, latency, loss),
            mode="train_phase",
            capacity=capacity,
            backpressure=backpressure,
            collect=False,
        )
        for i, (xs_host, ys) in enumerate(tenant_data)
    ]
    t0 = time.perf_counter()
    results, agg = multiplex.run(tenants, fuse=fuse)
    jax.block_until_ready(results["tenant0"].state.elm.beta)
    dt = time.perf_counter() - t0
    for r in results.values():
        assert r.stats.reconciled, r.stats.summary()
    return dt, results, agg


def _assert_fused_unfused_identical(fused, unfused):
    """The acceptance identity: fusion changes wall time, nothing else."""
    assert fused.keys() == unfused.keys()
    for name in fused:
        a, b = fused[name], unfused[name]
        for f in PARITY_STATS:
            assert getattr(a.stats, f) == getattr(b.stats, f), (
                f"{name}: stats.{f} diverged fused vs unfused"
            )
        for (path, la), (_, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a.state)[0],
            jax.tree_util.tree_flatten_with_path(b.state)[0],
        ):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb),
                err_msg=f"{name}: state leaf {path} diverged fused vs unfused",
            )


def bench(cfg, tenant_data, latency, loss, capacity, backpressure, iters=4):
    """Best-of-N, interleaved (container scheduling drifts on a scale of
    seconds; GC paused so gen-2 pauses don't pollute single iterations).
    Every fused iteration is checked bit-for-bit against an unfused run."""
    _sequential_once(cfg, tenant_data, latency, loss, capacity)  # warmup
    _multiplex_once(cfg, tenant_data, latency, loss, capacity, backpressure,
                    fuse=True)
    best = {"sequential": float("inf"), "unfused": float("inf"),
            "fused": float("inf")}
    best_results = None
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            best["sequential"] = min(
                best["sequential"],
                _sequential_once(cfg, tenant_data, latency, loss, capacity),
            )
            dt_u, results_u, _ = _multiplex_once(
                cfg, tenant_data, latency, loss, capacity, backpressure,
                fuse=False,
            )
            best["unfused"] = min(best["unfused"], dt_u)
            dt_f, results_f, _ = _multiplex_once(
                cfg, tenant_data, latency, loss, capacity, backpressure,
                fuse=True,
            )
            _assert_fused_unfused_identical(results_f, results_u)
            if dt_f < best["fused"]:
                best["fused"], best_results = dt_f, results_f
    finally:
        gc.enable()
    return best, best_results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 4 same-shaped lossy tenants, S=16, fused")
    ap.add_argument("--backpressure", default="drop_oldest",
                    choices=stream.BACKPRESSURE_POLICIES)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    args.out = common.bench_out_path("multiplex", args.quick, args.out)

    # (N tenants, S, T, teacher latency, loss) — quick is the CI smoke shape
    # (4 lossy tenants fused into one cohort); full sweeps the cohort sizes
    # the ISSUE-6 acceptance names, with a zero-latency and a laggy teacher.
    if args.quick:
        cases = [(4, 16, 32, 2, 0.2)]
        iters = 2
    else:
        cases = [
            (n, 64, 64, latency, 0.0)
            for latency in (0, 4)
            for n in (2, 4, 8, 16)
        ]
        iters = 4
    capacity = 16
    rows = []
    print(f"== Multiplexer throughput: sequential vs unfused vs fused "
          f"(n_in={N_IN}, N={N_HIDDEN}, backpressure={args.backpressure}) ==")
    for n_tenants, s, t, latency, loss in cases:
        cfg = _cfg()
        tenant_data = [_data(t, s, cfg, seed=i) for i in range(n_tenants)]
        steps = n_tenants * t * s
        best, results = bench(
            cfg, tenant_data, latency, loss, capacity, args.backpressure,
            iters=iters,
        )
        sps = {k: steps / v for k, v in best.items()}
        per_tenant = {
            name: {
                "tick_p50_ms": r.stats.tick_p50_ms,
                "tick_p95_ms": r.stats.tick_p95_ms,
                "labels_applied": r.stats.labels_applied,
                "queries_issued": r.stats.queries_issued,
                "queries_lost": r.stats.queries_lost,
            }
            for name, r in sorted(results.items())
        }
        rows.append({
            "streams": s,
            "ticks": t,
            "tenants": n_tenants,
            "quantum": multiplex.DEFAULT_QUANTUM,
            "n_hidden": N_HIDDEN,
            "teacher_latency_ticks": latency,
            "teacher_loss_prob": loss,
            "backpressure": args.backpressure,
            "sequential_steps_per_s": sps["sequential"],
            "unfused_steps_per_s": sps["unfused"],
            "fused_steps_per_s": sps["fused"],
            "unfused_vs_sequential": sps["unfused"] / sps["sequential"],
            "fused_vs_sequential": sps["fused"] / sps["sequential"],
            "fused_vs_unfused": sps["fused"] / sps["unfused"],
            "bit_for_bit": True,  # asserted every fused iteration
            "per_tenant": per_tenant,
        })
        print(f"N={n_tenants:2d} S={s:3d} T={t:3d} lat={latency:2d} "
              f"loss={loss:.1f}: seq {sps['sequential']:>10,.0f} sps | "
              f"unfused {sps['unfused']:>10,.0f} sps "
              f"({100 * sps['unfused'] / sps['sequential']:5.1f}%) | "
              f"fused {sps['fused']:>10,.0f} sps "
              f"({100 * sps['fused'] / sps['sequential']:5.1f}% of seq, "
              f"{sps['fused'] / sps['unfused']:.2f}x unfused)")

    out = {"bench": "multiplex", "backend": jax.default_backend(), "rows": rows}
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
