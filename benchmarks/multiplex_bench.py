"""Multi-tenant multiplexer vs N sequential stream.run calls.

Measures aggregate stream-steps/second and per-tenant tick p50/p95 for N
independent fleets (tenants) of S streams over T ticks each:

  * ``sequential`` — N back-to-back ``stream.run`` calls, one per tenant
    (the no-multiplexer baseline: each fleet waits for the previous one).
  * ``multiplex``  — ``engine.multiplex.run`` interleaving the same N
    tenants round-robin in one process, sharing compiled runners.

With identical tenant configs the multiplexer pays only scheduler overhead
(the executables are shared either way through the runner LRUs), so
aggregate throughput should stay >= ~90% of sequential — that, plus the
bit-for-bit parity locked by tests/test_multiplex.py, is the acceptance
bar for serving many fleets from one process.  Both sides report best-of-N
interleaved wall time (same protocol as stream_bench).

Writes BENCH_multiplex.json next to the repo root (same schema family as
BENCH_stream.json).

Run:  PYTHONPATH=src python benchmarks/multiplex_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time

import jax
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import multiplex, stream

N_IN, N_HIDDEN, N_OUT = 64, 64, 6


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.asarray(jax.numpy.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return [x for x in xs], ys


def _teacher(ys, latency, loss):
    return stream.LatencyTeacher(
        stream.array_labels(ys), latency=latency, loss_prob=loss, seed=0
    )


def _sequential_once(cfg, tenant_data, latency, loss, capacity):
    t0 = time.perf_counter()
    last = None
    for xs_host, ys in tenant_data:
        state, _, stats = stream.run(
            engine.init_fleet(cfg, xs_host[0].shape[0]),
            (x for x in xs_host),
            cfg, _teacher(ys, latency, loss), mode="train_phase",
            capacity=capacity, collect=False,
        )
        assert stats.reconciled, stats.summary()
        last = state
    jax.block_until_ready(last.elm.beta)
    return time.perf_counter() - t0


def _multiplex_once(cfg, tenant_data, latency, loss, capacity, backpressure):
    tenants = [
        multiplex.Tenant(
            name=f"tenant{i}",
            state=engine.init_fleet(cfg, xs_host[0].shape[0]),
            ticks=(x for x in xs_host),
            cfg=cfg,
            teacher=_teacher(ys, latency, loss),
            mode="train_phase",
            capacity=capacity,
            backpressure=backpressure,
            collect=False,
        )
        for i, (xs_host, ys) in enumerate(tenant_data)
    ]
    t0 = time.perf_counter()
    results, agg = multiplex.run(tenants)
    jax.block_until_ready(results["tenant0"].state.elm.beta)
    dt = time.perf_counter() - t0
    for r in results.values():
        assert r.stats.reconciled, r.stats.summary()
    return dt, results, agg


def bench(cfg, tenant_data, latency, loss, capacity, backpressure, iters=6):
    """Best-of-N, interleaved (container scheduling drifts on a scale of
    seconds; GC paused so gen-2 pauses don't pollute single iterations)."""
    _sequential_once(cfg, tenant_data, latency, loss, capacity)  # warmup
    _multiplex_once(cfg, tenant_data, latency, loss, capacity, backpressure)
    best_seq = best_mux = float("inf")
    best_results = None
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            best_seq = min(
                best_seq, _sequential_once(cfg, tenant_data, latency, loss, capacity)
            )
            dt, results, agg = _multiplex_once(
                cfg, tenant_data, latency, loss, capacity, backpressure
            )
            if dt < best_mux:
                best_mux, best_results = dt, results
    finally:
        gc.enable()
    return best_seq, best_mux, best_results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 tenants, S=16, lossy teacher")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--backpressure", default="drop_oldest",
                    choices=stream.BACKPRESSURE_POLICIES)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_multiplex_quick.json" if args.quick else "BENCH_multiplex.json"
        args.out = str(pathlib.Path(__file__).resolve().parent.parent / name)

    # (S, T, teacher latency, loss) — quick is the ISSUE-3 CI smoke shape.
    cases = (
        [(16, 32, 2, 0.2)] if args.quick else [(512, 64, 0, 0.0), (512, 64, 4, 0.0)]
    )
    capacity = 16
    rows = []
    print(f"== Multiplexer throughput ({args.tenants} tenants, "
          f"n_in={N_IN}, N={N_HIDDEN}, backpressure={args.backpressure}) ==")
    for s, t, latency, loss in cases:
        cfg = _cfg()
        tenant_data = [_data(t, s, cfg, seed=i) for i in range(args.tenants)]
        steps = args.tenants * t * s
        best_seq, best_mux, results = bench(
            cfg, tenant_data, latency, loss, capacity, args.backpressure
        )
        seq_sps, mux_sps = steps / best_seq, steps / best_mux
        per_tenant = {
            name: {
                "tick_p50_ms": r.stats.tick_p50_ms,
                "tick_p95_ms": r.stats.tick_p95_ms,
                "labels_applied": r.stats.labels_applied,
                "queries_issued": r.stats.queries_issued,
                "queries_lost": r.stats.queries_lost,
            }
            for name, r in sorted(results.items())
        }
        rows.append({
            "streams": s,
            "ticks": t,
            "tenants": args.tenants,
            "quantum": multiplex.DEFAULT_QUANTUM,
            "n_hidden": N_HIDDEN,
            "teacher_latency_ticks": latency,
            "teacher_loss_prob": loss,
            "backpressure": args.backpressure,
            "sequential_steps_per_s": seq_sps,
            "multiplex_steps_per_s": mux_sps,
            "multiplex_vs_sequential": mux_sps / seq_sps,
            "per_tenant": per_tenant,
        })
        p95s = ", ".join(
            f"{n} p50/p95 {d['tick_p50_ms']:.2f}/{d['tick_p95_ms']:.2f} ms"
            for n, d in per_tenant.items()
        )
        print(f"S={s:4d} T={t:3d} lat={latency:2d} loss={loss:.1f}: "
              f"sequential {seq_sps:>11,.0f} sps | multiplex {mux_sps:>11,.0f} sps "
              f"({100 * mux_sps / seq_sps:5.1f}%) | {p95s}")

    out = {"bench": "multiplex", "backend": jax.default_backend(), "rows": rows}
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
