"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from results/dryrun.jsonl (written by
``repro.launch.dryrun``):

  compute_s    = HLO_FLOPs_per_device / 197e12         (v5e bf16 peak)
  memory_s     = HLO_bytes_per_device / 819e9          (HBM bw)
  collective_s = collective_bytes_per_device / 50e9    (ICI per link)

``cost_analysis``/``memory_analysis`` of the SPMD-partitioned module are
per-device (verified in tests/test_roofline.py), and the collective census
sums result-shape bytes of every collective op in the per-device program.

Also reported: MODEL_FLOPS (6·N_active·D train / 2·N_active·D decode, the
standard MFU numerator), the useful-compute ratio MODEL/HLO (catches
remat/redundancy waste), and the roofline fraction
   RF = (MODEL_FLOPS_per_dev / peak) / max(compute_s, memory_s, collective_s)
— the score §Perf hillclimbs push up.
"""

from __future__ import annotations

import json
import os

from repro import configs
from repro.models import layers, model as model_lib

PEAK_FLOPS = 197e12  # TPU v5e bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
HBM_PER_CHIP = 16e9


def total_params(cfg) -> int:
    return layers.count_params(model_lib.build_schema(cfg))


def active_params(cfg) -> int:
    """MoE: experts contribute top_k/E of their weight; else == total."""
    total = total_params(cfg)
    if not cfg.n_experts:
        return total
    per_layer_expert = 3 * cfg.d_model * cfg.d_ff_expert * cfg.n_experts
    expert_total = cfg.n_layers * per_layer_expert
    active_expert = expert_total * cfg.top_k / cfg.n_experts
    return int(total - expert_total + active_expert)


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs of the step (standard 6ND / 2ND convention)."""
    n_act = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per stream


def analyse(rec: dict) -> dict:
    cfg = configs.get_config(rec["arch"])
    shape = configs.shape_by_name(rec["shape"])
    n_dev = rec["n_devices"]

    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    coll_bytes = rec.get(
        "collective_bytes", rec.get("collectives", {}).get("total_bytes", 0)
    )
    collective_s = coll_bytes / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())

    mf = model_flops(cfg, shape)
    mf_dev = mf / n_dev
    hlo_global = rec["flops"] * n_dev
    useful_ratio = mf / hlo_global if hlo_global else 0.0
    rf = (mf_dev / PEAK_FLOPS) / step_s if step_s > 0 else 0.0

    suggestions = {
        "compute": "reduce recompute (remat policy) / push useful-ratio up",
        "memory": "fuse attention (chunked softmax) and cut f32 intermediates to lift arithmetic intensity",
        "collective": "reshard to cut the dominant collective (overlap or move axis)",
    }
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "variant")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful_ratio,
        "roofline_fraction": rf,
        "peak_gb": rec.get("peak_bytes", 0) / 1e9,
        "fits_hbm": rec.get("peak_bytes", 0) <= HBM_PER_CHIP,
        "next_lever": suggestions[dominant],
    }


def load(path: str = "results/dryrun.jsonl"):
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # last record wins per cell (re-runs supersede)
            recs[(r["arch"], r["shape"], r["mesh"], r.get("variant", "base"))] = r
    return list(recs.values())


def table(path: str = "results/dryrun.jsonl", variant: str | None = "base",
          mesh: str | None = None):
    rows = []
    for rec in load(path):
        if rec.get("status") == "skipped":
            rows.append({**{k: rec[k] for k in ("arch", "shape", "mesh", "variant")},
                         "skipped": rec["reason"]})
            continue
        if rec.get("status") != "ok":
            rows.append({**{k: rec.get(k) for k in ("arch", "shape", "mesh", "variant")},
                         "error": rec.get("error", "?")})
            continue
        if variant and rec.get("variant") != variant:
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        rows.append(analyse(rec))
    return rows


def print_table(rows):
    print(f"{'arch':<20} {'shape':<12} {'mesh':<8} {'comp_ms':>8} {'mem_ms':>8} "
          f"{'coll_ms':>8} {'dom':<10} {'useful':>7} {'RF':>6} {'peakGB':>7}")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<8} SKIPPED: {r['skipped']}")
            continue
        if "error" in r:
            print(f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<8} ERROR: {r['error'][:60]}")
            continue
        print(
            f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<8} "
            f"{1e3*r['compute_s']:>8.2f} {1e3*r['memory_s']:>8.2f} "
            f"{1e3*r['collective_s']:>8.2f} {r['dominant']:<10} "
            f"{r['useful_ratio']:>7.3f} {r['roofline_fraction']:>6.3f} {r['peak_gb']:>7.1f}"
        )


def main():
    rows = table(variant=None)
    print("\n== Roofline (per device, v5e constants) ==")
    print_table(rows)
    return [
        (
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('variant','base')}",
            r.get("roofline_fraction", 0.0),
            r.get("dominant", r.get("skipped", r.get("error", ""))),
        )
        for r in rows
    ]


if __name__ == "__main__":
    main()
