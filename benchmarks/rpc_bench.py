"""Batched shared-connection RPC transport vs per-tenant connections.

The paper's cost argument is communication: auto data pruning cuts
teacher-query *volume*, and this transport cuts the per-query round-trip
cost — N tenants' asks coalesced into single length-prefixed binary
frames over one connection per teacher host.  This bench measures what
actually hits the wire for N ∈ {1, 2, 4} tenants multiplexed over one
process against a loopback ``LabelServer``:

  * ``per_tenant_v1`` — one ``RpcTeacher`` connection per tenant, legacy
    newline-JSON wire format (the PR-3 shape).
  * ``per_tenant``    — one connection per tenant, v2 binary frames
    (format win only).
  * ``batched``       — ONE shared ``BatchedRpcClient`` connection for
    all tenants, asks coalesced within the flush window (format win +
    batching win).

Reported per transport: request messages on the wire, request bytes per
query, messages per applied label, and aggregate stream-steps/s.  The
acceptance bar (ISSUE 5): at 4 tenants the batched transport sends >= 2x
fewer wire messages per applied label than per-tenant connections, at
>= 95% of their aggregate throughput.  A separate ``faults`` pass per N
(server-side ask loss + reply jitter + client deadline) asserts every
tenant's query accounting still reconciles exactly across batching.

Writes BENCH_rpc.json next to the repo root (``--quick``: 2 tenants,
S=16, the CI smoke — written to the bench artifact dir, not the committed
baseline; see benchmarks.common.bench_out_path).

Run:  PYTHONPATH=src python benchmarks/rpc_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time

import jax
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import multiplex, rpc, stream

try:
    from benchmarks import common
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import common

N_IN, N_HIDDEN, N_OUT = 64, 64, 6

TRANSPORTS = ("per_tenant_v1", "per_tenant", "batched")


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg, seed):
    kx = jax.random.PRNGKey(seed)
    return np.asarray(jax.numpy.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))


def _run_once(transport, cfg, tenant_data, capacity, timeout_s, window_s,
              batch_max, loss=0.0, jitter_s=0.0):
    """One multiplexed run of every tenant over ``transport``; returns
    (wall_s, results, wire_messages, wire_bytes)."""
    server = rpc.LabelServer(n_out=cfg.elm.n_out, loss_prob=loss,
                             jitter_s=jitter_s, seed=0).start()
    clients = []
    try:
        n = len(tenant_data)
        if transport == "batched":
            teachers, clients = multiplex.shared_rpc_teachers(
                [("127.0.0.1", server.port)] * n, timeout_s=timeout_s,
                batch_window_s=window_s, batch_max=batch_max,
            )
        else:
            wire = "v1" if transport == "per_tenant_v1" else "v2"
            teachers = [
                rpc.RpcTeacher("127.0.0.1", server.port, timeout_s=timeout_s,
                               wire=wire)
                for _ in range(n)
            ]
            clients = teachers
        tenants = [
            multiplex.Tenant(
                name=f"tenant{i}",
                state=engine.init_fleet(cfg, xs.shape[1]),
                ticks=(x for x in xs),
                cfg=cfg,
                teacher=teachers[i],
                mode="train_phase",
                capacity=capacity,
                collect=False,
            )
            for i, xs in enumerate(tenant_data)
        ]
        t0 = time.perf_counter()
        results, _ = multiplex.run(tenants)
        jax.block_until_ready(results["tenant0"].state.elm.beta)
        dt = time.perf_counter() - t0
        for r in results.values():
            assert r.stats.reconciled, r.stats.summary()
        msgs = sum(c.wire_messages for c in clients)
        nbytes = sum(c.wire_bytes for c in clients)
        assert server.frame_errors == 0, server.frame_errors
        return dt, results, msgs, nbytes
    finally:
        for c in clients:
            c.close()
        server.close()


def bench_transport(transport, cfg, tenant_data, capacity, timeout_s,
                    window_s, batch_max, iters):
    """Best-of-N wall time; wire counters are deterministic per run except
    for batch packing, so they come from the best run."""
    _run_once(transport, cfg, tenant_data, capacity, timeout_s, window_s,
              batch_max)  # warmup (compile)
    best = None
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            out = _run_once(transport, cfg, tenant_data, capacity, timeout_s,
                            window_s, batch_max)
            if best is None or out[0] < best[0]:
                best = out
    finally:
        gc.enable()
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 tenants, S=16, loopback server")
    ap.add_argument("--window-ms", type=float, default=5.0,
                    help="batched-transport flush window")
    ap.add_argument("--batch-max", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    args.out = common.bench_out_path("rpc", args.quick, args.out)

    tenant_counts = [2] if args.quick else [1, 2, 4]
    s, t = (16, 48) if args.quick else (64, 200)
    capacity, timeout_s = 32, 10.0
    window_s = args.window_ms / 1e3
    cfg = _cfg()
    rows = []
    print(f"== RPC transport ({'quick' if args.quick else 'full'}: S={s}, "
          f"T={t}, window={args.window_ms}ms, batch_max={args.batch_max}) ==")
    for n in tenant_counts:
        tenant_data = [_data(t, s, cfg, seed=i) for i in range(n)]
        steps = n * t * s
        row = {"tenants": n, "streams": s, "ticks": t, "n_hidden": N_HIDDEN,
               "batch_window_ms": args.window_ms, "batch_max": args.batch_max,
               "quantum": multiplex.DEFAULT_QUANTUM, "transports": {}}
        for transport in TRANSPORTS:
            dt, results, msgs, nbytes = bench_transport(
                transport, cfg, tenant_data, capacity, timeout_s, window_s,
                args.batch_max, args.iters,
            )
            queries = sum(r.stats.queries_issued for r in results.values())
            labels = sum(r.stats.labels_applied for r in results.values())
            row["transports"][transport] = {
                "steps_per_s": steps / dt,
                "wire_messages": msgs,
                "wire_bytes": nbytes,
                "bytes_per_query": nbytes / max(queries, 1),
                "messages_per_label": msgs / max(labels, 1),
                "labels_applied": labels,
            }
            d = row["transports"][transport]
            print(f"N={n} {transport:>14}: {d['steps_per_s']:>10,.0f} sps | "
                  f"{msgs:5d} msgs | {d['bytes_per_query']:7.1f} B/query | "
                  f"{d['messages_per_label']:.4f} msg/label")
        base = row["transports"]["per_tenant"]
        batched = row["transports"]["batched"]
        row["message_reduction_vs_per_tenant"] = (
            base["messages_per_label"] / batched["messages_per_label"]
        )
        row["throughput_vs_per_tenant"] = (
            batched["steps_per_s"] / base["steps_per_s"]
        )
        # Accounting survives loss + jitter + timeout across batching (the
        # per-run assert inside _run_once is the actual check).
        faults = {}
        for transport in ("per_tenant", "batched"):
            _, results, _, _ = _run_once(
                transport, cfg, tenant_data, capacity, timeout_s=0.5,
                window_s=window_s, batch_max=args.batch_max,
                loss=0.15, jitter_s=2e-3,
            )
            faults[transport] = {
                name: {
                    "queries_issued": r.stats.queries_issued,
                    "labels_applied": r.stats.labels_applied,
                    "queries_lost": r.stats.queries_lost,
                    "reconciled": r.stats.reconciled,
                }
                for name, r in sorted(results.items())
            }
            assert all(v["reconciled"] for v in faults[transport].values())
            assert any(v["queries_lost"] > 0 for v in faults[transport].values())
        row["faults"] = faults
        print(f"N={n}    batched vs per-tenant: "
              f"{row['message_reduction_vs_per_tenant']:.1f}x fewer msgs/label "
              f"at {100 * row['throughput_vs_per_tenant']:.1f}% throughput; "
              f"accounting reconciles under loss+jitter+timeout")
        rows.append(row)

    out = {"bench": "rpc", "backend": jax.default_backend(), "rows": rows}
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
