"""Fleet throughput: vmap-rank-1 baseline vs engine vs engine+kernel.

Measures stream-steps/second for T ticks of S concurrent ODL streams:

  * ``vmap``          — the pre-engine serving path: one jitted dispatch per
    tick doing fleet_predict + fleet_should_query + vmapped rank-1
    ``fleet_update`` (hidden projected twice, a (1, 1) solve per stream).
  * ``engine``        — ``repro.engine.run_fleet``: fused fleet_step scanned
    over time inside one donated jit call per chunk.
  * ``engine+kernel`` — same with ``use_kernel=True`` (the batched Pallas
    RLS entry; interpret mode on CPU, so S is capped — the number recorded
    validates the routing, not TPU speed).

``--mesh`` runs the mega-fleet scaling sweep instead (S up to 262,144
streams over the host's fleet mesh): single-device non-donated (the
committed "engine" rows' path) and donated references, the GSPMD
NamedSharding-placed resident fleet (``shard_fleet`` +
``run_fleet_sharded``), and the shard-local blocked path (``split_fleet``
+ ``run_fleet_shards``, one donated dispatch per 512-stream block — a
block's P slab stays cache-resident).  Every mesh mode is asserted
bit-for-bit against the single-device run at equal S before its
throughput is recorded.  On a CPU host force the device count first::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/fleet_bench.py --mesh

Writes BENCH_fleet.json next to the repo root (``--mesh`` merges a
``"mesh"`` section into it; ``--quick`` runs land in the bench artifact
dir instead — see ``benchmarks.common.bench_out_path``).

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] [--mesh]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning

try:
    from benchmarks import common
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import common

N_IN, N_HIDDEN, N_OUT = 64, 64, 6
KERNEL_S_CAP = 256  # interpret-mode Pallas iterates the stream grid in Python


def _cfg(use_kernel: bool = False) -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash",
            ridge=1e-2, use_kernel=use_kernel,
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in)))
    ys = jax.random.randint(ky, (t, s), 0, cfg.elm.n_out)
    return xs, ys


def _time(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def bench_vmap(cfg, xs, ys):
    """Tick-at-a-time vmap baseline (state pinned outside jit per tick)."""
    ecfg, pcfg = cfg.elm, cfg.prune
    s = xs.shape[1]

    @jax.jit
    def tick(elm, prune, x, y):
        preds, outs = oselm.fleet_predict(elm, x, ecfg)
        conf = pruning.confidence(outs)
        drift = jnp.zeros((s,), jnp.bool_)
        queried = pruning.fleet_should_query(prune, outs, elm.count, drift, pcfg)
        yoh = jax.nn.one_hot(y, ecfg.n_out)
        elm = oselm.fleet_update(elm, x, yoh, ecfg, mask=queried.astype(jnp.float32),
                                 use_kernel=False)
        prune = pruning.fleet_update(prune, queried, preds == y, conf, pcfg)
        return elm, prune

    def run(elm, prune):
        for t in range(xs.shape[0]):
            elm, prune = tick(elm, prune, xs[t], ys[t])
        return elm.beta

    elm0, prune0 = oselm.init_fleet(ecfg, s), pruning.init_fleet(s)
    dt, _ = _time(run, elm0, prune0)
    return dt


def bench_engine(cfg, xs, ys, chunk):
    def run(state):
        state, _ = engine.run_fleet(state, xs, ys, cfg, mode="train_phase", chunk=chunk)
        return state.elm.beta

    dt, _ = _time(run, engine.init_fleet(cfg, xs.shape[1]))
    return dt


def _time_fresh(run, make_state, iters):
    """Best-of-N wall time of ``run`` over a FRESH state per iteration —
    donated runs consume their input, and state build/placement is device
    setup, not steady-state throughput, so it stays untimed."""
    out = jax.block_until_ready(run(make_state()))  # compile + warm caches
    best = float("inf")
    for _ in range(iters):
        st = make_state()
        t0 = time.perf_counter()
        out = jax.block_until_ready(run(st))
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_mesh(quick: bool):
    """Mega-fleet scaling sweep over the host's fleet mesh; every mode's
    beta is asserted bitwise against the single-device run at equal S."""
    from repro.distributed import sharding
    from repro.engine.fleet import DEFAULT_STREAM_BLOCK
    from repro.launch import mesh as mesh_lib

    fleet_mesh = mesh_lib.make_fleet_mesh()
    n_dev = int(fleet_mesh.devices.size)
    sizes = [(2048, 4)] if quick else [(8192, 4), (65536, 4), (262144, 4)]
    rows = []
    print(f"== Mesh-sharded fleet ({n_dev}-device fleet mesh, "
          f"block={DEFAULT_STREAM_BLOCK}, n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        steps = t * s
        iters = 2 if s >= 200_000 else 3

        def make():
            return engine.init_fleet(cfg, s)

        def run_single(st, donate=None):
            return engine.run_fleet(
                st, xs, ys, cfg, mode="train_phase", chunk=t, donate=donate
            )[0]

        dt_base, st_ref = _time_fresh(run_single, make, iters)
        beta_ref = np.asarray(st_ref.elm.beta)
        del st_ref
        dt_don, st_don = _time_fresh(
            lambda st: run_single(st, donate=True), make, iters)
        assert np.array_equal(beta_ref, np.asarray(st_don.elm.beta)), (
            f"S={s}: donated single-device run diverged")
        del st_don

        with sharding.activate(fleet_mesh):
            def make_gspmd():
                return engine.shard_fleet(engine.init_fleet(cfg, s), cfg)[0]

            def run_gspmd(st):
                return engine.run_fleet_sharded(
                    st, xs, ys, cfg, mode="train_phase", chunk=t)[0]

            dt_gspmd, st_g = _time_fresh(run_gspmd, make_gspmd, iters)
            beta_g = np.asarray(jax.device_get(st_g.elm.beta))[:s]
            assert np.array_equal(beta_ref, beta_g), f"S={s}: gspmd diverged"
            del st_g, beta_g

            def make_shards():
                return engine.split_fleet(engine.init_fleet(cfg, s), cfg)

            def run_shards(sh):
                return engine.run_fleet_shards(
                    sh, xs, ys, cfg, mode="train_phase", chunk=t)[0]

            dt_shard, sh = _time_fresh(run_shards, make_shards, iters)
            merged = engine.merge_fleet(sh)
            assert np.array_equal(beta_ref, np.asarray(merged.elm.beta)), (
                f"S={s}: shard-local blocked run diverged")
            del sh, merged

        row = {
            "streams": s,
            "ticks": t,
            "devices": n_dev,
            "block": DEFAULT_STREAM_BLOCK,
            "n_hidden": N_HIDDEN,
            "single_streams_per_s": steps / dt_base,
            "single_donated_streams_per_s": steps / dt_don,
            "gspmd_streams_per_s": steps / dt_gspmd,
            "sharded_streams_per_s": steps / dt_shard,
            "sharded_speedup_vs_single": dt_base / dt_shard,
            "parity": "bitwise",
        }
        rows.append(row)
        print(
            f"S={s:6d} T={t}: single {row['single_streams_per_s']:>11,.0f} sps"
            f" | donated {row['single_donated_streams_per_s']:>11,.0f}"
            f" | gspmd {row['gspmd_streams_per_s']:>11,.0f}"
            f" | sharded[{DEFAULT_STREAM_BLOCK}] "
            f"{row['sharded_streams_per_s']:>11,.0f} sps "
            f"({row['sharded_speedup_vs_single']:.1f}x, parity bitwise)"
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes only")
    ap.add_argument("--mesh", action="store_true",
                    help="run the mega-fleet mesh scaling sweep instead "
                    "(force host devices via XLA_FLAGS on CPU)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    args.out = common.bench_out_path("fleet", args.quick, args.out)

    if args.mesh:
        mesh_rows = bench_mesh(args.quick)
        out_path = pathlib.Path(args.out)
        # Merge into the existing result so the standard rows survive.
        out = (json.loads(out_path.read_text())
               if out_path.exists() else {"bench": "fleet"})
        out["backend"] = jax.default_backend()
        out["mesh"] = {"devices": len(jax.devices()), "rows": mesh_rows}
        out_path.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {args.out}")
        return mesh_rows

    sizes = [(64, 32), (1024, 16)] if not args.quick else [(64, 8)]
    rows = []
    print(f"== Fleet throughput (n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        steps = t * s

        dt_vmap = bench_vmap(cfg, xs, ys)
        dt_eng = bench_engine(cfg, xs, ys, chunk=t)

        sk = min(s, KERNEL_S_CAP)
        kcfg = _cfg(use_kernel=True)
        dt_k = bench_engine(kcfg, xs[:, :sk], ys[:, :sk], chunk=t)
        k_sps = (t * sk) / dt_k

        row = {
            "streams": s,
            "ticks": t,
            "n_hidden": N_HIDDEN,
            "vmap_streams_per_s": steps / dt_vmap,
            "engine_streams_per_s": steps / dt_eng,
            "engine_kernel_streams": sk,
            "engine_kernel_streams_per_s": k_sps,
            "engine_speedup_vs_vmap": dt_vmap / dt_eng,
        }
        rows.append(row)
        print(
            f"S={s:5d} T={t:3d}: vmap {row['vmap_streams_per_s']:>12,.0f} sps | "
            f"engine {row['engine_streams_per_s']:>12,.0f} sps "
            f"({row['engine_speedup_vs_vmap']:.1f}x) | "
            f"engine+kernel[{sk}] {k_sps:>10,.0f} sps"
        )

    out_path = pathlib.Path(args.out)
    out = (json.loads(out_path.read_text())
           if out_path.exists() else {})  # keep an existing "mesh" section
    out.update({"bench": "fleet", "backend": jax.default_backend(), "rows": rows})
    out_path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
