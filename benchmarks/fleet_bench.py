"""Fleet throughput: vmap-rank-1 baseline vs engine vs engine+kernel.

Measures stream-steps/second for T ticks of S concurrent ODL streams:

  * ``vmap``          — the pre-engine serving path: one jitted dispatch per
    tick doing fleet_predict + fleet_should_query + vmapped rank-1
    ``fleet_update`` (hidden projected twice, a (1, 1) solve per stream).
  * ``engine``        — ``repro.engine.run_fleet``: fused fleet_step scanned
    over time inside one donated jit call per chunk.
  * ``engine+kernel`` — same with ``use_kernel=True`` (the batched Pallas
    RLS entry; interpret mode on CPU, so S is capped — the number recorded
    validates the routing, not TPU speed).

Writes BENCH_fleet.json next to the repo root.

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning

N_IN, N_HIDDEN, N_OUT = 64, 64, 6
KERNEL_S_CAP = 256  # interpret-mode Pallas iterates the stream grid in Python


def _cfg(use_kernel: bool = False) -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash",
            ridge=1e-2, use_kernel=use_kernel,
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in)))
    ys = jax.random.randint(ky, (t, s), 0, cfg.elm.n_out)
    return xs, ys


def _time(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def bench_vmap(cfg, xs, ys):
    """Tick-at-a-time vmap baseline (state pinned outside jit per tick)."""
    ecfg, pcfg = cfg.elm, cfg.prune
    s = xs.shape[1]

    @jax.jit
    def tick(elm, prune, x, y):
        preds, outs = oselm.fleet_predict(elm, x, ecfg)
        conf = pruning.confidence(outs)
        drift = jnp.zeros((s,), jnp.bool_)
        queried = pruning.fleet_should_query(prune, outs, elm.count, drift, pcfg)
        yoh = jax.nn.one_hot(y, ecfg.n_out)
        elm = oselm.fleet_update(elm, x, yoh, ecfg, mask=queried.astype(jnp.float32),
                                 use_kernel=False)
        prune = pruning.fleet_update(prune, queried, preds == y, conf, pcfg)
        return elm, prune

    def run(elm, prune):
        for t in range(xs.shape[0]):
            elm, prune = tick(elm, prune, xs[t], ys[t])
        return elm.beta

    elm0, prune0 = oselm.init_fleet(ecfg, s), pruning.init_fleet(s)
    dt, _ = _time(run, elm0, prune0)
    return dt


def bench_engine(cfg, xs, ys, chunk):
    def run(state):
        state, _ = engine.run_fleet(state, xs, ys, cfg, mode="train_phase", chunk=chunk)
        return state.elm.beta

    dt, _ = _time(run, engine.init_fleet(cfg, xs.shape[1]))
    return dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes only")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_fleet_quick.json" if args.quick else "BENCH_fleet.json"
        args.out = str(pathlib.Path(__file__).resolve().parent.parent / name)

    sizes = [(64, 32), (1024, 16)] if not args.quick else [(64, 8)]
    rows = []
    print(f"== Fleet throughput (n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        steps = t * s

        dt_vmap = bench_vmap(cfg, xs, ys)
        dt_eng = bench_engine(cfg, xs, ys, chunk=t)

        sk = min(s, KERNEL_S_CAP)
        kcfg = _cfg(use_kernel=True)
        dt_k = bench_engine(kcfg, xs[:, :sk], ys[:, :sk], chunk=t)
        k_sps = (t * sk) / dt_k

        row = {
            "streams": s,
            "ticks": t,
            "n_hidden": N_HIDDEN,
            "vmap_streams_per_s": steps / dt_vmap,
            "engine_streams_per_s": steps / dt_eng,
            "engine_kernel_streams": sk,
            "engine_kernel_streams_per_s": k_sps,
            "engine_speedup_vs_vmap": dt_vmap / dt_eng,
        }
        rows.append(row)
        print(
            f"S={s:5d} T={t:3d}: vmap {row['vmap_streams_per_s']:>12,.0f} sps | "
            f"engine {row['engine_streams_per_s']:>12,.0f} sps "
            f"({row['engine_speedup_vs_vmap']:.1f}x) | "
            f"engine+kernel[{sk}] {k_sps:>10,.0f} sps"
        )

    out = {"bench": "fleet", "backend": jax.default_backend(), "rows": rows}
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
