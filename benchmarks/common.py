"""Shared harness for the paper-table benchmarks."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odl_head, oselm, pruning
from repro.data import har


def timer_us(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def boot_core(splits, run_seed: int, theta, n_hidden: int = 128, variant: str = "hash"):
    """Initial-training boot of the paper's core (§3 steps 1-2)."""
    elm_cfg = oselm.OSELMConfig(
        n_in=har.N_FEATURES, n_hidden=n_hidden, n_out=har.N_CLASSES,
        variant=variant, seed=run_seed + 77, ridge=1e-2,
    )
    if theta == "auto":
        pcfg = pruning.PruneConfig(min_trained=max(n_hidden, 288))
    else:
        pcfg = pruning.PruneConfig(ladder=(float(theta),), min_trained=max(n_hidden, 288))
    cfg = odl_head.ODLCoreConfig(elm=elm_cfg, prune=pcfg)
    st0 = oselm.init_state_batch(
        elm_cfg, jnp.asarray(splits.train_x), jax.nn.one_hot(splits.train_y, har.N_CLASSES)
    )
    return cfg, odl_head.init_state(cfg)._replace(elm=st0)


def drift_trial(run_seed: int, theta, n_hidden: int = 128, variant: str = "hash",
                dataset_seed: int = 0):
    """One full §3 protocol run; returns dict of accuracies + comm volume."""
    splits = har.generate(seed=dataset_seed)
    cfg, core = boot_core(splits, run_seed, theta, n_hidden, variant)
    ox, oy, tx, ty = har.odl_split(splits, 0.6, run_seed)

    before = float(odl_head.accuracy(
        core, jnp.asarray(splits.test0_x), jnp.asarray(splits.test0_y), cfg))
    noodl_after = float(odl_head.accuracy(core, jnp.asarray(tx), jnp.asarray(ty), cfg))

    core, outs = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
        core, jnp.asarray(ox), jnp.asarray(oy)
    )
    after = float(odl_head.accuracy(core, jnp.asarray(tx), jnp.asarray(ty), cfg))
    comm = float(pruning.comm_volume_fraction(core.prune))
    return dict(before=before, after=after, noodl_after=noodl_after, comm=comm,
                queries=int(core.prune.queries), skips=int(core.prune.skips))


def mean_std(rows, key):
    v = np.asarray([r[key] for r in rows])
    return float(v.mean()), float(v.std())
