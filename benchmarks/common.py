"""Shared harness for the paper-table benchmarks (engine-backed)."""

from __future__ import annotations

import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import oselm, pruning
from repro.data import har

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def bench_out_path(name: str, quick: bool = False, override=None) -> str:
    """Where a benchmark writes its JSON result.

    Full runs are the committed reference baselines (``BENCH_<name>.json``
    at the repo root).  ``--quick`` runs are the CI smoke on shared
    runners — noisy numbers that must NOT clobber the committed
    ``BENCH_<name>_quick.json`` reference baselines — so they land in an
    artifact directory instead: ``$BENCH_ARTIFACT_DIR`` if set, else
    ``<repo>/bench_artifacts/`` (gitignored; CI uploads it), created on
    demand.  ``override`` (a bench's ``--out``) wins over everything.
    """
    if override:
        return str(override)
    if not quick:
        return str(_REPO_ROOT / f"BENCH_{name}.json")
    art = pathlib.Path(
        os.environ.get("BENCH_ARTIFACT_DIR", str(_REPO_ROOT / "bench_artifacts"))
    )
    art.mkdir(parents=True, exist_ok=True)
    return str(art / f"BENCH_{name}_quick.json")


def timer_us(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def boot_core(splits, run_seed: int, theta, n_hidden: int = 128, variant: str = "hash"):
    """Initial-training boot of the paper's core (§3 steps 1-2); returns an
    axis-free (single-head) engine state."""
    elm_cfg = oselm.OSELMConfig(
        n_in=har.N_FEATURES, n_hidden=n_hidden, n_out=har.N_CLASSES,
        variant=variant, seed=run_seed + 77, ridge=1e-2,
    )
    if theta == "auto":
        pcfg = pruning.PruneConfig(min_trained=max(n_hidden, 288))
    else:
        pcfg = pruning.PruneConfig(ladder=(float(theta),), min_trained=max(n_hidden, 288))
    cfg = engine.EngineConfig(elm=elm_cfg, prune=pcfg)
    st0 = oselm.init_state_batch(
        elm_cfg, jnp.asarray(splits.train_x), jax.nn.one_hot(splits.train_y, har.N_CLASSES)
    )
    return cfg, engine.init_state(cfg)._replace(elm=st0)


def drift_trial(run_seed: int, theta, n_hidden: int = 128, variant: str = "hash",
                dataset_seed: int = 0):
    """One full §3 protocol run; returns dict of accuracies + comm volume.

    The retraining phase is a one-stream ``engine.run_fleet`` (the same
    state machine the fleet/serving paths use at S=thousands).
    """
    splits = har.generate(seed=dataset_seed)
    cfg, core = boot_core(splits, run_seed, theta, n_hidden, variant)
    ox, oy, tx, ty = har.odl_split(splits, 0.6, run_seed)

    fleet = engine.broadcast_streams(core, 1)

    def acc(state, x, y):
        return float(engine.fleet_accuracy(state, jnp.asarray(x), jnp.asarray(y), cfg)[0])

    before = acc(fleet, splits.test0_x, splits.test0_y)
    noodl_after = acc(fleet, tx, ty)

    # Paper §3 step 3: new training phase (re-arm pruning condition 1).
    fleet = fleet._replace(prune=pruning.reset_phase(fleet.prune))
    fleet, _ = engine.run_fleet(
        fleet, jnp.asarray(ox)[:, None], jnp.asarray(oy, jnp.int32)[:, None],
        cfg, mode="train_phase",
    )
    after = acc(fleet, tx, ty)
    prune_one = jax.tree.map(lambda a: a[0], fleet.prune)
    comm = float(pruning.comm_volume_fraction(prune_one))
    return dict(before=before, after=after, noodl_after=noodl_after, comm=comm,
                queries=int(prune_one.queries), skips=int(prune_one.skips))


def mean_std(rows, key):
    v = np.asarray([r[key] for r in rows])
    return float(v.mean()), float(v.std())
