"""Snapshot overhead: durable sessions vs the plain streaming runtime.

Measures, at S=512 (quick: S=16):

  * ``baseline``  — ``stream``-driven session, no snapshots;
  * ``durable``   — the same session with a full-fidelity snapshot
    (``engine/snapshot.py``) captured and published through
    ``CheckpointManager.save_async`` every 1000 ticks (quick: 64);
  * per-snapshot *pause*: the synchronous part of a snapshot — capture
    (device→host copy of EngineState + ring context) plus the async-write
    handoff — which is the only time the tick loop actually stops.

Acceptance (ISSUE 4): steady-state durable throughput within 5% of
baseline at the 1k-tick cadence.  Writes BENCH_snapshot.json next to
the repo root (``--quick`` writes to the bench artifact dir, not the
committed baseline; see benchmarks.common.bench_out_path).

Run:  PYTHONPATH=src python benchmarks/snapshot_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import stream
from repro.runtime.checkpoint import CheckpointManager

try:
    from benchmarks import common
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import common

N_IN, N_HIDDEN, N_OUT = 64, 64, 6


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    xs = np.asarray(jax.numpy.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in))))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return [x for x in xs], ys


def _run_once(cfg, xs_host, ys, snapshot_every, snapshot_dir):
    """One full stream pass; returns (wall_s, [pause_s per snapshot])."""
    sess = stream.StreamSession(
        engine.init_fleet(cfg, xs_host[0].shape[0]), cfg,
        stream.LatencyTeacher(stream.array_labels(ys), latency=0),
        mode="train_phase", collect=False,
    )
    manager = (
        CheckpointManager(snapshot_dir, keep=2) if snapshot_every else None
    )
    pauses = []
    last_snap = 0
    t0 = time.perf_counter()
    it = iter(xs_host)
    sess.start(next(it))
    while sess._p is not None:
        sess.advance(next(it, None))
        if snapshot_every and sess.t - last_snap >= snapshot_every:
            p0 = time.perf_counter()
            manager.save_async(sess.t, sess.snapshot())
            pauses.append(time.perf_counter() - p0)
            last_snap = sess.t
    if manager is not None:
        manager.wait()
    state, _, stats = sess.finish()
    jax.block_until_ready(state.elm.beta)
    dt = time.perf_counter() - t0
    assert stats.reconciled, stats.summary()
    return dt, pauses


def bench(cfg, xs_host, ys, snapshot_every, snapshot_dir, iters):
    _run_once(cfg, xs_host, ys, 0, snapshot_dir)  # warmup/compile
    best_base = best_dur = float("inf")
    all_pauses = []
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            best_base = min(best_base, _run_once(cfg, xs_host, ys, 0, None)[0])
            dt, pauses = _run_once(cfg, xs_host, ys, snapshot_every, snapshot_dir)
            best_dur = min(best_dur, dt)
            all_pauses.extend(pauses)
    finally:
        gc.enable()
    return best_base, best_dur, all_pauses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: S=16, T=256, cadence 64")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    args.out = common.bench_out_path("snapshot", args.quick, args.out)

    s, t, cadence = (16, 256, 64) if args.quick else (512, 2500, 1000)
    cfg = _cfg()
    xs_host, ys = _data(t, s, cfg, seed=0)
    print(f"== Snapshot overhead (S={s}, T={t}, cadence={cadence}, "
          f"n_in={N_IN}, N={N_HIDDEN}) ==")
    with tempfile.TemporaryDirectory(prefix="snap_bench_") as d:
        best_base, best_dur, pauses = bench(
            cfg, xs_host, ys, cadence, d, args.iters
        )
    steps = t * s
    base_sps, dur_sps = steps / best_base, steps / best_dur
    overhead = 1.0 - dur_sps / base_sps
    pause_ms = sorted(p * 1e3 for p in pauses)
    row = {
        "streams": s,
        "ticks": t,
        "snapshot_every": cadence,
        "n_hidden": N_HIDDEN,
        "baseline_steps_per_s": base_sps,
        "durable_steps_per_s": dur_sps,
        "overhead_fraction": overhead,
        "snapshots_per_run": len(pause_ms) // max(args.iters, 1),
        "snapshot_pause_ms_p50": float(np.percentile(pause_ms, 50)) if pause_ms else 0.0,
        "snapshot_pause_ms_max": max(pause_ms) if pause_ms else 0.0,
    }
    print(f"baseline {base_sps:>12,.0f} sps | durable {dur_sps:>12,.0f} sps "
          f"({100 * (1 - overhead):5.1f}%); snapshot pause p50/max "
          f"{row['snapshot_pause_ms_p50']:.2f}/{row['snapshot_pause_ms_max']:.2f} ms")
    target = 0.05
    if args.quick:
        # The smoke shape snapshots every 64 sub-millisecond ticks — far off
        # the acceptance cadence; it only proves the path end to end.
        print(f"steady-state overhead {100 * overhead:.2f}% "
              f"(quick smoke; the <{100 * target:.0f}% target applies to the "
              f"full S=512 / 1k-cadence run)")
    else:
        verdict = "PASS" if overhead < target else "FAIL"
        print(f"steady-state overhead {100 * overhead:.2f}% "
              f"(target < {100 * target:.0f}%): {verdict}")
    out = {
        "bench": "snapshot",
        "backend": jax.default_backend(),
        "target_overhead": target,
        "rows": [row],
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return row


if __name__ == "__main__":
    main()
