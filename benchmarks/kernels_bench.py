"""Kernel microbenchmarks: wall time of jitted ops on CPU (interpret-mode
kernels are validated for correctness; wall numbers here compare the
kernel-structured path against the pure-jnp oracle at equal math) plus the
analytic HBM-traffic advantage of ODLHash on TPU (alpha generated in VMEM)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timer_us
from repro.kernels import ref


def main():
    rows = []
    print("\n== Kernel microbench (CPU wall time; TPU traffic analytic) ==")
    for b, n_in, n_hidden in ((8, 561, 128), (64, 561, 256), (256, 1024, 1024)):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, n_in))
        f_ref = jax.jit(lambda x: ref.xorshift_projection_ref(x, 7, n_hidden))
        us = timer_us(f_ref, x)
        # HBM bytes on TPU: stored-alpha streams 4*n_in*n_hidden per call;
        # hashed generation streams zero (alpha lives only in VMEM).
        alpha_bytes = 4 * n_in * n_hidden
        io_bytes = 4 * (b * n_in + b * n_hidden)
        rows.append((f"kernels/xorshift_proj/{b}x{n_in}x{n_hidden}_us", us,
                     f"alpha_hbm_bytes_saved={alpha_bytes} io={io_bytes}"))
        print(f"xorshift_proj {b}x{n_in}x{n_hidden}: {us:9.1f} us/call "
              f"(saves {alpha_bytes/1e3:.0f} kB alpha HBM traffic/call on TPU)")

    for n, k in ((128, 1), (128, 8), (512, 32)):
        key = jax.random.PRNGKey(1)
        p = jnp.eye(n) * 0.5
        beta = jnp.zeros((n, 6))
        h = jax.nn.sigmoid(jax.random.normal(key, (k, n)))
        y = jax.nn.one_hot(jnp.arange(k) % 6, 6)
        f = jax.jit(lambda p, b_, h_, y_: ref.oselm_rls_update_ref(p, b_, h_, y_))
        us = timer_us(f, p, beta, h, y)
        # Fused kernel reads/writes P once instead of twice: saves 8*N^2 B.
        rows.append((f"kernels/oselm_rls/N{n}_k{k}_us", us,
                     f"fused_P_traffic_saved_bytes={8*n*n}"))
        print(f"oselm_rls N={n} k={k}: {us:9.1f} us/call "
              f"(fusion saves {8*n*n/1e3:.0f} kB P traffic/update on TPU)")
    return rows


if __name__ == "__main__":
    main()
