"""Streaming runtime vs materialized run_fleet throughput.

Measures stream-steps/second for T ticks of S concurrent ODL streams:

  * ``run_fleet``  — the offline baseline: the whole (T, S, n_in) stream
    materialized (np.stack + device transfer, timed — both runtimes are fed
    the same host-side tick source, and run_fleet cannot start until the
    full array exists), then one jit dispatch per chunk (same-tick labels).
  * ``stream``     — ``engine.stream.run`` fed one tick at a time from an
    iterator, with a ``LatencyTeacher`` answering after 0 / 4 / 16 ticks:
    per-tick fused learn+plan dispatches, pending-query ring,
    double-buffered host ingestion.  At latency 0 the two produce
    bit-identical state (tests/test_stream.py); the interesting number is
    how little the per-tick dispatch + teacher round-trip costs.

Both sides report best-of-N wall time (the container's scheduling noise
otherwise swamps the ~10% effect being measured).

Writes BENCH_stream.json next to the repo root.

Run:  PYTHONPATH=src python benchmarks/stream_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import stream

N_IN, N_HIDDEN, N_OUT = 64, 64, 6
LATENCIES = (0, 4, 16)


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in)))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return xs, ys


def _fleet_once(cfg, xs_host, ys):
    t = len(xs_host)

    def run(state):
        # The offline path's first step IS materialization: assemble the
        # (T, S, n_in) array from the host tick stream and ship it.
        xs = jnp.asarray(np.stack(xs_host))
        state, _ = engine.run_fleet(
            state, xs, jnp.asarray(ys), cfg, mode="train_phase", chunk=t
        )
        return state.elm.beta

    t0 = time.perf_counter()
    jax.block_until_ready(run(engine.init_fleet(cfg, xs_host[0].shape[0])))
    return time.perf_counter() - t0


def _stream_once(cfg, xs_host, ys, latency):
    t = len(xs_host)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=latency)
    t0 = time.perf_counter()
    state, _, stats = stream.run(
        engine.init_fleet(cfg, xs_host[0].shape[0]),
        (xs_host[i] for i in range(t)),
        cfg, teacher, mode="train_phase", capacity=max(4 * latency, 8),
        collect=False,
    )
    jax.block_until_ready(state.elm.beta)
    return time.perf_counter() - t0, stats


def bench_pair(cfg, xs, ys, latency, iters=8):
    """Best-of-N for both sides, *interleaved* — the container's scheduling
    drifts on a scale of seconds, so measuring the two sides back-to-back
    within each iteration exposes them to the same machine state.  GC is
    paused during the timed region (gen-2 collections over the per-tick
    array churn otherwise inject multi-ms pauses into single iterations)."""
    # Ticks arrive as host arrays (the streaming deployment story); the
    # stream runtime ingests them tick by tick, the offline baseline
    # stacks them into one array first.
    xs_host = [np.asarray(x) for x in np.asarray(xs)]
    _fleet_once(cfg, xs_host, ys)  # warmup (chunk runner compile)
    _stream_once(cfg, xs_host, ys, latency)  # warmup (plan/learn/fused compile)
    best_fleet = best_stream = float("inf")
    best_stats = None
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            best_fleet = min(best_fleet, _fleet_once(cfg, xs_host, ys))
            dt, stats = _stream_once(cfg, xs_host, ys, latency)
            if dt < best_stream:
                best_stream, best_stats = dt, stats
    finally:
        gc.enable()
    return best_fleet, best_stream, best_stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes only (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_stream_quick.json" if args.quick else "BENCH_stream.json"
        args.out = str(pathlib.Path(__file__).resolve().parent.parent / name)

    sizes = [(64, 64)] if args.quick else [(1024, 128)]
    rows = []
    print(f"== Streaming runtime throughput (n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        steps = t * s

        print(f"S={s:5d} T={t:3d}:")
        for lat in LATENCIES:
            dt_fleet, dt_s, stats = bench_pair(cfg, xs, ys, lat)
            base_sps = steps / dt_fleet
            sps = steps / dt_s
            rows.append({
                "streams": s,
                "ticks": t,
                "n_hidden": N_HIDDEN,
                "teacher_latency_ticks": lat,
                "run_fleet_steps_per_s": base_sps,
                "stream_steps_per_s": sps,
                "stream_vs_run_fleet": sps / base_sps,
                "tick_p50_ms": stats.tick_p50_ms,
                "tick_p95_ms": stats.tick_p95_ms,
                "labels_applied": stats.labels_applied,
                "queries_issued": stats.queries_issued,
                "tickets_dropped": stats.tickets_dropped,
            })
            print(f"  lat={lat:2d}: run_fleet {base_sps:>11,.0f} sps | "
                  f"stream {sps:>11,.0f} sps ({100 * sps / base_sps:5.1f}%) | "
                  f"tick p50/p95 {stats.tick_p50_ms:.2f}/{stats.tick_p95_ms:.2f} ms | "
                  f"labels {stats.labels_applied}/{stats.queries_issued}")

    out = {"bench": "stream", "backend": jax.default_backend(), "rows": rows}
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
