"""Streaming runtime vs materialized run_fleet throughput.

Measures stream-steps/second for T ticks of S concurrent ODL streams:

  * ``run_fleet``  — the offline baseline: the whole (T, S, n_in) stream
    materialized (np.stack + device transfer, timed — both runtimes are fed
    the same host-side tick source, and run_fleet cannot start until the
    full array exists), then one jit dispatch per chunk (same-tick labels).
  * ``stream``     — ``engine.stream.run`` fed one tick at a time from an
    iterator, with a ``LatencyTeacher`` answering after 0 / 4 / 16 ticks:
    per-tick fused learn+plan dispatches, pending-query ring,
    double-buffered host ingestion.  At latency 0 the two produce
    bit-identical state (tests/test_stream.py); the interesting number is
    how little the per-tick dispatch + teacher round-trip costs.

Both sides report best-of-N wall time (the container's scheduling noise
otherwise swamps the ~10% effect being measured).

``--mesh`` runs the mega-fleet comparison instead: solo ``stream.run``
vs ``stream.run_sharded`` over the host's fleet mesh — one shard-local
session (ring + teacher + dispatch) per device, labels learning back only
into the shard that planned them.  The sharded state is asserted
bit-for-bit against the solo run at equal S before throughput is
recorded.  On CPU force devices first::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/stream_bench.py --mesh

Writes BENCH_stream.json next to the repo root (``--mesh`` merges a
``"mesh"`` section; ``--quick`` runs land in the bench artifact dir —
see ``benchmarks.common.bench_out_path``).

Run:  PYTHONPATH=src python benchmarks/stream_bench.py [--quick] [--mesh]
"""

from __future__ import annotations

import argparse
import gc
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.engine import stream

try:
    from benchmarks import common
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    import common

N_IN, N_HIDDEN, N_OUT = 64, 64, 6
LATENCIES = (0, 4, 16)


def _cfg() -> engine.EngineConfig:
    return engine.EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=N_IN, n_hidden=N_HIDDEN, n_out=N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=8),
        drift=drift_mod.DriftConfig(),
    )


def _data(t, s, cfg):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jnp.tanh(jax.random.normal(kx, (t, s, cfg.elm.n_in)))
    ys = np.asarray(jax.random.randint(ky, (t, s), 0, cfg.elm.n_out), np.int32)
    return xs, ys


def _fleet_once(cfg, xs_host, ys):
    t = len(xs_host)

    def run(state):
        # The offline path's first step IS materialization: assemble the
        # (T, S, n_in) array from the host tick stream and ship it.
        xs = jnp.asarray(np.stack(xs_host))
        state, _ = engine.run_fleet(
            state, xs, jnp.asarray(ys), cfg, mode="train_phase", chunk=t
        )
        return state.elm.beta

    t0 = time.perf_counter()
    jax.block_until_ready(run(engine.init_fleet(cfg, xs_host[0].shape[0])))
    return time.perf_counter() - t0


def _stream_once(cfg, xs_host, ys, latency):
    t = len(xs_host)
    teacher = stream.LatencyTeacher(stream.array_labels(ys), latency=latency)
    t0 = time.perf_counter()
    state, _, stats = stream.run(
        engine.init_fleet(cfg, xs_host[0].shape[0]),
        (xs_host[i] for i in range(t)),
        cfg, teacher, mode="train_phase", capacity=max(4 * latency, 8),
        collect=False,
    )
    jax.block_until_ready(state.elm.beta)
    return time.perf_counter() - t0, stats


def bench_pair(cfg, xs, ys, latency, iters=8):
    """Best-of-N for both sides, *interleaved* — the container's scheduling
    drifts on a scale of seconds, so measuring the two sides back-to-back
    within each iteration exposes them to the same machine state.  GC is
    paused during the timed region (gen-2 collections over the per-tick
    array churn otherwise inject multi-ms pauses into single iterations)."""
    # Ticks arrive as host arrays (the streaming deployment story); the
    # stream runtime ingests them tick by tick, the offline baseline
    # stacks them into one array first.
    xs_host = [np.asarray(x) for x in np.asarray(xs)]
    _fleet_once(cfg, xs_host, ys)  # warmup (chunk runner compile)
    _stream_once(cfg, xs_host, ys, latency)  # warmup (plan/learn/fused compile)
    best_fleet = best_stream = float("inf")
    best_stats = None
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            best_fleet = min(best_fleet, _fleet_once(cfg, xs_host, ys))
            dt, stats = _stream_once(cfg, xs_host, ys, latency)
            if dt < best_stream:
                best_stream, best_stats = dt, stats
    finally:
        gc.enable()
    return best_fleet, best_stream, best_stats


# The telemetry hard budget: instrumented steady-state throughput must stay
# within this fraction of the uninstrumented run (ISSUE 9's <2% gate,
# asserted in --quick so CI holds the line).
TELEMETRY_MAX_OVERHEAD = 0.02


def bench_telemetry(cfg, xs, ys, latency=4, iters=8):
    """Telemetry overhead: the SAME stream workload with telemetry off vs
    on (full-rate spans, finish-time counter mirroring), interleaved
    best-of-N in one process — an honest apples-to-apples ratio, unlike
    comparing absolute sps against a baseline measured on other hardware.
    Returns ``(off_sps, on_sps, overhead_frac)``."""
    from repro.runtime import telemetry

    xs_host = [np.asarray(x) for x in np.asarray(xs)]
    telemetry.disable()
    _stream_once(cfg, xs_host, ys, latency)  # warmup (compiles)
    best_off = best_on = float("inf")
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            telemetry.disable()
            dt, _ = _stream_once(cfg, xs_host, ys, latency)
            best_off = min(best_off, dt)
            telemetry.enable()
            dt, _ = _stream_once(cfg, xs_host, ys, latency)
            best_on = min(best_on, dt)
    finally:
        gc.enable()
        telemetry.disable()
    steps = len(xs_host) * xs_host[0].shape[0]
    overhead = best_on / best_off - 1.0
    return steps / best_off, steps / best_on, overhead


def _sharded_once(cfg, xs_host, ys, latency, fleet_mesh):
    """One timed ``run_sharded`` pass over the fleet mesh: shard-local
    LatencyTeachers answer from each shard's row window of ``ys``."""
    from repro.distributed import sharding

    t = len(xs_host)
    s = xs_host[0].shape[0]
    with sharding.activate(fleet_mesh):
        n_shards = sharding.fleet_axis_size()
        width = (s + (-s) % n_shards) // n_shards

        def make_teacher(k):
            lo = min(k * width, s)
            hi = min(lo + width, s)
            return stream.LatencyTeacher(
                stream.array_labels(ys[:, lo:hi]), latency=latency
            )

        t0 = time.perf_counter()
        state, _, stats_list = stream.run_sharded(
            engine.init_fleet(cfg, s),
            (xs_host[i] for i in range(t)),
            cfg, make_teacher, mode="train_phase",
            capacity=max(4 * latency, 8), collect=False,
        )
        jax.block_until_ready(jax.tree.leaves(state))
        dt = time.perf_counter() - t0
    return dt, state, stats_list


def bench_mesh(quick: bool):
    """Solo ``stream.run`` vs mesh-sharded ``stream.run_sharded`` —
    interleaved best-of-N, sharded state asserted bitwise vs solo."""
    from repro.launch import mesh as mesh_lib

    fleet_mesh = mesh_lib.make_fleet_mesh()
    n_dev = int(fleet_mesh.devices.size)
    sizes = [(512, 8)] if quick else [(2048, 64), (8192, 32)]
    iters = 2 if quick else 4
    rows = []
    print(f"== Mesh-sharded streaming runtime ({n_dev}-device fleet mesh, "
          f"n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        xs_host = [np.asarray(x) for x in np.asarray(xs)]
        steps = t * s
        print(f"S={s:5d} T={t:3d}:")
        for lat in (0, 4):
            # Warmup both sides (compiles) + the parity lock: same ticks,
            # same deterministic lossless teacher, equal S -> the merged
            # sharded state must be bit-for-bit the solo one.
            _, solo_stats = _stream_once(cfg, xs_host, ys, lat)
            solo_state, _, _ = stream.run(
                engine.init_fleet(cfg, s), (x for x in xs_host), cfg,
                stream.LatencyTeacher(stream.array_labels(ys), latency=lat),
                mode="train_phase", capacity=max(4 * lat, 8), collect=False,
            )
            _, sharded_state, stats_list = _sharded_once(
                cfg, xs_host, ys, lat, fleet_mesh)
            assert np.array_equal(
                np.asarray(solo_state.elm.beta),
                np.asarray(sharded_state.elm.beta),
            ), f"S={s} lat={lat}: sharded stream diverged from solo"
            del solo_state, sharded_state

            best_solo = best_sharded = float("inf")
            gc.collect()
            gc.disable()
            try:
                for _ in range(iters):
                    dt, _ = _stream_once(cfg, xs_host, ys, lat)
                    best_solo = min(best_solo, dt)
                    dt, _, stats_list = _sharded_once(
                        cfg, xs_host, ys, lat, fleet_mesh)
                    best_sharded = min(best_sharded, dt)
            finally:
                gc.enable()
            agg = stream.aggregate_stats(stats_list)
            rows.append({
                "streams": s,
                "ticks": t,
                "devices": n_dev,
                "n_hidden": N_HIDDEN,
                "teacher_latency_ticks": lat,
                "solo_steps_per_s": steps / best_solo,
                "sharded_steps_per_s": steps / best_sharded,
                "sharded_vs_solo": best_solo / best_sharded,
                "labels_applied": agg["labels_applied"],
                "queries_issued": agg["queries_issued"],
                "parity": "bitwise",
            })
            print(f"  lat={lat:2d}: solo {steps / best_solo:>11,.0f} sps | "
                  f"sharded {steps / best_sharded:>11,.0f} sps "
                  f"({best_solo / best_sharded:.2f}x, parity bitwise) | "
                  f"labels {agg['labels_applied']}/{agg['queries_issued']}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes only (CI smoke)")
    ap.add_argument("--mesh", action="store_true",
                    help="solo vs mesh-sharded streaming sweep instead "
                    "(force host devices via XLA_FLAGS on CPU)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    args.out = common.bench_out_path("stream", args.quick, args.out)

    if args.mesh:
        mesh_rows = bench_mesh(args.quick)
        out_path = pathlib.Path(args.out)
        out = (json.loads(out_path.read_text())
               if out_path.exists() else {"bench": "stream"})
        out["backend"] = jax.default_backend()
        out["mesh"] = {"devices": len(jax.devices()), "rows": mesh_rows}
        out_path.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {args.out}")
        return mesh_rows

    sizes = [(64, 64)] if args.quick else [(1024, 128)]
    rows = []
    print(f"== Streaming runtime throughput (n_in={N_IN}, N={N_HIDDEN}) ==")
    for s, t in sizes:
        cfg = _cfg()
        xs, ys = _data(t, s, cfg)
        steps = t * s

        print(f"S={s:5d} T={t:3d}:")
        for lat in LATENCIES:
            dt_fleet, dt_s, stats = bench_pair(cfg, xs, ys, lat)
            base_sps = steps / dt_fleet
            sps = steps / dt_s
            rows.append({
                "streams": s,
                "ticks": t,
                "n_hidden": N_HIDDEN,
                "teacher_latency_ticks": lat,
                "run_fleet_steps_per_s": base_sps,
                "stream_steps_per_s": sps,
                "stream_vs_run_fleet": sps / base_sps,
                "tick_p50_ms": stats.tick_p50_ms,
                "tick_p95_ms": stats.tick_p95_ms,
                "labels_applied": stats.labels_applied,
                "queries_issued": stats.queries_issued,
                "tickets_dropped": stats.tickets_dropped,
            })
            print(f"  lat={lat:2d}: run_fleet {base_sps:>11,.0f} sps | "
                  f"stream {sps:>11,.0f} sps ({100 * sps / base_sps:5.1f}%) | "
                  f"tick p50/p95 {stats.tick_p50_ms:.2f}/{stats.tick_p95_ms:.2f} ms | "
                  f"labels {stats.labels_applied}/{stats.queries_issued}")

    # Telemetry overhead gate: same workload, registry+tracer off vs on.
    off_sps, on_sps, overhead = bench_telemetry(
        cfg, xs, ys, iters=4 if args.quick else 8)
    print(f"telemetry: off {off_sps:>11,.0f} sps | on {on_sps:>11,.0f} sps "
          f"({100 * overhead:+.2f}% overhead, budget "
          f"{100 * TELEMETRY_MAX_OVERHEAD:.0f}%)")
    telemetry_row = {
        "telemetry_off_steps_per_s": off_sps,
        "telemetry_on_steps_per_s": on_sps,
        "telemetry_overhead": overhead,
        "telemetry_budget": TELEMETRY_MAX_OVERHEAD,
    }
    if args.quick and overhead > TELEMETRY_MAX_OVERHEAD:
        raise SystemExit(
            f"telemetry overhead {100 * overhead:.2f}% exceeds the "
            f"{100 * TELEMETRY_MAX_OVERHEAD:.0f}% budget")

    out_path = pathlib.Path(args.out)
    out = (json.loads(out_path.read_text())
           if out_path.exists() else {})  # keep an existing "mesh" section
    out.update({"bench": "stream", "backend": jax.default_backend(),
                "rows": rows, "telemetry": telemetry_row})
    out_path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
