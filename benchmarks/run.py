"""Benchmark entry point: one function per paper table/figure + roofline.

Usage:  PYTHONPATH=src python -m benchmarks.run [--trials N] [--skip-drift]

Prints human-readable blocks followed by a ``name,value,derived`` CSV (the
repo harness convention).  Roofline rows appear when results/dryrun.jsonl
exists (produced by ``python -m repro.launch.dryrun``).
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=5,
                    help="drift-protocol repetitions (paper uses 20)")
    ap.add_argument("--skip-drift", action="store_true",
                    help="skip the minutes-long accuracy experiments")
    args = ap.parse_args(argv)

    from benchmarks import fleet_bench, kernels_bench, paper_tables, roofline

    rows = []
    rows += paper_tables.table1_memory()
    rows += paper_tables.table4_core()
    rows += kernels_bench.main()
    rows += [
        (f"fleet/S{r['streams']}_engine_sps", r["engine_streams_per_s"],
         f"vmap={r['vmap_streams_per_s']:.0f} speedup={r['engine_speedup_vs_vmap']:.2f}x")
        for r in fleet_bench.main(["--quick"])
    ]
    if not args.skip_drift:
        rows += paper_tables.table2_params(trials=min(3, args.trials))
        rows += paper_tables.table3_drift(trials=args.trials)
        rows += paper_tables.fig3_pruning(trials=args.trials)
        rows += paper_tables.fig4_power(trials=min(3, args.trials))
    rows += roofline.main()

    print("\nname,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
