"""Pallas TPU kernels for the paper's compute hot-spots.

xorshift_proj — ODLHash projection: alpha generated in VMEM from the
                counter-based Xorshift16(7,9,8) hash (never stored in HBM).
oselm_update  — fused rank-k RLS update: each P tile read once for both
                the Woodbury downdate and the beta update.
ops           — jit'd wrappers with backend dispatch (interpret on CPU).
ref           — pure-jnp oracles every kernel is tested against.
"""
