"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

On CPU (this container) kernels run in interpret mode — the kernel body
executes as Python/jnp, validating the exact tiling/accumulation logic the
TPU would run.  On a real TPU backend ``interpret=False`` compiles to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import oselm_update as _oselm_update
from repro.kernels import ref as _ref
from repro.kernels import xorshift_proj as _xorshift_proj


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def xorshift_projection(
    x: jnp.ndarray,
    seed: int,
    n_hidden: int,
    scale: float = 1.0,
    activation: str = "sigmoid",
) -> jnp.ndarray:
    """ODLHash projection H = G(x @ alpha(seed)); alpha generated in VMEM.

    Accepts (..., n_in); leading dims are flattened for the kernel.
    """
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    h = _xorshift_proj.xorshift_projection(
        x2, seed=seed, n_hidden=n_hidden, scale=scale, activation=activation,
        interpret=_interpret(),
    )
    return h.reshape(lead + (n_hidden,))


def oselm_rls_update(
    P: jnp.ndarray, beta: jnp.ndarray, H: jnp.ndarray, Y: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused rank-k RLS update (P', beta')."""
    return _oselm_update.oselm_rls_update(P, beta, H, Y, interpret=_interpret())


def oselm_rls_update_fleet(
    P: jnp.ndarray, beta: jnp.ndarray, H: jnp.ndarray, Y: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused rank-k RLS update for S independent heads (leading stream axis)."""
    return _oselm_update.oselm_rls_update_fleet(P, beta, H, Y, interpret=_interpret())


# Re-export oracles for benchmarking convenience.
xorshift_projection_ref = _ref.xorshift_projection_ref
oselm_rls_update_ref = _ref.oselm_rls_update_ref
