"""Pallas TPU kernel: fused rank-k RLS (OS-ELM sequential training) update.

The paper's sequential trainer (Fig. 2(d)) updates BOTH the inverse Gram
matrix P and the output weights beta from the same P tiles.  A naive jnp
implementation streams P from HBM twice (once for ``P - PHt @ G``, once for
``beta + P' @ W``); at N x N x 4 bytes that doubles the dominant HBM traffic
of the update.  This kernel fuses the two so each P tile is read once,
updated in VMEM, written once, and its contribution to beta' accumulated in
the same pass:

  grid (i, j) over (TN_i x TN_j) tiles of P:
    P'[i,j]  = P[i,j] - PHt[i] @ G[j]                    (rank-k downdate)
    beta'[i] += P'[i,j] @ W[j]      (accumulated over j; init at j == 0)

with small operands precomputed on-core by the wrapper (k, m << N):
    PHt = P @ H^T        (N, k)   — plain GEMM, XLA handles it well
    S   = I_k + H PHt    (k, k)
    G   = S^{-1} (PHt)^T (k, N)   — tiny solve
    E   = Y - H beta     (k, m)
    W   = H^T E          (N, m)

TPU grid iterations are sequential, so the j-accumulation into beta' is safe
(same guarantee interpret mode provides).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rls_kernel(p_ref, pht_ref, g_ref, w_ref, beta_ref, po_ref, bo_ref):
    j = pl.program_id(1)

    # Fused P tile update: read once, write once.
    p_new = p_ref[...] - jnp.dot(
        pht_ref[...], g_ref[...], preferred_element_type=jnp.float32
    )
    po_ref[...] = p_new

    # beta' row-block accumulation across the j axis.
    @pl.when(j == 0)
    def _init():
        bo_ref[...] = beta_ref[...]

    bo_ref[...] += jnp.dot(p_new, w_ref[...], preferred_element_type=jnp.float32)


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def oselm_rls_update(
    P: jnp.ndarray,  # (N, N) f32
    beta: jnp.ndarray,  # (N, m) f32
    H: jnp.ndarray,  # (k, N) f32
    Y: jnp.ndarray,  # (k, m) f32
    tn: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused rank-k RLS update; returns (P', beta').  See module docstring."""
    n = P.shape[0]
    m = beta.shape[1]
    k = H.shape[0]

    # Small-operand stage (k x k solve etc.) — negligible FLOPs, done in jnp.
    pht = P @ H.T  # (N, k)
    s = jnp.eye(k, dtype=jnp.float32) + H @ pht
    g = jnp.linalg.solve(s, pht.T)  # (k, N)
    e = Y.astype(jnp.float32) - H @ beta
    w = H.T @ e  # (N, m)

    # Pad N to tile multiple.  Padded P rows/cols are zero; PHt/G/W padded
    # rows are zero so padded tiles stay zero and are sliced away.
    np_ = _ceil_to(n, tn)
    if np_ != n:
        P = jnp.zeros((np_, np_), P.dtype).at[:n, :n].set(P)
        pht = jnp.zeros((np_, k), pht.dtype).at[:n].set(pht)
        g = jnp.zeros((k, np_), g.dtype).at[:, :n].set(g)
        w = jnp.zeros((np_, m), w.dtype).at[:n].set(w)
        beta = jnp.zeros((np_, m), beta.dtype).at[:n].set(beta)

    nt = np_ // tn
    p_out, b_out = pl.pallas_call(
        _rls_kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((tn, tn), lambda i, j: (i, j)),  # P
            pl.BlockSpec((tn, k), lambda i, j: (i, 0)),  # PHt row block
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),  # G col block
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),  # W (indexed by j!)
            pl.BlockSpec((tn, m), lambda i, j: (i, 0)),  # beta row block
        ],
        out_specs=[
            pl.BlockSpec((tn, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tn, m), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((np_, m), jnp.float32),
        ],
        interpret=interpret,
    )(P, pht, g, w, beta)
    return p_out[:n, :n], b_out[:n]


# ---------------------------------------------------------------------------
# Fleet (batched) entry: S independent heads, one grid axis over streams.
# ---------------------------------------------------------------------------


def _rls_fleet_kernel(p_ref, pht_ref, g_ref, w_ref, beta_ref, po_ref, bo_ref):
    """Same fused update as ``_rls_kernel`` with a leading stream grid axis:
    grid (s, i, j) over streams x (TN_i x TN_j) tiles of that stream's P.
    Block leading dims are 1 (one stream per iteration); j varies fastest,
    so the per-(s, i) beta accumulation stays sequential."""
    j = pl.program_id(2)

    p_new = p_ref[0] - jnp.dot(
        pht_ref[0], g_ref[0], preferred_element_type=jnp.float32
    )
    po_ref[0] = p_new

    @pl.when(j == 0)
    def _init():
        bo_ref[0] = beta_ref[0]

    bo_ref[0] += jnp.dot(p_new, w_ref[0], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def oselm_rls_update_fleet(
    P: jnp.ndarray,  # (S, N, N) f32 — one inverse Gram per stream
    beta: jnp.ndarray,  # (S, N, m) f32
    H: jnp.ndarray,  # (S, k, N) f32 — rank-k rows per stream (k=1 for fleet ticks)
    Y: jnp.ndarray,  # (S, k, m) f32
    tn: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused rank-k RLS update for S independent heads; returns (P', beta').

    The small-operand stage (per-stream k x k solve etc.) is batched jnp; the
    P/beta fusion runs in one ``pallas_call`` with grid (S, nt, nt) so each
    stream's P tiles are still read once for both the Woodbury downdate and
    the beta accumulation.  This is the entry ``use_kernel=True`` fleet
    training routes through (``oselm.fleet_rank1_update_h``).
    """
    s_, n = P.shape[0], P.shape[1]
    k = H.shape[1]
    m = beta.shape[2]

    pht = jnp.einsum("snj,skj->snk", P, H)  # (S, N, k) = P Hᵀ
    ss = jnp.eye(k, dtype=jnp.float32) + jnp.einsum("skn,snj->skj", H, pht)
    g = jnp.linalg.solve(ss, pht.transpose(0, 2, 1))  # (S, k, N) = S⁻¹ H P
    e = Y.astype(jnp.float32) - jnp.einsum("skn,snm->skm", H, beta)
    w = jnp.einsum("skn,skm->snm", H, e)  # (S, N, m) = Hᵀ E

    tn = min(tn, _ceil_to(n, 8))  # small fleets (N < tn) use one N-sized tile
    np_ = _ceil_to(n, tn)
    if np_ != n:
        P = jnp.zeros((s_, np_, np_), P.dtype).at[:, :n, :n].set(P)
        pht = jnp.zeros((s_, np_, k), pht.dtype).at[:, :n].set(pht)
        g = jnp.zeros((s_, k, np_), g.dtype).at[:, :, :n].set(g)
        w = jnp.zeros((s_, np_, m), w.dtype).at[:, :n].set(w)
        beta = jnp.zeros((s_, np_, m), beta.dtype).at[:, :n].set(beta)

    nt = np_ // tn
    p_out, b_out = pl.pallas_call(
        _rls_fleet_kernel,
        grid=(s_, nt, nt),
        in_specs=[
            pl.BlockSpec((1, tn, tn), lambda s, i, j: (s, i, j)),  # P
            pl.BlockSpec((1, tn, k), lambda s, i, j: (s, i, 0)),  # PHt row block
            pl.BlockSpec((1, k, tn), lambda s, i, j: (s, 0, j)),  # G col block
            pl.BlockSpec((1, tn, m), lambda s, i, j: (s, j, 0)),  # W (indexed by j!)
            pl.BlockSpec((1, tn, m), lambda s, i, j: (s, i, 0)),  # beta row block
        ],
        out_specs=[
            pl.BlockSpec((1, tn, tn), lambda s, i, j: (s, i, j)),
            pl.BlockSpec((1, tn, m), lambda s, i, j: (s, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_, np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((s_, np_, m), jnp.float32),
        ],
        interpret=interpret,
    )(P, pht, g, w, beta)
    return p_out[:, :n, :n], b_out[:, :n]
