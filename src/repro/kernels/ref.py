"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function here defines the exact semantics the corresponding kernel in
``xorshift_proj.py`` / ``oselm_update.py`` must reproduce; tests sweep shapes
and dtypes asserting allclose between kernel (interpret=True) and these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import xorshift


def xorshift_projection_ref(
    x: jnp.ndarray,
    seed: int,
    n_hidden: int,
    scale: float = 1.0,
    activation: str = "sigmoid",
) -> jnp.ndarray:
    """H = G(x @ alpha(seed) * scale / sqrt(n_in)) with counter-based alpha.

    x: (..., n_in) f32/bf16.  alpha is the ODLHash matrix (never stored on
    TPU; here the oracle materializes it).
    """
    n_in = x.shape[-1]
    alpha = xorshift.alpha_hash(seed, n_in, n_hidden)
    z = x.astype(jnp.float32) @ (alpha * jnp.float32(scale))
    z = z / jnp.sqrt(jnp.float32(n_in))
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    if activation == "relu":
        return jax.nn.relu(z)
    if activation == "identity":
        return z
    raise ValueError(activation)


def oselm_rls_update_ref(
    P: jnp.ndarray,  # (N, N) f32
    beta: jnp.ndarray,  # (N, m) f32
    H: jnp.ndarray,  # (k, N) f32
    Y: jnp.ndarray,  # (k, m) f32
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rank-k Woodbury RLS update (paper Fig. 2(d)):

      S     = I_k + H P H^T
      P'    = P - (P H^T) S^{-1} (H P)
      beta' = beta + P' H^T (Y - H beta)

    Returns (P', beta').  P' is symmetrized for numerical hygiene.
    """
    k = H.shape[0]
    pht = P @ H.T  # (N, k)
    s = jnp.eye(k, dtype=jnp.float32) + H @ pht  # (k, k)
    g = jnp.linalg.solve(s, pht.T)  # (k, N)
    new_p = P - pht @ g
    new_p = 0.5 * (new_p + new_p.T)
    new_beta = beta + new_p @ (H.T @ (Y - H @ beta))
    return new_p, new_beta


def fused_elm_head_ref(
    x: jnp.ndarray,  # (k, n_in)
    P: jnp.ndarray,
    beta: jnp.ndarray,
    Y: jnp.ndarray,
    seed: int,
    scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Projection + RLS update fused (what serve/train steps actually run).

    Returns (H, P', beta').
    """
    h = xorshift_projection_ref(x, seed, P.shape[0], scale)
    new_p, new_beta = oselm_rls_update_ref(P, beta, h, Y)
    return h, new_p, new_beta
