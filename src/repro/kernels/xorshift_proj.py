"""Pallas TPU kernel: ODLHash hidden projection with in-VMEM weight generation.

The paper's ODLHash stores NO input weights: a 16-bit Xorshift PRNG generates
``alpha`` on the fly (45nm state machine, §2.3).  The TPU adaptation
(DESIGN.md §2) regenerates ``alpha`` *tiles* inside the kernel from a
counter-based Xorshift16 hash, so the (n_in x N) matrix never exists in HBM:

    HBM traffic:  x block in, H block out — alpha costs zero bytes.
    MXU work:     unchanged dense (TB x TK) @ (TK x TN) dots.

This converts the projection from memory-bound (arithmetic intensity ~2 for
stored weights at batch 1-8, the ODL serving regime) to compute-bound, which
is exactly the insight of the ASIC translated to the TPU memory hierarchy:
SRAM scarcity there, HBM bandwidth scarcity here.

Grid: (B/TB, N/TN, K/TK), K innermost for accumulation.  Alpha tiles are
derived from *global* (row, col) indices so every grid cell generates
bit-identical values to the ``ref.py`` oracle (tested exact).

All integer work is done in uint32 lanes with explicit 16-bit masking —
bit-identical to uint16 semantics and portable across interpret/TPU backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.xorshift import DEFAULT_ROUNDS, MIX_CONSTANTS, SHIFT_A, SHIFT_B, SHIFT_C

# NOTE: constants inside the kernel body must be numpy scalars (inlined as
# literals) — jnp arrays would be captured consts, which pallas_call rejects.
_M16 = np.uint32(0xFFFF)


def _mix16_u32(x: jnp.ndarray, rounds: int) -> jnp.ndarray:
    """(7,9,8) Xorshift16 + odd-constant multiply per round, on uint32 lanes
    with 16-bit masking — bit-identical to core.xorshift.mix16."""
    for r in range(rounds):
        x = (x ^ ((x << SHIFT_A) & _M16)) & _M16
        x = x ^ (x >> SHIFT_B)
        x = (x ^ ((x << SHIFT_C) & _M16)) & _M16
        x = (x * np.uint32(MIX_CONSTANTS[r % len(MIX_CONSTANTS)])) & _M16
    return x


def _alpha_tile(
    seed: int, row0: jnp.ndarray, col0: jnp.ndarray, tk: int, tn: int, n_total: int
) -> jnp.ndarray:
    """Generate the (tk, tn) alpha tile at global offset (row0, col0)."""
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (tk, tn), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (tk, tn), 1)
    ctr = rows * np.uint32(n_total) + cols + np.uint32(1)
    x = (np.uint32(seed) ^ ctr) & _M16
    x = jnp.where(x == 0, np.uint32(0x9E37), x)  # avoid the zero fixed point
    x = _mix16_u32(x, DEFAULT_ROUNDS)
    # u16 -> [-1, 1)
    return x.astype(jnp.float32) * np.float32(1.0 / 32768.0) - np.float32(1.0)


def _proj_kernel(
    x_ref,  # (TB, TK) VMEM
    o_ref,  # (TB, TN) VMEM, accumulated over the K grid axis
    *,
    seed: int,
    n_total: int,
    n_in: int,
    scale: float,
    activation: str,
    k_tiles: int,
):
    j = pl.program_id(1)  # N tile
    k = pl.program_id(2)  # K tile (innermost; sequential on TPU)
    tb, tk = x_ref.shape
    tn = o_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    alpha = _alpha_tile(
        seed,
        (k * tk).astype(jnp.uint32),
        (j * tn).astype(jnp.uint32),
        tk,
        tn,
        n_total,
    )
    part = jnp.dot(
        x_ref[...].astype(jnp.float32), alpha, preferred_element_type=jnp.float32
    )
    o_ref[...] += part * np.float32(scale)

    @pl.when(k == k_tiles - 1)
    def _finish():
        z = o_ref[...] * np.float32(1.0 / np.sqrt(n_in))
        if activation == "sigmoid":
            o_ref[...] = jax.nn.sigmoid(z)
        elif activation == "relu":
            o_ref[...] = jnp.maximum(z, 0.0)
        else:  # identity
            o_ref[...] = z


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("seed", "n_hidden", "scale", "activation", "tb", "tn", "tk", "interpret"),
)
def xorshift_projection(
    x: jnp.ndarray,
    seed: int,
    n_hidden: int,
    scale: float = 1.0,
    activation: str = "sigmoid",
    tb: int = 128,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """H = G(x @ alpha(seed) * scale / sqrt(n_in)); x: (B, n_in) -> (B, n_hidden).

    Tile sizes default to MXU-aligned 128; inputs are zero-padded to tile
    multiples (zero x rows/cols contribute nothing) and the output sliced.
    """
    b, n_in = x.shape
    bp, np_, kp = _ceil_to(b, tb), _ceil_to(n_hidden, tn), _ceil_to(n_in, tk)
    xp = jnp.zeros((bp, kp), x.dtype).at[:b, :n_in].set(x)
    k_tiles = kp // tk

    out = pl.pallas_call(
        functools.partial(
            _proj_kernel,
            seed=seed,
            n_total=n_hidden,  # counter layout uses the *logical* N
            n_in=n_in,
            scale=scale,
            activation=activation,
            k_tiles=k_tiles,
        ),
        grid=(bp // tb, np_ // tn, k_tiles),
        in_specs=[pl.BlockSpec((tb, tk), lambda i, j, k: (i, k))],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:b, :n_hidden]
