"""Serving driver: prefill -> decode loop with the ODL cascade.

Each decode step emits (next-token logits, per-stream ODL prediction,
query_mask).  Streams whose P1P2 confidence clears auto-theta SKIP the
teacher — the paper's data pruning as a serving-compute/communication saver.
Teacher answers arrive asynchronously (here: next loop tick) and are applied
with ``serve_apply_labels`` (rank-1 RLS per stream).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as model_lib


def serve(arch: str, variant: str = "smoke", batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 32, max_len: int = 128, seed: int = 0):
    cfg = configs.get_config(arch, variant)
    key = jax.random.PRNGKey(seed)
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    _, state = jax.jit(
        lambda p, t: model_lib.prefill(p, t, cfg, max_len=max_len)
    )(params, prompts)

    step = jax.jit(lambda p, st, t: model_lib.serve_step(p, st, t, cfg))
    apply_labels = jax.jit(
        lambda st, f, l, m: model_lib.serve_apply_labels(st, f, l, m, cfg)
    )

    tok = prompts[:, -1:]
    queries = skips = applied = 0
    pending = None  # (feats, mask) awaiting teacher labels
    rng = np.random.default_rng(seed)

    def answer(st, pend):
        feats, mask = pend
        labels = jnp.asarray(rng.integers(0, cfg.odl.n_out, size=batch), jnp.int32)
        return apply_labels(st, feats, labels, mask), int(np.asarray(mask).sum())

    for i in range(gen_tokens):
        logits, state, odl = step(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        q = np.asarray(odl["query_mask"])
        queries += int(q.sum())
        skips += int((~q).sum())
        # Async label acquisition: teacher answers last tick's queries.
        if pending is not None:
            state, n = answer(state, pending)
            applied += n
        pending = (odl["feats"], odl["query_mask"])
    # The decode loop exits with the final tick's queries still in flight;
    # apply those teacher answers too so no labels are silently dropped.
    if pending is not None:
        state, n = answer(state, pending)
        applied += n
    total = queries + skips
    meter_bytes = float(np.asarray(state.odl.meter.total).sum())
    print(f"decoded {gen_tokens} tokens x {batch} streams; "
          f"teacher queries {queries}/{total} ({100*queries/max(total, 1):.1f}% comm volume), "
          f"labels applied {applied}/{queries}, {meter_bytes/1e3:.1f} kB metered")
    return queries, skips


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args(argv)
    serve(args.arch, args.variant, batch=args.batch, gen_tokens=args.tokens)


if __name__ == "__main__":
    main()
