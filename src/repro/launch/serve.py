"""Serving driver: prefill -> decode loop with the ODL cascade.

Each decode step emits (next-token logits, per-stream ODL prediction,
query_mask).  Streams whose P1P2 confidence clears auto-theta SKIP the
teacher — the paper's data pruning as a serving-compute/communication saver.
Teacher answers arrive asynchronously through the engine's Teacher protocol
(``repro.engine.stream``) with injectable latency/jitter; in-flight queries
wait in a fixed-capacity ``PendingRing`` and are applied out of order with
``serve_apply_labels`` (masked rank-1 RLS per stream).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 32 \
      --teacher-latency 2 --teacher-jitter 1
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.engine import stream
from repro.models import model as model_lib


def serve(arch: str, variant: str = "smoke", batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 32, max_len: int = 128, seed: int = 0,
          teacher_latency: int = 1, teacher_jitter: int = 0,
          pending_capacity: int = 8):
    cfg = configs.get_config(arch, variant)
    key = jax.random.PRNGKey(seed)
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    _, state = jax.jit(
        lambda p, t: model_lib.prefill(p, t, cfg, max_len=max_len)
    )(params, prompts)

    step = jax.jit(lambda p, st, t: model_lib.serve_step(p, st, t, cfg))
    apply_labels = jax.jit(
        lambda st, f, l, m: model_lib.serve_apply_labels(st, f, l, m, cfg)
    )

    # The smoke teacher predicts random classes (a real deployment points
    # label_fn at the pod-side backbone ensemble); latency/jitter model the
    # BLE/network round-trip in decode ticks.
    rng = np.random.default_rng(seed)
    teacher = stream.LatencyTeacher(
        label_fn=lambda tick, feats: rng.integers(0, cfg.odl.n_out, size=batch),
        latency=teacher_latency, jitter=teacher_jitter, seed=seed,
    )
    ring = stream.PendingRing(pending_capacity)
    stats = stream.StreamStats()

    def drain_replies(state, now):
        for reply in teacher.poll(now):
            ent = ring.pop(reply.ticket)
            if ent is None:
                stats.replies_orphaned += 1
                continue
            asked_tick, feats, qmask = ent
            mask = qmask & np.asarray(reply.answered, bool)
            n = int(mask.sum())
            if n == 0:
                # Reply covered none of the asked streams: those queries
                # are gone for good — meter the ticket as lost.
                stats.tickets_lost += 1
                continue
            state = apply_labels(
                state, feats, jnp.asarray(reply.labels, jnp.int32), jnp.asarray(mask)
            )
            stats.labels_applied += n
            stats.label_latency_ticks.append(now - asked_tick)
        return state

    tok = prompts[:, -1:]
    skips = 0
    for i in range(gen_tokens):
        t0 = time.perf_counter()
        logits, state, odl = step(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        q = np.asarray(odl["query_mask"])
        n_q = int(q.sum())
        skips += int((~q).sum())
        if n_q:
            ticket = teacher.ask(odl["feats"], q, i)
            stats.tickets_issued += 1
            stats.queries_issued += n_q
            dropped = ring.push(ticket, (i, odl["feats"], q))
            if dropped is not None:
                stats.tickets_dropped += 1
                stats.queries_dropped += int(dropped[2].sum())
        state = drain_replies(state, i)
        jax.block_until_ready(tok)
        stats.ticks += 1
        stats.stream_steps += batch
        stats.tick_ms.append((time.perf_counter() - t0) * 1e3)
    # The decode loop exits with the final ticks' queries still in flight;
    # wait out the teacher so no answered labels are silently dropped.
    t = gen_tokens
    drained = 0
    while len(ring) and teacher.in_flight() > 0 and drained < stream.MAX_DRAIN_TICKS:
        state = drain_replies(state, t)
        t += 1
        drained += 1
    stats.tickets_lost += len(ring.drain())

    queries = stats.queries_issued
    total = queries + skips
    meter_bytes = float(np.asarray(state.odl.meter.total).sum())
    print(f"decoded {gen_tokens} tokens x {batch} streams; "
          f"teacher queries {queries}/{total} ({100*queries/max(total, 1):.1f}% comm volume), "
          f"labels applied {stats.labels_applied}/{queries}, "
          f"{stats.tickets_dropped} tickets dropped, {meter_bytes/1e3:.1f} kB metered")
    print(f"tick latency p50/p95: {stats.tick_p50_ms:.2f}/{stats.tick_p95_ms:.2f} ms; "
          f"label latency p50/p95: {stats.label_latency_p50:.0f}/"
          f"{stats.label_latency_p95:.0f} ticks "
          f"(teacher latency {teacher_latency}+U[0,{teacher_jitter}])")
    return queries, skips


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--teacher-latency", type=int, default=1,
                    help="teacher answer latency in decode ticks")
    ap.add_argument("--teacher-jitter", type=int, default=0,
                    help="extra uniform per-ticket latency in [0, J] ticks")
    ap.add_argument("--pending-capacity", type=int, default=8,
                    help="in-flight query ring capacity (oldest dropped)")
    args = ap.parse_args(argv)
    serve(args.arch, args.variant, batch=args.batch, gen_tokens=args.tokens,
          teacher_latency=args.teacher_latency, teacher_jitter=args.teacher_jitter,
          pending_capacity=args.pending_capacity)


if __name__ == "__main__":
    main()
