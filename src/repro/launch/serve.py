"""Serving driver: prefill -> decode loop with multi-tenant ODL cascades.

The backbone decodes once per tick; the per-tick stream features fan out to
``--tenants`` independent ODL fleets multiplexed over this process by
``repro.engine.multiplex`` — each tenant has its own engine state, pending
ring, teacher connection, and backpressure policy (``--backpressure``:
drop_oldest / drop_newest / block / coalesce), while all tenants share one
compiled plan/learn executable through the engine's bounded runner LRUs.
Streams whose P1P2 confidence clears auto-theta SKIP the teacher — the
paper's data pruning as a serving-compute/communication saver.  Tenants
run the engine's ``serve`` mode: the per-stream drift detector runs live
and a drifting stream is forced to query (pruning condition 2), exactly
the ``gate`` decision logic the single-tenant ``model.serve_step`` path
uses.  Teacher answers arrive asynchronously (out of order, possibly
partial) and are applied against the *plan-time* decision context, so a
delayed reply is judged by the prediction/threshold the query was issued
under.

``--teacher rpc`` swaps the in-process latency model for a real loopback
TCP label server (``repro.engine.rpc``), with wall-clock timeout → loss.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 32 \
      --tenants 2 --backpressure coalesce --teacher-latency 2
"""

from __future__ import annotations

import argparse
import contextlib
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, engine
from repro.engine import multiplex, rpc, stream
from repro.models import model as model_lib


def _decode_feats(params, state, prompts, cfg, gen_tokens):
    """Tick source: one backbone decode step per tick, yielding (B, d)
    stream features (greedy next-token feedback, ODL state untouched)."""
    step = jax.jit(lambda p, st, t: model_lib.decode_step(p, st, t, cfg))
    tok = prompts[:, -1:]
    for _ in range(gen_tokens):
        logits, feats, state = step(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        yield feats


def serve(arch: str, variant: str = "smoke", batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 32, max_len: int = 128, seed: int = 0,
          teacher_latency: int = 1, teacher_jitter: int = 0,
          teacher_loss: float = 0.0, pending_capacity: int = 8,
          tenants: int = 1, backpressure: str = "drop_oldest",
          teacher: str = "latency", rpc_timeout_s: float = 5.0):
    cfg = configs.get_config(arch, variant)
    key = jax.random.PRNGKey(seed)
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    _, state = jax.jit(
        lambda p, t: model_lib.prefill(p, t, cfg, max_len=max_len)
    )(params, prompts)

    odl_cfg = model_lib.core_config(cfg)
    # One backbone decode feeds every tenant: tee the tick source N ways
    # (the round-robin scheduler keeps tenants within one time slice of
    # each other, so the tee buffer stays bounded by the quantum).
    feeds = itertools.tee(
        _decode_feats(params, state, prompts, cfg, gen_tokens), tenants
    )

    with contextlib.ExitStack() as stack:
        if teacher == "rpc":
            host, port = stack.enter_context(
                rpc.loopback_server(n_out=cfg.odl.n_out)
            )
            teachers = [
                stack.enter_context(
                    rpc.RpcTeacher(host, port, timeout_s=rpc_timeout_s)
                )
                for _ in range(tenants)
            ]
        else:
            # The smoke teacher predicts random classes (a real deployment
            # points label_fn at the pod-side backbone ensemble);
            # latency/jitter/loss model the BLE/network round-trip in
            # decode ticks, per tenant.
            def make_label_fn(i):
                rng = np.random.default_rng(seed + i)
                return lambda tick, feats: rng.integers(0, cfg.odl.n_out, size=batch)

            teachers = [
                stream.LatencyTeacher(
                    label_fn=make_label_fn(i), latency=teacher_latency,
                    jitter=teacher_jitter, loss_prob=teacher_loss, seed=seed + i,
                )
                for i in range(tenants)
            ]

        tenant_list = [
            multiplex.Tenant(
                name=f"tenant{i}",
                state=engine.init_fleet(odl_cfg, batch),
                ticks=feeds[i],
                cfg=odl_cfg,
                teacher=teachers[i],
                mode="serve",  # gate semantics: live drift detector,
                # condition-2 forced queries, controller always armed
                capacity=pending_capacity,
                backpressure=backpressure,
                collect=False,  # long-running servers keep no history
            )
            for i in range(tenants)
        ]
        results, agg = multiplex.run(tenant_list)

    queries = skips = 0
    for name in sorted(results):
        r = results[name]
        s = r.stats
        t_skips = s.stream_steps - s.queries_issued
        queries += s.queries_issued
        skips += t_skips
        meter_kb = float(np.asarray(r.state.meter.total).sum()) / 1e3
        recon = "ok" if s.reconciled else "BROKEN"
        print(f"{name}: queries {s.queries_issued}/{s.stream_steps} "
              f"({100 * s.queries_issued / max(s.stream_steps, 1):.1f}% comm volume), "
              f"labels {s.labels_applied}, dropped {s.queries_dropped}, "
              f"lost {s.queries_lost}, coalesced {s.queries_coalesced}, "
              f"orphaned {s.replies_orphaned}, accounting {recon}, "
              f"{meter_kb:.1f} kB metered")
        rpc_note = (
            f"; rpc timeouts {teachers[int(name.removeprefix('tenant'))].timed_out}"
            if teacher == "rpc" else ""
        )
        print(f"  tick p50/p95 {s.tick_p50_ms:.2f}/{s.tick_p95_ms:.2f} ms; "
              f"label latency p50/p95 {s.label_latency_p50:.0f}/"
              f"{s.label_latency_p95:.0f} ticks{rpc_note}")
        if not s.reconciled:
            raise AssertionError(f"{name}: query accounting does not reconcile: "
                                 f"{s.summary()}")
    caches = stream.cache_stats()["plan_runner"]
    print(f"aggregate: {tenants} tenant(s) x {gen_tokens} tokens x {batch} streams "
          f"= {agg.stream_steps} steps in {agg.wall_s:.2f}s "
          f"({agg.steps_per_s:,.0f} steps/s); backpressure={backpressure}, "
          f"teacher={teacher}; plan-runner cache "
          f"{caches['hits']} hits / {caches['misses']} misses "
          f"(tenants share executables)")
    return queries, skips


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=1,
                    help="independent ODL fleets multiplexed over this process")
    ap.add_argument("--backpressure", default="drop_oldest",
                    choices=stream.BACKPRESSURE_POLICIES,
                    help="pending-ring saturation policy (per tenant)")
    ap.add_argument("--teacher", default="latency", choices=("latency", "rpc"),
                    help="latency: in-process tick-granular model; "
                    "rpc: loopback TCP label server with timeout->loss")
    ap.add_argument("--teacher-latency", type=int, default=1,
                    help="teacher answer latency in decode ticks")
    ap.add_argument("--teacher-jitter", type=int, default=0,
                    help="extra uniform per-ticket latency in [0, J] ticks")
    ap.add_argument("--teacher-loss", type=float, default=0.0,
                    help="fraction of tickets silently lost by the teacher")
    ap.add_argument("--rpc-timeout", type=float, default=5.0,
                    help="rpc teacher reply deadline in wall seconds")
    ap.add_argument("--pending-capacity", type=int, default=8,
                    help="in-flight query ring capacity (see --backpressure)")
    args = ap.parse_args(argv)
    serve(args.arch, args.variant, batch=args.batch, gen_tokens=args.tokens,
          teacher_latency=args.teacher_latency, teacher_jitter=args.teacher_jitter,
          teacher_loss=args.teacher_loss, pending_capacity=args.pending_capacity,
          tenants=args.tenants, backpressure=args.backpressure,
          teacher=args.teacher, rpc_timeout_s=args.rpc_timeout)


if __name__ == "__main__":
    main()
