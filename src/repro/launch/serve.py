"""Serving driver: prefill -> decode loop with multi-tenant ODL cascades.

The backbone decodes once per tick; the per-tick stream features fan out to
``--tenants`` independent ODL fleets multiplexed over this process by
``repro.engine.multiplex`` — each tenant has its own engine state, pending
ring, teacher connection, and backpressure policy (``--backpressure``:
drop_oldest / drop_newest / block / coalesce), while all tenants share one
compiled plan/learn executable through the engine's bounded runner LRUs.
Streams whose P1P2 confidence clears auto-theta SKIP the teacher — the
paper's data pruning as a serving-compute/communication saver.  Tenants
run the engine's ``serve`` mode: the per-stream drift detector runs live
and a drifting stream is forced to query (pruning condition 2), exactly
the ``gate`` decision logic the single-tenant ``model.serve_step`` path
uses.  Teacher answers arrive asynchronously (out of order, possibly
partial) and are applied against the *plan-time* decision context, so a
delayed reply is judged by the prediction/threshold the query was issued
under.

``--teacher rpc`` swaps the in-process latency model for a real loopback
TCP label server (``repro.engine.rpc``), with wall-clock timeout → loss.
All tenants share **one** batched connection per teacher host
(``rpc.BatchedRpcClient``): asks landing within
``--teacher-batch-window`` ms (up to ``--teacher-batch-max``) coalesce
into a single length-prefixed binary frame, amortizing the per-query
round-trip the paper's pruning argument is about.  ``--teacher-secret``
arms the HMAC challenge–response handshake on both ends (an
unauthenticated label server is refused) — once per connection, not once
per tenant.  ``--teacher-compress`` wraps the binary frames in zlib
envelopes (negotiated in the handshake when a secret is set).

``--mesh-fleet N`` is the mega-fleet path: a single tenant's stream axis
shards over an N-device ``("fleet",)`` mesh — one shard-local session
(engine-state rows on device k, pending ring, teacher handle, plan/learn
dispatch) per device, a teacher answer learning back only into the shard
that planned the query (``stream.run_sharded``).  On a CPU host, force
devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--sched drr`` replaces the fixed quantum-tick round robin with deficit
round robin in stream-step units, so a huge tenant cannot starve small
ones.

``--fuse-cohorts`` (default on) stacks same-shaped tenants — same engine
config, mode, and stream width — into one cohort whose states ride a
single batched plan/learn dispatch per quantum (``repro.engine.cohort``),
instead of one dispatch per tenant.  Everything tenant-visible (pending
rings, teachers, backpressure, accounting, snapshots, migration) stays
per-tenant and bit-for-bit identical to the unfused path; ``off`` keeps
one dispatch per tenant.

Durable sessions (``repro.engine.snapshot``): ``--snapshot-dir`` +
``--snapshot-every`` publish per-tenant session snapshots atomically
(keep-k) as the decode loop runs; ``--resume`` restores every tenant from
its latest published snapshot (replaying the backbone decode up to the
recorded tick cursor) — kill the process mid-serve and it continues where
it stopped.  ``--migrate`` demonstrates live tenant migration: tenant0 is
quiesced mid-stream, snapshotted, extracted from the running multiplexer,
and restored into a second multiplexer with a *fresh* teacher connection
(in-flight tickets re-asked and metered) — the query-accounting identity
must still reconcile, and the report proves it.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tokens 32 \
      --tenants 2 --backpressure coalesce --teacher-latency 2 \
      --snapshot-dir /tmp/serve_ckpt --snapshot-every 8
"""

from __future__ import annotations

import argparse
import contextlib
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, engine
from repro.engine import multiplex, rpc, snapshot, stream
from repro.models import model as model_lib
from repro.runtime import telemetry


def _decode_feats(params, state, prompts, cfg, gen_tokens):
    """Tick source: one backbone decode step per tick, yielding (B, d)
    stream features (greedy next-token feedback, ODL state untouched)."""
    step = jax.jit(lambda p, st, t: model_lib.decode_step(p, st, t, cfg))
    tok = prompts[:, -1:]
    for _ in range(gen_tokens):
        logits, feats, state = step(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        yield feats


def _print_stream_report(parsed: dict) -> dict:
    """ONE render for every serve path's per-session counter block.

    ``parsed`` is a ``telemetry.parse_prometheus`` view — the same shape
    whether it came from this process's registry (solo / mesh) or a
    worker scrape (fleet) — so the three reports cannot drift apart.
    Prints one line per label set carrying stream counters and returns
    the summed counters plus ``identity_ok`` / ``sessions``.
    """
    ident = telemetry.check_stream_identity(parsed)
    fields = ("queries_issued", "stream_steps", "labels_applied",
              "queries_dropped", "queries_lost", "queries_coalesced",
              "replies_orphaned", "tickets_reasked")
    totals = dict.fromkeys(fields, 0)
    for key in sorted(ident):
        def g(f, key=key):
            return int(parsed.get((f"odl_stream_{f}", key), 0))
        who = ",".join(
            f"{k}{v}" if k in ("shard", "cohort") else v for k, v in key
        ) or "session"
        recon = "ok" if ident[key] else "BROKEN"
        issued, steps = g("queries_issued"), g("stream_steps")
        print(f"{who}: queries {issued}/{steps} "
              f"({100 * issued / max(steps, 1):.1f}% comm volume), "
              f"labels {g('labels_applied')}, dropped {g('queries_dropped')}, "
              f"lost {g('queries_lost')}, coalesced {g('queries_coalesced')}, "
              f"orphaned {g('replies_orphaned')}, "
              f"reasked {g('tickets_reasked')}, accounting {recon}")
        for f in fields:
            totals[f] += g(f)
    totals["identity_ok"] = bool(ident) and all(ident.values())
    totals["sessions"] = len(ident)
    return totals


def _print_label_server_stats(ls: dict) -> None:
    """The label server's own counters, scraped over the wire
    (``rpc.server_stats``) — the server runs as a subprocess, so this is
    the only way the final report can include its side of the ledger."""
    comp = ""
    if ls.get("frames_compressed"):
        win_in = ls["raw_bytes_in"] / max(ls["compressed_bytes_in"], 1)
        win_out = ls["raw_bytes_out"] / max(ls["compressed_bytes_out"], 1)
        comp = (f", compression x{win_in:.1f} in / x{win_out:.1f} out over "
                f"{ls['frames_compressed']} frames")
    print(f"label server: {ls['asks_served']} asks "
          f"({ls['frames_v2']} v2 frames, {ls['requests_v1']} v1 requests), "
          f"frame errors {ls['frame_errors']}, auth failures "
          f"{ls['auth_failures']}, {ls['connections_accepted']} "
          f"connection(s), {ls['thread_count']} live thread(s){comp}")


def _write_metrics_json(path: str, doc: dict, traces: dict = None) -> None:
    """``--metrics-json``: machine-readable run metrics, plus one Chrome
    ``trace_event`` file per traced process (load it in chrome://tracing
    or https://ui.perfetto.dev)."""
    import json as json_mod

    with open(path, "w") as f:
        json_mod.dump(doc, f, indent=2, sort_keys=True, default=str)
    written = [path]
    for tag, trace in (traces or {}).items():
        tpath = f"{path}.{tag}.trace.json" if tag else f"{path}.trace.json"
        with open(tpath, "w") as f:
            json_mod.dump(trace, f)
        written.append(tpath)
    print(f"metrics written: {', '.join(written)}")


def _serve_mesh(cfg, odl_cfg, params, state, prompts, *, mesh_fleet, batch,
                gen_tokens, seed, teacher, teacher_latency, teacher_jitter,
                teacher_loss, pending_capacity, backpressure, rpc_timeout_s,
                teacher_batch_window_s, teacher_batch_max, teacher_secret,
                teacher_compress, metrics_json=None):
    """Mega-fleet path: one tenant, its stream axis sharded over a
    ``("fleet",)`` mesh — one shard-local session (pending ring, teacher
    connection, plan/learn dispatch) per device, a label learning back
    only into the shard that planned it (``stream.run_sharded``).  On a
    CPU host, force the device count first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    from repro.distributed import sharding
    from repro.launch import mesh as mesh_lib

    fleet_mesh = mesh_lib.make_fleet_mesh(mesh_fleet)
    ticks = _decode_feats(params, state, prompts, cfg, gen_tokens)
    with contextlib.ExitStack() as stack:
        if teacher == "rpc":
            host, port = stack.enter_context(
                rpc.loopback_server(n_out=cfg.odl.n_out, secret=teacher_secret)
            )
            # One shared batched connection; each shard gets its own tenant
            # handle — shard asks coalesce into single frames on one socket
            # without breaking shard locality (the demux is per-handle).
            client = rpc.BatchedRpcClient(
                host, port, timeout_s=rpc_timeout_s, secret=teacher_secret,
                batch_window_s=teacher_batch_window_s,
                batch_max=teacher_batch_max, compress=teacher_compress,
            )
            stack.callback(client.close)

            def teachers(k):
                return client.tenant(name=f"shard{k}")
        else:
            def teachers(k):
                rng = np.random.default_rng(seed + k)
                return stream.LatencyTeacher(
                    label_fn=lambda tick, feats: rng.integers(
                        0, cfg.odl.n_out, size=np.asarray(feats).shape[0]
                    ),
                    latency=teacher_latency, jitter=teacher_jitter,
                    loss_prob=teacher_loss, seed=seed + k,
                )

        with sharding.activate(fleet_mesh):
            n_shards = sharding.fleet_axis_size()
            st, _, stats_list = stream.run_sharded(
                engine.init_fleet(odl_cfg, batch), ticks, odl_cfg, teachers,
                mode="serve", capacity=pending_capacity,
                backpressure=backpressure, collect=False,
            )
        rpc_bytes = client.wire_bytes if teacher == "rpc" else None
        label_server_stats = None
        if teacher == "rpc":
            client.sync_telemetry()
            label_server_stats = rpc.server_stats(host, port,
                                                  secret=teacher_secret)

    tel = telemetry.get() or telemetry.enable()
    for k, s in enumerate(stats_list):
        telemetry.sync_stream_stats(tel.registry, s, pending=0, shard=str(k))
    report = _print_stream_report(
        telemetry.parse_prometheus(tel.registry.prometheus_text()))
    queries = report["queries_issued"]
    skips = report["stream_steps"] - report["queries_issued"]
    if not report["identity_ok"]:
        raise AssertionError(
            "shard query accounting does not reconcile: "
            + "; ".join(s.summary() for s in stats_list))
    if label_server_stats is not None:
        _print_label_server_stats(label_server_stats)
    agg = stream.aggregate_stats(
        stats_list, padded_streams=(-batch) % max(n_shards, 1))
    meter_kb = float(np.asarray(st.meter.total).sum()) / 1e3
    rpc_note = f"; rpc wire {rpc_bytes / 1e3:.1f} kB" if rpc_bytes else ""
    print(f"mesh aggregate: {n_shards} shard(s) x {gen_tokens} tokens x "
          f"{batch} streams = {agg['stream_steps']} steps in "
          f"{agg['wall_s']:.2f}s ({agg['steps_per_s']:,.0f} steps/s); "
          f"padded {agg['padded_streams']} dead rows; "
          f"backpressure={backpressure}, teacher={teacher}"
          f"{rpc_note}; {meter_kb:.1f} kB metered")
    if metrics_json:
        _write_metrics_json(metrics_json, {
            "mode": "mesh", "shards": n_shards, "tokens": gen_tokens,
            "report": report, "aggregate": agg,
            "prometheus": tel.registry.prometheus_text(),
            "registry": tel.registry.snapshot(),
            "label_server": label_server_stats,
        }, {"": tel.tracer.chrome_trace()})
    return queries, skips


def serve_fleet(workers: int = 2, tenants: int = 4, batch: int = 4,
                gen_tokens: int = 2000, fleet_ticks: str = "synth",
                arch: str = "qwen3-4b", variant: str = "smoke", seed: int = 0,
                tick_sleep_ms: float = 1.0, teacher_latency: int = 2,
                teacher_jitter: int = 1, teacher_loss: float = 0.0,
                pending_capacity: int = 8, backpressure: str = "drop_oldest",
                worker_capacity: int = None, migrate: bool = True,
                drain: bool = True, snapshot_full_every: int = 8,
                metrics_json: str = None):
    """Elastic fleet path (``--workers N``): spin ``workers`` multiplexer
    worker subprocesses behind a shape-aware router
    (``repro.runtime.elastic``), admit ``tenants`` tenants by
    compiled-shape affinity, optionally live-migrate one mid-stream and
    drain a whole worker (scale-in), then reconcile the fleet-wide
    query-accounting identity across every migration."""
    import time as time_mod

    from repro.runtime import elastic
    from repro.runtime import worker as worker_mod

    if fleet_ticks == "decode":
        # Real backbone decode ticks: each worker builds (and caches) the
        # backbone once per distinct spec; n_in is the model dim.
        odl_cfg = model_lib.core_config(configs.get_config(arch, variant))
        ticks_spec = {"kind": "decode", "arch": arch, "variant": variant,
                      "batch": batch, "prompt_len": 16, "max_len": 128,
                      "seed": seed, "t_total": gen_tokens,
                      "tick_sleep_ms": tick_sleep_ms}
    else:
        # Synthetic per-tick-seeded features: O(1) seek, identical bytes in
        # any process — the migration-parity default.
        from repro.core import drift as drift_mod
        from repro.core import oselm, pruning

        odl_cfg = engine.EngineConfig(
            elm=oselm.OSELMConfig(n_in=16, n_hidden=16, n_out=4,
                                  variant="hash", ridge=1e-2),
            prune=pruning.PruneConfig(min_trained=1_000_000),
            drift=drift_mod.DriftConfig(),
        )
        ticks_spec = None  # per-tenant (distinct seeds), built below

    if worker_capacity is None:
        # Spread evenly so same-shape tenants split into fused cohorts
        # instead of all packing onto the first worker.
        worker_capacity = max(1, -(-tenants // workers))

    # Router-side telemetry: migrate.ship spans land in THIS process's
    # trace; each worker keeps its own registry, scraped over the control
    # socket (router.fleet_metrics).
    tel = telemetry.enable()
    tel.registry.clear()
    fleet = [elastic.spawn_worker(f"w{i}") for i in range(workers)]
    router = elastic.Router(fleet, capacity=worker_capacity)
    collected: dict = {}
    try:
        specs = []
        for i in range(tenants):
            spec = worker_mod.tenant_spec(
                f"tenant{i}", odl_cfg, s=batch, mode="serve",
                capacity=pending_capacity, backpressure=backpressure,
                ticks=(ticks_spec or worker_mod.synth_ticks_spec(
                    seed=seed + 100 + i, t_total=gen_tokens,
                    tick_sleep_ms=tick_sleep_ms)),
                teacher=worker_mod.latency_teacher_spec(
                    n_out=odl_cfg.elm.n_out, latency=teacher_latency,
                    jitter=teacher_jitter, loss=teacher_loss, seed=seed + i),
            )
            specs.append(spec)
            w = router.admit(spec)
            print(f"placed {spec['name']} -> {w.name} "
                  f"(shape key {worker_mod.spec_shape_key(spec)})")

        if migrate:
            # Live migration: wait until tenant0 is mid-stream, then move it.
            target = max(2, gen_tokens // 2)
            deadline = time_mod.monotonic() + 300
            while time_mod.monotonic() < deadline:
                src = router.worker_of("tenant0")
                st = src.status()
                row = next((t for t in st["live"] if t["name"] == "tenant0"),
                           None)
                if row is None:
                    print("tenant0 finished before the migration point "
                          "(--tokens too small); continuing without migration")
                    break
                if row["t"] >= target:
                    dst = router.migrate("tenant0")
                    print(f"tenant0 migrated {src.name} -> {dst.name} "
                          f"at tick >= {row['t']}")
                    break
                time_mod.sleep(0.02)

        # Mid-run live scrape: every worker's registry over the control
        # socket, while tenants still stream.  The scraped identity
        # (issued == applied + dropped + lost + coalesced + pending) must
        # close at this instant — the CI observability smoke rides this.
        scrape = router.fleet_metrics()
        midrun = {}
        for wname, h in scrape["workers"].items():
            midrun.update(telemetry.check_stream_identity(
                telemetry.parse_prometheus(h["prometheus"])))
        print(f"mid-run scrape: {len(midrun)} tenant series across "
              f"{len(scrape['workers'])} worker(s), identity "
              f"{'ok' if midrun and all(midrun.values()) else 'BROKEN'}")
        if not midrun or not all(midrun.values()):
            raise AssertionError(
                f"mid-run scraped query accounting broken: {midrun}")

        if drain and len(router.workers) > 1:
            victim = router.workers[-1]
            moved, finished = router.scale_in(victim)
            collected.update(finished)
            print(f"scale-in: drained {victim.name} "
                  f"(migrated {moved or 'nothing'}; "
                  f"{len(finished)} finished there)")

        router.wait_finished([s["name"] for s in specs], timeout_s=600)

        # Final scrape (with traces when they'll be written) BEFORE the
        # workers go away, then ONE render over the collected stats — the
        # same `_print_stream_report` the solo and mesh paths use, fed
        # through a registry so the fleet report cannot drift from them.
        final_scrape = router.fleet_metrics(trace=bool(metrics_json))
        for w in router.workers:
            collected.update(w.report())
        reg = telemetry.Registry()
        for name, s in collected.items():
            telemetry.sync_stream_stats(
                reg, worker_mod.stats_from_wire(s), pending=0, tenant=name)
        report = _print_stream_report(
            telemetry.parse_prometheus(reg.prometheus_text()))
        agg = elastic.reconcile(collected)
        recon = "ok" if agg["reconciled"] else "BROKEN"
        print(f"fleet aggregate: {len(collected)} tenant(s) over "
              f"{workers} worker(s) -> {len(router.workers)} after scale-in; "
              f"{agg['stream_steps']} steps, queries {agg['queries_issued']}, "
              f"labels {agg['labels_applied']}, dropped "
              f"{agg['queries_dropped']}, lost {agg['queries_lost']}, "
              f"coalesced {agg['queries_coalesced']}, reasked "
              f"{agg['tickets_reasked']}, accounting {recon}")
        if not agg["reconciled"] or not all(agg["per_tenant"].values()):
            raise AssertionError(
                f"fleet query accounting does not reconcile: {agg}")
        if not report["identity_ok"]:
            raise AssertionError(
                "scraped metrics identity does not hold at end of run")
        if metrics_json:
            traces = dict(final_scrape["traces"])
            traces["router"] = tel.tracer.chrome_trace()
            _write_metrics_json(metrics_json, {
                "mode": "fleet", "workers_spawned": workers,
                "workers": final_scrape["workers"],
                "report": report,
                "aggregate": {k: v for k, v in agg.items()
                              if k != "per_tenant"},
                "per_tenant_reconciled": agg["per_tenant"],
                "midrun_series": len(midrun),
            }, traces)
        return agg["queries_issued"], agg["stream_steps"] - agg["queries_issued"]
    finally:
        router.close()


def serve(arch: str, variant: str = "smoke", batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 32, max_len: int = 128, seed: int = 0,
          teacher_latency: int = 1, teacher_jitter: int = 0,
          teacher_loss: float = 0.0, pending_capacity: int = 8,
          tenants: int = 1, backpressure: str = "drop_oldest",
          teacher: str = "latency", rpc_timeout_s: float = 5.0,
          teacher_batch_window_s: float = rpc.DEFAULT_BATCH_WINDOW_S,
          teacher_batch_max: int = rpc.DEFAULT_BATCH_MAX,
          teacher_secret: str = None, sched: str = "rr",
          snapshot_dir: str = None, snapshot_every: int = 0,
          resume: bool = False, migrate: bool = False,
          fuse_cohorts: bool = True, teacher_compress: bool = False,
          mesh_fleet: int = 0, metrics_json: str = None):
    # Driver-level telemetry: spans + mirrored counters for this process.
    # The stream bench gates the instrumented overhead at <2%, so it is
    # on by default here.  Cleared per run — serve() may be called twice
    # in one process (tests) and stale tenant series must not leak.
    tel = telemetry.enable()
    tel.registry.clear()
    cfg = configs.get_config(arch, variant)
    key = jax.random.PRNGKey(seed)
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    _, state = jax.jit(
        lambda p, t: model_lib.prefill(p, t, cfg, max_len=max_len)
    )(params, prompts)

    odl_cfg = model_lib.core_config(cfg)
    if mesh_fleet:
        if tenants != 1:
            raise ValueError(
                "--mesh-fleet shards ONE fleet's stream axis across devices; "
                "it does not compose with --tenants > 1 (run one sharded "
                "process per tenant instead)")
        if snapshot_dir is not None or resume or migrate:
            raise ValueError(
                "--mesh-fleet does not compose with snapshots/resume/migrate "
                "(per-shard sessions are not snapshot-capable yet)")
        return _serve_mesh(
            cfg, odl_cfg, params, state, prompts, mesh_fleet=mesh_fleet,
            batch=batch, gen_tokens=gen_tokens, seed=seed,
            teacher=teacher, teacher_latency=teacher_latency,
            teacher_jitter=teacher_jitter, teacher_loss=teacher_loss,
            pending_capacity=pending_capacity, backpressure=backpressure,
            rpc_timeout_s=rpc_timeout_s,
            teacher_batch_window_s=teacher_batch_window_s,
            teacher_batch_max=teacher_batch_max,
            teacher_secret=teacher_secret, teacher_compress=teacher_compress,
            metrics_json=metrics_json,
        )
    durable = snapshot_dir is not None
    # One backbone decode feeds every tenant: tee the tick source N ways
    # (the scheduler keeps tenants within one time slice of each other, so
    # the tee buffer stays bounded by the quantum).
    shared = itertools.tee(
        _decode_feats(params, state, prompts, cfg, gen_tokens), tenants
    )
    if durable:
        # Durability additionally needs a *seekable* source per tenant: the
        # live path keeps sharing the one tee'd decode (cursor 0), and only
        # an actual resume (cursor k > 0) pays for a fresh decode replayed
        # to the snapshot's tick cursor — the backbone is deterministic.
        # (Caveat: a tenant that resumes leaves its tee branch unconsumed,
        # pinning the tee buffer for this run — fine at serve scale, and
        # only on runs that actually resumed.)
        def make_feed(branch):
            def factory(start, branch=branch):
                if start == 0:
                    return branch
                return itertools.islice(
                    _decode_feats(params, state, prompts, cfg, gen_tokens),
                    start, None,
                )
            return snapshot.ResumableTicks(factory)

        feeds = [make_feed(b) for b in shared]
    else:
        feeds = shared

    with contextlib.ExitStack() as stack:
        def make_teacher(i):
            if teacher == "rpc":
                # Only the migration path lands here: a migrated tenant is
                # conceptually on a new host, so it gets a FRESH shared
                # connection (own handshake), not a handle on the old one.
                client = rpc.BatchedRpcClient(
                    host, port, timeout_s=rpc_timeout_s, secret=teacher_secret,
                    batch_window_s=teacher_batch_window_s,
                    batch_max=teacher_batch_max, compress=teacher_compress,
                )
                stack.callback(client.close)
                return client.tenant(name=f"tenant{i}")
            # The smoke teacher predicts random classes (a real deployment
            # points label_fn at the pod-side backbone ensemble);
            # latency/jitter/loss model the BLE/network round-trip in
            # decode ticks, per tenant.
            rng = np.random.default_rng(seed + i)
            return stream.LatencyTeacher(
                label_fn=lambda tick, feats: rng.integers(
                    0, cfg.odl.n_out, size=batch
                ),
                latency=teacher_latency, jitter=teacher_jitter,
                loss_prob=teacher_loss, seed=seed + i,
            )

        if teacher == "rpc":
            host, port = stack.enter_context(
                rpc.loopback_server(n_out=cfg.odl.n_out, secret=teacher_secret)
            )
            # Default transport: every tenant with the same endpoint shares
            # one batched connection — one socket, one HMAC handshake, asks
            # coalesced into single frames within the flush window.
            rpc_teachers, rpc_clients = multiplex.shared_rpc_teachers(
                [(host, port)] * tenants, timeout_s=rpc_timeout_s,
                secret=teacher_secret, batch_window_s=teacher_batch_window_s,
                batch_max=teacher_batch_max, compress=teacher_compress,
            )
            for client in rpc_clients:
                stack.callback(client.close)
            teachers = {f"tenant{i}": t for i, t in enumerate(rpc_teachers)}
        else:
            teachers = {f"tenant{i}": make_teacher(i) for i in range(tenants)}

        tenant_list = [
            multiplex.Tenant(
                name=f"tenant{i}",
                state=engine.init_fleet(odl_cfg, batch),
                ticks=feeds[i],
                cfg=odl_cfg,
                teacher=teachers[f"tenant{i}"],
                mode="serve",  # gate semantics: live drift detector,
                # condition-2 forced queries, controller always armed
                capacity=pending_capacity,
                backpressure=backpressure,
                collect=False,  # long-running servers keep no history
            )
            for i in range(tenants)
        ]
        if resume and snapshot_dir is None:
            raise ValueError("--resume needs --snapshot-dir (nothing to "
                             "restore from otherwise)")
        mux = multiplex.Multiplexer(
            tenant_list, sched=sched, snapshot_dir=snapshot_dir,
            snapshot_every=snapshot_every, resume=resume, fuse=fuse_cohorts,
            # Migration wants to stop mid-stream: schedule tick by tick so
            # the threshold check below lands before the stream drains.
            quantum=1 if migrate else multiplex.DEFAULT_QUANTUM,
        )
        if migrate:
            # Live migration demo: run until tenant0 is mid-stream, quiesce
            # + snapshot + extract it, restore it into a second multiplexer
            # behind a FRESH teacher (a migration lands on a new host: the
            # old socket/object is gone), finish both, merge the reports.
            while mux.round():
                if mux.session("tenant0").t >= max(2, gen_tokens // 2):
                    break
            if mux.finished("tenant0"):
                # Too few tokens for a mid-stream cut: nothing to migrate.
                print("tenant0 finished before the migration point "
                      "(--tokens too small); serving without migration")
                migrate = False
        if migrate:
            tree, rest_ticks = mux.extract("tenant0")
            results, agg = mux.run()  # finish the remaining tenants
            fresh = make_teacher(0)
            teachers["tenant0"] = fresh
            # pending="reask": the destination teacher is a new connection
            # on a (conceptually) new host — never restore the old teacher's
            # state into it, re-ask whatever is still in flight.
            mux_b = multiplex.Multiplexer([], sched=sched, pending="reask",
                                          fuse=fuse_cohorts)
            mux_b.admit(
                multiplex.Tenant(
                    name="tenant0", state=None, ticks=rest_ticks, cfg=odl_cfg,
                    teacher=fresh, mode="serve", capacity=pending_capacity,
                    backpressure=backpressure, collect=False,
                ),
                snapshot=tree,
                positioned=True,  # rest_ticks is extract()'s live iterator
            )
            results_b, agg_b = mux_b.run()
            migrated = results_b["tenant0"]
            print(f"tenant0 migrated at tick {snapshot.ticks_consumed(tree)} "
                  f"(re-asked {migrated.stats.tickets_reasked} in-flight "
                  f"tickets through the fresh teacher)")
            results = {**results, "tenant0": migrated}
            agg.stream_steps += agg_b.stream_steps
            agg.ticks += agg_b.ticks
            agg.wall_s += agg_b.wall_s
            agg.n_tenants = tenants
        else:
            results, agg = mux.run()

        # Pull every meter into the registry while the teachers are still
        # alive, and scrape the label server's own counters over the wire
        # (it is a subprocess — this is the only way to see them).
        mux.sync_telemetry()
        if migrate:
            mux_b.sync_telemetry()
        label_server_stats = None
        if teacher == "rpc":
            for client in rpc_clients:
                client.sync_telemetry()
            label_server_stats = rpc.server_stats(host, port,
                                                  secret=teacher_secret)

    # ONE render over the registry view — shared with the mesh and fleet
    # paths, so the per-tenant counter block cannot drift between them.
    report = _print_stream_report(
        telemetry.parse_prometheus(tel.registry.prometheus_text()))
    queries = report["queries_issued"]
    skips = report["stream_steps"] - report["queries_issued"]
    for name in sorted(results):  # details the registry doesn't carry
        r = results[name]
        s = r.stats
        meter_kb = float(np.asarray(r.state.meter.total).sum()) / 1e3
        rpc_note = (
            f"; rpc timeouts {teachers[name].timed_out}"
            if teacher == "rpc" else ""
        )
        print(f"  {name}: tick p50/p95 {s.tick_p50_ms:.2f}/{s.tick_p95_ms:.2f}"
              f" ms; label latency p50/p95 {s.label_latency_p50:.0f}/"
              f"{s.label_latency_p95:.0f} ticks; "
              f"{meter_kb:.1f} kB metered{rpc_note}")
        if not s.reconciled:
            raise AssertionError(f"{name}: query accounting does not reconcile: "
                                 f"{s.summary()}")
    if not report["identity_ok"]:
        raise AssertionError("scraped metrics identity does not hold")
    if label_server_stats is not None:
        _print_label_server_stats(label_server_stats)
    caches = stream.cache_stats()["plan_runner"]
    extras = f", sched={sched}"
    if durable:
        extras += f", snapshots under {snapshot_dir} every {snapshot_every} ticks"
    print(f"aggregate: {tenants} tenant(s) x {gen_tokens} tokens x {batch} streams "
          f"= {agg.stream_steps} steps in {agg.wall_s:.2f}s "
          f"({agg.steps_per_s:,.0f} steps/s); backpressure={backpressure}, "
          f"teacher={teacher}{extras}; plan-runner cache "
          f"{caches['hits']} hits / {caches['misses']} misses "
          f"(tenants share executables)")
    if metrics_json:
        _write_metrics_json(metrics_json, {
            "mode": "solo", "tenants": tenants, "tokens": gen_tokens,
            "report": report,
            "aggregate": {"stream_steps": agg.stream_steps,
                          "wall_s": agg.wall_s,
                          "steps_per_s": agg.steps_per_s},
            "prometheus": tel.registry.prometheus_text(),
            "registry": tel.registry.snapshot(),
            "label_server": label_server_stats,
        }, {"": tel.tracer.chrome_trace()})
    return queries, skips


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=1,
                    help="independent ODL fleets multiplexed over this process")
    ap.add_argument("--backpressure", default="drop_oldest",
                    choices=stream.BACKPRESSURE_POLICIES,
                    help="pending-ring saturation policy (per tenant)")
    ap.add_argument("--sched", default="rr", choices=multiplex.SCHEDULERS,
                    help="rr: fixed quantum-tick round robin; drr: deficit "
                    "round robin in stream-step units (size-fair)")
    ap.add_argument("--fuse-cohorts", default="on", choices=("on", "off"),
                    help="stack same-shaped tenants into one batched "
                    "plan/learn dispatch per quantum (bit-for-bit identical "
                    "to unfused; off: one dispatch per tenant)")
    ap.add_argument("--teacher", default="latency", choices=("latency", "rpc"),
                    help="latency: in-process tick-granular model; "
                    "rpc: loopback TCP label server with timeout->loss")
    ap.add_argument("--teacher-latency", type=int, default=1,
                    help="teacher answer latency in decode ticks")
    ap.add_argument("--teacher-jitter", type=int, default=0,
                    help="extra uniform per-ticket latency in [0, J] ticks")
    ap.add_argument("--teacher-loss", type=float, default=0.0,
                    help="fraction of tickets silently lost by the teacher")
    ap.add_argument("--teacher-secret", default=None,
                    help="shared secret: HMAC-authenticate the rpc teacher "
                    "connection (both ends)")
    ap.add_argument("--rpc-timeout", type=float, default=5.0,
                    help="rpc teacher reply deadline in wall seconds")
    ap.add_argument("--teacher-batch-window", type=float,
                    default=rpc.DEFAULT_BATCH_WINDOW_S * 1e3,
                    help="rpc ask-coalescing flush window in ms (asks from "
                    "all tenants landing within it ride one frame; 0 sends "
                    "one frame per ask)")
    ap.add_argument("--teacher-batch-max", type=int,
                    default=rpc.DEFAULT_BATCH_MAX,
                    help="max asks coalesced into one rpc frame")
    ap.add_argument("--teacher-compress", action="store_true",
                    help="wrap rpc frames in zlib envelopes (negotiated in "
                    "the HMAC handshake when --teacher-secret is set)")
    ap.add_argument("--mesh-fleet", type=int, default=0,
                    help="shard the (single) tenant's stream axis over this "
                    "many devices on a ('fleet',) mesh — one shard-local "
                    "session (ring + teacher + dispatch) per device; 0: off "
                    "(on CPU, force devices via "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--pending-capacity", type=int, default=8,
                    help="in-flight query ring capacity (see --backpressure)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="publish per-tenant session snapshots here "
                    "(atomic, keep-k) — enables --resume")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in decode ticks (0: only explicit)")
    ap.add_argument("--resume", action="store_true",
                    help="restore every tenant from its latest published "
                    "snapshot under --snapshot-dir")
    ap.add_argument("--migrate", action="store_true",
                    help="demo: quiesce+snapshot tenant0 mid-stream and "
                    "restore it into a second multiplexer behind a fresh "
                    "teacher connection")
    ap.add_argument("--workers", type=int, default=0,
                    help="elastic fleet mode: spin this many multiplexer "
                    "worker subprocesses behind the shape-aware router "
                    "(repro.runtime.elastic); 0: single-process serve")
    ap.add_argument("--fleet-ticks", default="synth",
                    choices=("synth", "decode"),
                    help="fleet tick source: synth (per-tick-seeded "
                    "features, O(1) seek) or decode (each worker drives "
                    "the real backbone)")
    ap.add_argument("--fleet-tick-sleep", type=float, default=1.0,
                    help="per-tick sleep in ms (keeps tenants mid-stream "
                    "long enough to migrate)")
    ap.add_argument("--worker-capacity", type=int, default=None,
                    help="max live tenants per worker (default: spread "
                    "--tenants evenly over --workers)")
    ap.add_argument("--no-fleet-migrate", action="store_true",
                    help="fleet mode: skip the mid-stream live migration")
    ap.add_argument("--no-fleet-drain", action="store_true",
                    help="fleet mode: skip the scale-in worker drain")
    ap.add_argument("--snapshot-full-every", type=int, default=8,
                    help="worker cadence saves ship only changed leaves; "
                    "every k-th save is full (1: all saves full)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write run metrics (registry snapshot + Prometheus "
                    "text + per-tenant report) to PATH, plus Chrome "
                    "trace_event files PATH.<tag>.trace.json")
    args = ap.parse_args(argv)
    if args.workers:
        return serve_fleet(
            workers=args.workers, tenants=args.tenants, batch=args.batch,
            gen_tokens=args.tokens, fleet_ticks=args.fleet_ticks,
            arch=args.arch, variant=args.variant,
            tick_sleep_ms=args.fleet_tick_sleep,
            teacher_latency=args.teacher_latency,
            teacher_jitter=args.teacher_jitter,
            teacher_loss=args.teacher_loss,
            pending_capacity=args.pending_capacity,
            backpressure=args.backpressure,
            worker_capacity=args.worker_capacity,
            migrate=not args.no_fleet_migrate,
            drain=not args.no_fleet_drain,
            snapshot_full_every=args.snapshot_full_every,
            metrics_json=args.metrics_json)
    serve(args.arch, args.variant, batch=args.batch, gen_tokens=args.tokens,
          teacher_latency=args.teacher_latency, teacher_jitter=args.teacher_jitter,
          teacher_loss=args.teacher_loss, pending_capacity=args.pending_capacity,
          tenants=args.tenants, backpressure=args.backpressure,
          teacher=args.teacher, rpc_timeout_s=args.rpc_timeout,
          teacher_batch_window_s=args.teacher_batch_window / 1e3,
          teacher_batch_max=args.teacher_batch_max,
          teacher_secret=args.teacher_secret, sched=args.sched,
          snapshot_dir=args.snapshot_dir, snapshot_every=args.snapshot_every,
          resume=args.resume, migrate=args.migrate,
          fuse_cohorts=args.fuse_cohorts == "on",
          teacher_compress=args.teacher_compress,
          mesh_fleet=args.mesh_fleet, metrics_json=args.metrics_json)


if __name__ == "__main__":
    main()
