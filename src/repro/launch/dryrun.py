import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# The dry-run (and ONLY the dry-run) runs with 512 placeholder CPU devices so
# jax.make_mesh can build the production meshes (16x16 and 2x16x16).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (single-pod 256 or multi-pod 512 chips),
  2. constructs abstract params/optimizer/cache state (ShapeDtypeStruct +
     NamedSharding — zero allocation),
  3. ``jax.jit(step).lower(...).compile()`` — any sharding mismatch,
     non-divisible partition, unsupported collective, or compile-time OOM
     is a FAILURE of the framework and crashes the cell,
  4. records ``compiled.memory_analysis()`` (proves it fits),
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline) and the
     collective-byte census parsed from the optimized HLO,
  5. appends one JSON record per cell to ``results/dryrun.jsonl``.

Usage:
  python -m repro.launch.dryrun                       # all cells, 1-pod
  python -m repro.launch.dryrun --multi-pod           # all cells, 2 pods
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --variant sp          # hillclimb variants
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import TrainConfig
from repro.distributed import sharding
from repro.launch.hlo_census import collective_census
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, model as model_lib



def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on 0.4.x."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _rules_for(cfg, variant: str, mesh):
    """Sharding-rule overrides per arch + hillclimb variant."""
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    rules = {}
    if cfg.n_heads % msize != 0:
        # Heads don't divide the model axis (deepseek-coder 56H): fall back
        # to attention sequence-sharding for the score tensors.
        rules["seq_attn"] = "model"
    if "sp" in variant.split("+"):
        # Megatron-style sequence parallelism on the residual stream.
        rules["seq"] = "model"
    if variant == "dp_only":
        rules.update({k: None for k in ("heads", "kv_heads", "mlp", "experts", "vocab")})
    return rules


def _cfg_for_variant(cfg, variant: str):
    """Hillclimb variants that change the model program itself."""
    for part in variant.split("+"):
        if part == "flash":
            cfg = cfg.replace(attention_impl="chunked", attention_chunk=1024)
        elif part == "flash512":
            cfg = cfg.replace(attention_impl="chunked", attention_chunk=512)
        elif part == "ep":
            cfg = cfg.replace(moe_impl="ep")
        elif part == "dus":
            cfg = cfg.replace(cache_update="dus")
    return cfg


def _w16(variant: str) -> bool:
    return "w16" in variant.split("+")


# Per-arch dry-run overrides: models whose f32 master state cannot fit the
# pod (see TrainConfig.param_dtype) and depth/size-driven microbatch counts.
TRAIN_OVERRIDES = {
    # NOTE: temp bytes GROW with microbatch count on this backend (measured
    # 18.9 GB @ mb=8 -> 32.8 GB @ mb=32 — see EXPERIMENTS.md §Perf refuted
    # hypothesis H2), so the override keeps mb moderate.
    "deepseek-v2-236b": dict(param_dtype="bfloat16", microbatches=8),
    "deepseek-coder-33b": dict(microbatches=8),
    "chameleon-34b": dict(microbatches=8),
}


def _train_cell(cfg, shape, tcfg: TrainConfig):
    state = model_lib.abstract_train_state(cfg, tcfg)
    batch = model_lib.input_specs(cfg, shape)

    def step(st, b):
        return model_lib.train_step(st, b, cfg, tcfg)

    # donate_argnums=(0,): the new TrainState aliases the old one — without
    # this, peak memory double-counts params+moments (in + out).
    return jax.jit(step, donate_argnums=(0,)), (state, batch)


def _prefill_cell(cfg, shape, w16: bool = False):
    schema = model_lib.build_schema(cfg)
    params = model_lib.layers.abstract_params(
        schema, dtype=jnp.bfloat16 if w16 else jnp.float32
    )
    specs = model_lib.input_specs(cfg, shape)
    if cfg.enc_dec:

        def step(p, frames):
            return model_lib.encdec_prefill(p, frames, cfg, max_len=shape.seq_len)

        return jax.jit(step), (params, specs["frames"])

    def step(p, tokens):
        return model_lib.prefill(p, tokens, cfg)

    return jax.jit(step), (params, specs["tokens"])


def _decode_cell(cfg, shape, w16: bool = False):
    schema = model_lib.build_schema(cfg)
    params = model_lib.layers.abstract_params(
        schema, dtype=jnp.bfloat16 if w16 else jnp.float32
    )
    token = model_lib.input_specs(cfg, shape)["token"]
    b = shape.global_batch
    if cfg.enc_dec:
        # Decoder decode: self cache + precomputed cross K/V over the source.
        enc_sds = model_lib._sds((b, min(shape.seq_len, cfg.max_source_len), cfg.d_model),
                                 jnp.bfloat16, "batch", "seq_kv", "embed")
        caches = jax.eval_shape(
            lambda e: encdec.init_caches(
                model_lib.layers.abstract_params(schema), e, cfg, shape.seq_len
            ),
            enc_sds,
        )
        caches = model_lib._abstract_like(
            caches, model_lib._axes_like(caches, model_lib.cache_axes)
        )
        pos = model_lib._sds((b,), jnp.int32, "stream")

        def step(p, tok, c, q):
            return encdec.decode_step(p, tok, c, q, cfg)

        return jax.jit(step), (params, token, caches, pos)

    state = model_lib.abstract_serve_state(cfg, b, shape.seq_len)

    def step(p, st, tok):
        return model_lib.serve_step(p, st, tok, cfg)

    # Donate the serve state: the KV cache updates in place.
    return jax.jit(step, donate_argnums=(1,)), (params, state, token)


def _cell_for(cfg, shape, tcfg_mb, w16: bool = False):
    if shape.kind == "train":
        return _train_cell(cfg, shape, tcfg_mb)
    if shape.kind == "prefill":
        return _prefill_cell(cfg, shape, w16)
    return _decode_cell(cfg, shape, w16)


def _cost_compile(cfg, shape, mesh, rules, param_dtype, w16=False):
    """Compile one (possibly shrunk+unrolled) variant; return cost numbers."""
    with sharding.activate(mesh, rules):
        jitted, args = _cell_for(
            cfg, shape, TrainConfig(microbatches=1, param_dtype=param_dtype), w16
        )
        compiled = jitted.lower(*args).compile()
    cost = _cost_dict(compiled)
    census = collective_census(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(census.get("total_bytes", 0)),
    }


def cost_extrapolation(cfg, shape, mesh, rules, param_dtype, w16=False) -> dict:
    """XLA cost_analysis counts loop bodies ONCE, so scanned stacks
    under-report by the trip count.  We compile UNROLLED shrunk variants at
    two depths and extrapolate linearly: cost(L) = a + b*L  (embed/logits/
    optimizer in `a`, per-layer in `b`).  Hybrid stacks extrapolate in
    pattern-groups; enc-dec varies both stacks together (equal depths)."""
    if cfg.hybrid_pattern:
        p = len(cfg.hybrid_pattern)
        l1, l2 = p, 2 * p  # 1 and 2 full groups, no tail
    else:
        l1, l2 = 1, 2

    def shrink(n):
        kw = dict(n_layers=n, unroll_layers=True)
        if cfg.enc_dec:
            kw["n_enc_layers"] = n
        return cfg.replace(**kw)

    c1 = _cost_compile(shrink(l1), shape, mesh, rules, param_dtype, w16)
    c2 = _cost_compile(shrink(l2), shape, mesh, rules, param_dtype, w16)
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (c2[k] - c1[k]) / (l2 - l1)
        base = c1[k] - slope * l1
        out[k] = base + slope * cfg.n_layers
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant: str = "base",
             microbatches: int = 8) -> dict:
    cfg = _cfg_for_variant(configs.get_config(arch), variant)
    shape = configs.shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "kind": shape.kind,
        "n_devices": mesh.size,
    }
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        rec.update(status="skipped", reason="full attention is quadratic at 524k")
        return rec
    if shape.kind == "decode" and cfg.enc_dec and shape.name == "long_500k":
        rec.update(status="skipped", reason="enc-dec decoder capped below 500k")
        return rec

    t0 = time.time()
    with sharding.activate(mesh, _rules_for(cfg, variant, mesh)):
        if shape.kind == "train":
            over = dict(TRAIN_OVERRIDES.get(arch, {}))
            mb = min(over.pop("microbatches", microbatches), shape.global_batch)
            jitted, args = _train_cell(cfg, shape, TrainConfig(microbatches=mb, **over))
        elif shape.kind == "prefill":
            jitted, args = _prefill_cell(cfg, shape, _w16(variant))
        else:
            jitted, args = _decode_cell(cfg, shape, _w16(variant))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    census = collective_census(compiled.as_text())
    arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
    out_b = int(getattr(mem, "output_size_in_bytes", 0))
    tmp_b = int(getattr(mem, "temp_size_in_bytes", 0))
    alias_b = int(getattr(mem, "alias_size_in_bytes", 0))
    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        raw_flops=float(cost.get("flops", 0.0)),
        raw_bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        argument_bytes=arg_b,
        output_bytes=out_b,
        temp_bytes=tmp_b,
        alias_bytes=alias_b,
        # Live bytes per device: donated outputs alias their inputs.
        peak_bytes=arg_b + max(out_b - alias_b, 0) + tmp_b,
        collectives=census,
    )

    # Roofline cost terms (single-pod only, per the assignment): correct the
    # loop-body undercount via unrolled 1-/2-layer extrapolation.
    if not multi_pod:
        over = TRAIN_OVERRIDES.get(arch, {})
        ext = cost_extrapolation(
            cfg, shape, mesh, _rules_for(cfg, variant, mesh),
            over.get("param_dtype", "float32"), _w16(variant),
        )
        rec.update(
            flops=ext["flops"],
            bytes_accessed=ext["bytes"],
            collective_bytes=ext["coll"],
        )
    else:
        rec.update(
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collective_bytes=float(census.get("total_bytes", 0)),
        )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
    shapes = [args.shape] if args.shape else [s.name for s in configs.LM_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        rec = run_cell(arch, shape, mp, args.variant, args.microbatches)
                    except Exception as e:  # noqa: BLE001 — report and continue
                        failures += 1
                        rec = {
                            "arch": arch, "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16",
                            "variant": args.variant, "status": "error",
                            "error": f"{type(e).__name__}: {e}",
                        }
                        traceback.print_exc()
                    print(json.dumps({k: v for k, v in rec.items() if k != "collectives"}))
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
    print(f"done; failures={failures}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
