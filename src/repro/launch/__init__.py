"""repro.launch"""
