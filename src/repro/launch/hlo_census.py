"""Collective-byte census over optimized HLO text.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module: every ``all-reduce`` / ``all-gather`` / ``reduce-scatter``
/ ``all-to-all`` / ``collective-permute`` instruction contributes its
RESULT shape bytes (tuple shapes summed).  This is the per-device traffic
estimator used by the roofline's collective term.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-reduce.5 = f32[256,1024]{1,0} all-reduce(...)
#       ROOT %r = (f32[8,16]{...}, u32[]) all-to-all(...)
_INSTR = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_census(hlo_text: str) -> dict:
    """Returns {op_kind: {"count": int, "bytes": int}, "total_bytes": int}.

    ``*-done`` ops are skipped (their ``*-start`` carries the shape), so
    async pairs are not double-counted.
    """
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _INSTR.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        b = _shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    result = {k: dict(v) for k, v in out.items()}
    result["total_bytes"] = sum(v["bytes"] for v in out.values())
    return result
