"""Training driver: data pipeline -> pjit train_step -> checkpoint/restart.

CPU-runnable end-to-end (reduced configs); the same loop drives the
production mesh (the dry-run proves the step compiles there).  Fault
tolerance: async keep-k checkpoints, NaN-guard rollback, deterministic
seekable data (restore step N -> identical remaining stream).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --variant smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import TrainConfig
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import model as model_lib
from repro.runtime import fault
from repro.runtime.checkpoint import CheckpointManager


def make_step(cfg, tcfg):
    def step(state, batch):
        return model_lib.train_step(state, batch, cfg, tcfg)

    return jax.jit(step, donate_argnums=(0,))


def train(
    arch: str,
    variant: str = "smoke",
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    seed: int = 0,
    microbatches: int = 1,
    log_every: int = 10,
):
    cfg = configs.get_config(arch, variant)
    tcfg = TrainConfig(microbatches=microbatches)
    stream = TokenStream(
        TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
                          n_domains=cfg.odl.n_out, seed=seed)
    )
    step_fn = make_step(cfg, tcfg)
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    guard = fault.NaNGuard(mgr) if mgr else None

    start = 0
    if mgr and mgr.latest_step() is not None:
        start, state = mgr.restore()
        print(f"restored checkpoint at step {start}")
    else:
        state = model_lib.init_train_state(cfg, jax.random.PRNGKey(seed), tcfg)

    losses = []
    t0 = time.time()
    for step in range(start + 1, steps + 1):
        batch_np = stream.batch(step)
        if cfg.enc_dec:
            rng = np.random.default_rng(step)
            batch_np["frames"] = rng.normal(
                0, 1, (batch, seq, cfg.d_model)
            ).astype(np.float32)
        state, metrics = step_fn(state, {k: jax.numpy.asarray(v) for k, v in batch_np.items()})
        loss = float(metrics["loss"])
        losses.append(loss)
        if guard:
            state2, rstep, rolled = guard.check(step, metrics, state)
            if rolled:
                state = state2
                continue
        if mgr and step % ckpt_every == 0:
            mgr.save_async(step, state)
        if step % log_every == 0 or step == steps:
            print(
                f"step {step:5d} loss {loss:8.4f} odl_q {float(metrics['odl_query_frac']):.2f}"
                f" odl_acc {float(metrics['odl_acc']):.2f} theta {float(metrics['odl_theta']):.2f}"
                f" ({(time.time()-t0)/max(step-start,1):.2f}s/step)"
            )
    if mgr:
        mgr.wait()
    return state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _, losses = train(
        args.arch, args.variant, args.steps, args.batch, args.seq,
        args.ckpt_dir, microbatches=args.microbatches, seed=args.seed,
    )
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
