"""Production mesh construction (multi-pod dry-run §1).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and only then calls this.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x has no AxisType at all.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_dev_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess with 4-8 devices)."""
    return _make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n: int = 0):
    """1-D ``("fleet",)`` mesh over ``n`` devices (default: all visible).

    The ODL fleet's stream axis shards over this axis (sharding
    DEFAULT_RULES maps ``stream -> ("fleet", ...)``).  On a CPU host, force
    the device count first: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    avail = len(jax.devices())
    if n <= 0:
        n = avail
    if n > avail:
        raise ValueError(f"mesh-fleet {n} > {avail} visible devices")
    return _make_mesh((n,), ("fleet",))
