"""repro.runtime"""
