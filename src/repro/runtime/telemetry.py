"""Process-local observability core: metrics registry + span tracer.

The repo's five runtime subsystems (stream sessions, cohorts, RPC
transport, workers, router) each grew private counters — ``StreamStats``,
``MultiplexStats``, ``BatchedRpcClient`` wire meters, ``LabelServer``
request counters — that are only readable at end of run, in-process.
This module gives them one shared, scrape-able surface:

* a metrics **registry** — named counters / gauges / histograms with
  label sets (``{tenant, worker, shard, cohort}``), exported as
  Prometheus text exposition or a JSON snapshot; and
* a bounded ring-buffer **span tracer** — monotonic-clock spans for tick
  plan/learn, teacher ask→reply, ring evictions, snapshot save/restore,
  RPC flush/reconnect, cohort pack/dissolve, and migration
  extract→ship→admit — exported as Chrome ``trace_event`` JSON (open it
  in ``chrome://tracing`` / Perfetto) or a JSONL event log.

Design constraints (these are load-bearing — the streaming hot path is
instrumented per tick and ``benchmarks/stream_bench.py`` gates the
overhead at <2%):

* **Disabled is branch-cheap.**  Telemetry is off by default; the global
  ``TELEMETRY`` is ``None`` and every instrumentation site is one module
  attribute read plus an ``is not None`` branch.  Nothing is allocated,
  no lock is taken, no clock is read.
* **Enabled is sampled.**  ``SpanTracer`` records one in ``sample``
  begin/end spans per name (rare events — evictions, reconnects,
  migrations — always record); the ring is bounded (``deque(maxlen)``)
  so a long-running worker never grows trace memory.
* **Counters are mirrored, not forked.**  ``StreamStats`` stays the
  single source of truth for query accounting; ``sync_stream_stats``
  copies its fields into the registry at sync points (tick-loop
  boundaries, ``finish()``, live scrapes) via absolute ``set_counter``
  writes, so the two views are *identical* by construction
  (tests/test_telemetry.py locks this for all four backpressure
  policies).  Telemetry never touches the device op sequence —
  bit-for-bit parity with an uninstrumented run is part of the lock.

Snapshot semantics: the registry and trace ring are **process-local and
intentionally excluded from session snapshots** — a migrated tenant's
``StreamStats`` (including ``tick_rate_ema`` / ``ring_occupancy_hwm``)
rides the snapshot and re-mirrors on the destination, but spans recorded
on the source stay on the source.  Parity tests exclude the tracer for
exactly this reason.
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import threading
import time
from typing import Optional

from repro.runtime import lockdebug

__all__ = [
    "Registry", "SpanTracer", "Telemetry", "TELEMETRY",
    "enable", "disable", "get",
    "sync_stream_stats", "parse_prometheus", "check_stream_identity",
    "STREAM_COUNTER_FIELDS", "STREAM_GAUGE_FIELDS", "STREAM_MIRROR_EXCLUDED",
]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: tuple) -> str:
    if not key:
        return ""
    esc = lambda v: v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in key) + "}"


class Registry:
    """Named counters / gauges / histograms with label sets.

    Counters support both relative ``count`` (hot-path increments: mux
    rounds, RPC flushes) and absolute ``set_counter`` (mirroring an
    authoritative source like ``StreamStats``).  Histograms keep
    count/sum/min/max — enough for occupancy and size distributions
    without per-observation storage.  All methods are thread-safe (one
    lock; the RPC client's flush thread and the worker's control thread
    write concurrently with the tick loop).
    """

    def __init__(self):
        self._lock = lockdebug.make_lock("telemetry.Registry._lock")
        # name -> {label_key: value}
        self._counters: "dict[str, dict[tuple, float]]" = {}
        self._gauges: "dict[str, dict[tuple, float]]" = {}
        # name -> {label_key: [count, total, min, max]}
        self._hists: "dict[str, dict[tuple, list]]" = {}

    # -- writes ------------------------------------------------------------

    def count(self, name: str, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[key] = series.get(key, 0.0) + value

    def set_counter(self, name: str, value: float, **labels) -> None:
        """Absolute write — for mirroring a counter whose source of truth
        lives elsewhere (``StreamStats`` fields)."""
        with self._lock:
            self._counters.setdefault(name, {})[_label_key(labels)] = float(value)

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges.setdefault(name, {})[_label_key(labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = _label_key(labels)
        v = float(value)
        with self._lock:
            h = self._hists.setdefault(name, {}).get(key)
            if h is None:
                self._hists[name][key] = [1, v, v, v]
            else:
                h[0] += 1
                h[1] += v
                h[2] = min(h[2], v)
                h[3] = max(h[3], v)

    # -- reads -------------------------------------------------------------

    def get_counter(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(name, {}).get(_label_key(labels), 0.0)

    def get_gauge(self, name: str, **labels) -> float:
        with self._lock:
            return self._gauges.get(name, {}).get(_label_key(labels), 0.0)

    def snapshot(self) -> dict:
        """JSON-able dump: ``{"counters": {name: [{"labels": {...},
        "value": v}, ...]}, "gauges": ..., "histograms": ...}``."""
        def rows(series):
            return [
                {"labels": dict(key), "value": value}
                for key, value in sorted(series.items())
            ]

        with self._lock:
            return {
                "counters": {n: rows(s) for n, s in sorted(self._counters.items())},
                "gauges": {n: rows(s) for n, s in sorted(self._gauges.items())},
                "histograms": {
                    n: [
                        {
                            "labels": dict(key),
                            "count": h[0], "sum": h[1], "min": h[2], "max": h[3],
                        }
                        for key, h in sorted(s.items())
                    ]
                    for n, s in sorted(self._hists.items())
                },
            }

    def prometheus_text(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        lines = []
        with self._lock:
            for name, series in sorted(self._counters.items()):
                lines.append(f"# TYPE {name} counter")
                for key, value in sorted(series.items()):
                    lines.append(f"{name}{_fmt_labels(key)} {_num(value)}")
            for name, series in sorted(self._gauges.items()):
                lines.append(f"# TYPE {name} gauge")
                for key, value in sorted(series.items()):
                    lines.append(f"{name}{_fmt_labels(key)} {_num(value)}")
            for name, series in sorted(self._hists.items()):
                lines.append(f"# TYPE {name} summary")
                for key, h in sorted(series.items()):
                    lines.append(f"{name}_count{_fmt_labels(key)} {h[0]}")
                    lines.append(f"{name}_sum{_fmt_labels(key)} {_num(h[1])}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


def _num(v: float) -> str:
    # Integral values print without a trailing .0 — counters are ints in
    # spirit and the cross-check tests compare exact values.
    return str(int(v)) if float(v).is_integer() else repr(float(v))


class SpanTracer:
    """Bounded ring buffer of monotonic-clock spans.

    ``begin(name)`` returns an opaque token (or None when the span is
    sampled out — every instrumentation site must handle None);
    ``end(token, **labels)`` records it.  ``event`` records an instant
    (zero-duration, never sampled — evictions, reconnects, drops are rare
    and individually meaningful).  The ring is ``deque(maxlen=capacity)``:
    old spans fall off, memory is bounded, nothing is ever flushed on the
    hot path.
    """

    def __init__(self, capacity: int = 8192, sample: int = 1):
        self.capacity = int(capacity)
        self.sample = max(1, int(sample))
        self._ring: "collections.deque" = collections.deque(maxlen=self.capacity)
        self._lock = lockdebug.make_lock("telemetry.SpanTracer._lock")
        self._seq: "dict[str, int]" = {}
        self.dropped = 0  # sampled-out spans (visibility into what's missing)

    def begin(self, name: str):
        if self.sample > 1:
            with self._lock:
                n = self._seq.get(name, 0)
                self._seq[name] = n + 1
                if n % self.sample:
                    # Bumped by every session thread — a plain += outside
                    # the lock loses updates under contention.
                    self.dropped += 1  # odlint: guarded-by(_lock)
                    return None
        return (name, time.monotonic_ns())

    def end(self, token, **labels) -> None:
        if token is None:
            return
        name, t0 = token
        now = time.monotonic_ns()
        self._ring.append(
            (name, t0, now - t0, threading.get_ident(), labels or None)
        )

    @contextlib.contextmanager
    def span(self, name: str, **labels):
        tok = self.begin(name)
        try:
            yield
        finally:
            self.end(tok, **labels)

    def event(self, name: str, **labels) -> None:
        self._ring.append(
            (name, time.monotonic_ns(), 0, threading.get_ident(), labels or None)
        )

    # -- exporters ---------------------------------------------------------

    def spans(self) -> list:
        """Snapshot of the ring, oldest first:
        ``(name, t0_ns, dur_ns, tid, labels|None)`` tuples."""
        return list(self._ring)

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (open in chrome://tracing or
        https://ui.perfetto.dev): complete ('X') events for spans, instant
        ('i') events for zero-duration ones."""
        pid = os.getpid()
        events = []
        for name, t0, dur, tid, labels in self.spans():
            ev = {
                "name": name,
                "cat": name.split(".", 1)[0],
                "ts": t0 / 1e3,  # trace_event wants microseconds
                "pid": pid,
                "tid": tid,
                "args": labels or {},
            }
            if dur:
                ev["ph"] = "X"
                ev["dur"] = dur / 1e3
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_jsonl(self) -> str:
        """One JSON object per line — greppable event log."""
        lines = []
        for name, t0, dur, tid, labels in self.spans():
            row = {"name": name, "ts_ns": t0, "dur_ns": dur, "tid": tid}
            if labels:
                row.update(labels)
            lines.append(json.dumps(row, default=str))
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        self._ring.clear()
        with self._lock:
            self._seq.clear()
            self.dropped = 0


class Telemetry:
    """The enabled-state bundle: one registry + one tracer."""

    def __init__(self, span_capacity: int = 8192, span_sample: int = 1):
        self.registry = Registry()
        self.tracer = SpanTracer(capacity=span_capacity, sample=span_sample)


# The global switch.  ``None`` == disabled: instrumentation sites read
# this once per call and branch — no allocation, no lock, no clock.
TELEMETRY: Optional[Telemetry] = None


def enable(span_capacity: int = 8192, span_sample: int = 1) -> Telemetry:
    """Turn telemetry on process-wide (idempotent: an existing enabled
    instance is kept so counters survive repeated calls)."""
    global TELEMETRY
    if TELEMETRY is None:
        TELEMETRY = Telemetry(span_capacity=span_capacity, span_sample=span_sample)
    return TELEMETRY


def disable() -> None:
    global TELEMETRY
    TELEMETRY = None


def get() -> Optional[Telemetry]:
    return TELEMETRY


# ---------------------------------------------------------------------------
# StreamStats mirroring — one source of truth, two views.
# ---------------------------------------------------------------------------

# Every integer accounting counter of engine.stream.StreamStats, mirrored
# verbatim as ``odl_stream_<field>``.  The query-accounting identity
# (queries_issued == labels_applied + queries_dropped + queries_lost +
# queries_coalesced, plus queries_pending mid-run) is therefore checkable
# from a live scrape, not just an end-of-run dump.
STREAM_COUNTER_FIELDS = (
    "ticks", "stream_steps",
    "tickets_issued", "queries_issued", "labels_applied",
    "tickets_dropped", "queries_dropped", "replies_orphaned",
    "tickets_lost", "queries_lost",
    "tickets_coalesced", "queries_coalesced",
    "asks_deferred", "tickets_reasked",
)

# Load signals: not monotonic, exported as gauges.
STREAM_GAUGE_FIELDS = ("tick_rate_ema", "ring_occupancy_hwm")

# StreamStats fields deliberately NOT mirrored into the registry:
# wall-clock totals and raw per-tick sample deques belong to the
# end-of-run report (histograms of them would re-aggregate what the
# spans already carry).  Every StreamStats field must appear in exactly
# one of COUNTER/GAUGE/EXCLUDED — enforced statically by odlint ODL003
# and at runtime by tests/test_telemetry.py's growth guard.
STREAM_MIRROR_EXCLUDED = ("wall_s", "tick_ms", "label_latency_ticks")


def sync_stream_stats(registry: Registry, stats, pending: Optional[int] = None,
                      **labels) -> None:
    """Mirror a ``StreamStats`` into ``registry`` (absolute writes — the
    stats object stays the source of truth).  ``pending`` is the session's
    in-flight query count (``StreamSession.pending_queries()``): with it,
    the scraped identity ``issued == applied + dropped + lost + coalesced
    + pending`` holds at *any* instant, not just after a drain."""
    for f in STREAM_COUNTER_FIELDS:
        registry.set_counter(f"odl_stream_{f}", getattr(stats, f), **labels)
    for f in STREAM_GAUGE_FIELDS:
        registry.gauge(f"odl_stream_{f}", float(getattr(stats, f)), **labels)
    if pending is not None:
        registry.gauge("odl_stream_queries_pending", float(pending), **labels)


# ---------------------------------------------------------------------------
# Prometheus exposition parsing — used by the CI smoke (scrape a live
# worker, check the text actually parses and the identity holds) and by
# fleet-level aggregation.
# ---------------------------------------------------------------------------


def _unescape(v: str) -> str:
    # Sequential scan, not chained str.replace — ``\\n`` is an escaped
    # backslash followed by a literal 'n', NOT a newline.
    out = []
    i = 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append("\n" if nxt == "n" else nxt)
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def parse_prometheus(text: str) -> dict:
    """Parse text exposition back to ``{(name, label_key): value}``.

    Minimal but strict for what the registry emits: raises ValueError on
    a malformed sample line, so the CI check "the exposition parses"
    means something."""
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, _, value = line.rpartition(" ")
        if not body:
            raise ValueError(f"malformed sample line: {line!r}")
        if body.endswith("}"):
            name, _, rest = body.partition("{")
            if not rest.endswith("}"):
                raise ValueError(f"malformed labels: {line!r}")
            labels = {}
            rest = rest[:-1]
            if rest:
                for part in rest.split(","):
                    k, _, v = part.partition("=")
                    if not (v.startswith('"') and v.endswith('"')):
                        raise ValueError(f"malformed label value: {line!r}")
                    labels[k] = _unescape(v[1:-1])
            key = _label_key(labels)
        else:
            name, key = body, ()
        out[(name, key)] = float(value)
    return out


def check_stream_identity(parsed: dict) -> dict:
    """Per-label-set query-accounting identity over a *scraped* view.

    ``parsed`` is ``parse_prometheus`` output.  For every label set that
    carries ``odl_stream_queries_issued``, checks

        issued == applied + dropped + lost + coalesced + pending

    (``pending`` defaults to 0 when the gauge is absent — e.g. an
    end-of-run scrape after drain).  Returns ``{label_key: bool}``; an
    empty dict means the scrape carried no stream counters at all, which
    callers should treat as a failure, not a pass.
    """
    out = {}
    for (name, key), issued in parsed.items():
        if name != "odl_stream_queries_issued":
            continue
        applied = parsed.get(("odl_stream_labels_applied", key), 0.0)
        dropped = parsed.get(("odl_stream_queries_dropped", key), 0.0)
        lost = parsed.get(("odl_stream_queries_lost", key), 0.0)
        coalesced = parsed.get(("odl_stream_queries_coalesced", key), 0.0)
        pending = parsed.get(("odl_stream_queries_pending", key), 0.0)
        out[key] = issued == applied + dropped + lost + coalesced + pending
    return out
