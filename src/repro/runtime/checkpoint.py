"""Sharded checkpointing: atomic, async, keep-k — no orbax in this container.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json     — tree structure, shapes, dtypes, write status
        <leaf-path>.npy   — one file per pytree leaf (full logical array)
    <dir>/step_000123.tmp — staging dir, atomically renamed on completion

Fault-tolerance properties:
  * atomic publish: readers never observe a partial checkpoint (rename(2));
  * async: `save_async` snapshots device arrays to host, then writes on a
    background thread so the train loop keeps stepping;
  * keep-k garbage collection;
  * `latest_step` skips unpublished (crashed mid-write) checkpoints, so
    restart after a mid-save failure falls back to the previous good step —
    the restore path of the checkpoint/restart story.

On multi-host TPU each host would write only its addressable shards; here
(single CPU host) arrays are fully addressable and written whole, while the
restore path re-shards to whatever mesh is active (runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree


def _unflatten(leaves: dict, manifest):
    if manifest["kind"] == "leaf":
        return leaves[manifest["path"]]
    if manifest["kind"] == "dict":
        return {k: _unflatten(leaves, v) for k, v in manifest["children"].items()}
    seq = [_unflatten(leaves, v) for v in manifest["children"]]
    return tuple(seq) if manifest["kind"] == "tuple" else seq


def _manifest_of(tree, path=()):
    if isinstance(tree, dict):
        return {
            "kind": "dict",
            "children": {k: _manifest_of(tree[k], path + (str(k),)) for k in sorted(tree)},
        }
    if isinstance(tree, (list, tuple)):
        kind = "tuple" if isinstance(tree, tuple) else "list"
        return {
            "kind": kind,
            "children": [_manifest_of(v, path + (str(i),)) for i, v in enumerate(tree)],
        }
    return {"kind": "leaf", "path": "/".join(path)}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree) -> str:
        """Synchronous atomic save."""
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now, write on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # device->host now
        self._thread = threading.Thread(target=self._write, args=(step, host_tree))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        leaves = dict(_flatten(host_tree))
        for path, leaf in leaves.items():
            fn = os.path.join(tmp, "/".join(path).replace("/", "__") + ".npy")
            np.save(fn, np.asarray(leaf))
        manifest = {"step": step, "tree": _manifest_of(host_tree)}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; optionally place leaves with `shardings` (a
        pytree of NamedSharding matching the saved structure) — this is the
        elastic-rescale entry point: the same bytes restore onto any mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(final, "MANIFEST.json")) as f:
            manifest = json.load(f)
        leaves = {}
        for name in os.listdir(final):
            if name.endswith(".npy"):
                leaves[name[: -len(".npy")].replace("__", "/")] = np.load(
                    os.path.join(final, name)
                )
        tree = _unflatten(leaves, manifest["tree"])
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
                tree,
                shardings,
            )
        return step, tree
