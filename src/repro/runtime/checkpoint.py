"""Sharded checkpointing: atomic, async, keep-k, incremental — no orbax here.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json     — tree structure, save kind (full/delta), base step
        <leaf-path>.npy   — one file per *written* pytree leaf
    <dir>/step_000123.tmp — staging dir, atomically renamed on completion

Fault-tolerance properties:
  * atomic publish: readers never observe a partial checkpoint (rename(2));
  * async: `save_async` snapshots device arrays to host, then writes on a
    background thread so the train loop keeps stepping;
  * keep-k garbage collection (delta-chain aware: a kept step's base chain
    is never collected out from under it);
  * `latest_step` skips unpublished (crashed mid-write) checkpoints, so
    restart after a mid-save failure falls back to the previous good step —
    the restore path of the checkpoint/restart story.

Incremental saves (``full_every > 1``): cadence snapshots of a streaming
session re-serialize the full `P` slab (S·N²·4 bytes) every time even when
auto-pruning skipped every query since the last save and nothing learned.
A *delta* save writes only the leaves whose bytes changed since the last
published step and records that step as its base; every ``full_every``-th
save (and the first of a process, and any step-number rewind) is full.
``restore`` transparently composes base+delta by walking the chain, so
readers never know the difference.

On multi-host TPU each host would write only its addressable shards; here
(single CPU host) arrays are fully addressable and written whole, while the
restore path re-shards to whatever mesh is active (``rescale`` below — the
same bytes restore onto any mesh, so growing or shrinking a device mesh is
a restore with new NamedShardings, never a resharding pass over the bytes).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree


def _unflatten(leaves: dict, manifest):
    if manifest["kind"] == "leaf":
        return leaves[manifest["path"]]
    if manifest["kind"] == "dict":
        return {k: _unflatten(leaves, v) for k, v in manifest["children"].items()}
    seq = [_unflatten(leaves, v) for v in manifest["children"]]
    return tuple(seq) if manifest["kind"] == "tuple" else seq


def _manifest_of(tree, path=()):
    if isinstance(tree, dict):
        return {
            "kind": "dict",
            "children": {k: _manifest_of(tree[k], path + (str(k),)) for k in sorted(tree)},
        }
    if isinstance(tree, (list, tuple)):
        kind = "tuple" if isinstance(tree, tuple) else "list"
        return {
            "kind": kind,
            "children": [_manifest_of(v, path + (str(i),)) for i, v in enumerate(tree)],
        }
    return {"kind": "leaf", "path": "/".join(path)}


def _leaf_paths(manifest):
    if manifest["kind"] == "leaf":
        yield manifest["path"]
    elif manifest["kind"] == "dict":
        for v in manifest["children"].values():
            yield from _leaf_paths(v)
    else:
        for v in manifest["children"]:
            yield from _leaf_paths(v)


def _digest(arr: np.ndarray) -> str:
    # dtype+shape fold in so a reshape/retype with identical bytes still
    # counts as changed (the .npy on disk would differ).
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((arr.dtype.str, arr.shape)).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, full_every: int = 1):
        self.dir = directory
        self.keep = keep
        # 1: every save is full (the pre-incremental behavior); k>1: one
        # full save then k-1 deltas, repeating.
        self.full_every = max(1, int(full_every))
        self._thread: Optional[threading.Thread] = None
        # Digests of the last *published composed* tree, for delta diffing.
        # In-memory only: a fresh process always starts with a full save.
        self._published_step: Optional[int] = None
        self._published_digests: dict = {}
        self._since_full = 0
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree) -> str:
        """Synchronous atomic save."""
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now, write on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # device->host now
        self._thread = threading.Thread(target=self._write, args=(step, host_tree))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        blobs = {
            "/".join(path): np.ascontiguousarray(np.asarray(leaf))
            for path, leaf in _flatten(host_tree)
        }
        digests = {p: _digest(a) for p, a in blobs.items()}
        # Re-writing a step (or rewinding) would make a delta its own base
        # after the rmtree below — force full whenever step doesn't advance.
        full = (
            self.full_every <= 1
            or self._published_step is None
            or step <= self._published_step
            or self._since_full >= self.full_every - 1
        )
        if full:
            written = sorted(blobs)
        else:
            prev = self._published_digests
            written = sorted(
                p for p, d in digests.items() if prev.get(p) != d
            )
        for p in written:
            fn = os.path.join(tmp, p.replace("/", "__") + ".npy")
            np.save(fn, blobs[p])
        manifest = {
            "step": step,
            "kind": "full" if full else "delta",
            "base_step": None if full else self._published_step,
            "tree": _manifest_of(host_tree),
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._published_step = step
        self._published_digests = digests
        self._since_full = 0 if full else self._since_full + 1
        self._gc()
        return final

    def _gc(self) -> None:
        if not self.keep:
            return
        steps = self.all_steps()
        kept = set(steps[-self.keep :])
        # A kept delta is useless without its base chain: protect every
        # step reachable through base_step links from a kept step.
        protected = set()
        frontier = list(kept)
        while frontier:
            s = frontier.pop()
            if s in protected:
                continue
            protected.add(s)
            base = self._manifest(s).get("base_step")
            if base is not None and base not in protected:
                frontier.append(base)
        for s in steps:
            if s not in protected:
                shutil.rmtree(
                    os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True
                )

    # -- read ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _manifest(self, step: int) -> dict:
        with open(
            os.path.join(self.dir, f"step_{step:09d}", "MANIFEST.json")
        ) as f:
            return json.load(f)

    def _leaves_in(self, step: int) -> dict:
        final = os.path.join(self.dir, f"step_{step:09d}")
        out = {}
        for name in os.listdir(final):
            if name.endswith(".npy"):
                out[name[: -len(".npy")].replace("__", "/")] = os.path.join(
                    final, name
                )
        return out

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; optionally place leaves with `shardings` (a
        pytree of NamedSharding matching the saved structure) — this is the
        elastic-rescale entry point: the same bytes restore onto any mesh.

        A delta checkpoint composes transparently: leaves it did not write
        are pulled from its base chain (pre-incremental checkpoints have no
        ``kind`` field and read as full)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        manifest = self._manifest(step)
        needed = set(_leaf_paths(manifest["tree"]))
        leaves = {}
        cursor = step
        while needed:
            for p, fn in self._leaves_in(cursor).items():
                if p in needed:
                    leaves[p] = np.load(fn)
                    needed.discard(p)
            if not needed:
                break
            cur_manifest = manifest if cursor == step else self._manifest(cursor)
            base = cur_manifest.get("base_step")
            if base is None:
                raise FileNotFoundError(
                    f"step {step} is missing leaves {sorted(needed)[:4]}... "
                    "and has no base checkpoint to compose them from"
                )
            cursor = base
        tree = _unflatten(leaves, manifest["tree"])
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
                tree,
                shardings,
            )
        return step, tree


# ---------------------------------------------------------------------------
# Elastic mesh rescale: restore any checkpoint onto any mesh
# ---------------------------------------------------------------------------
#
# Checkpoints store full logical arrays, so rescaling from N to M devices is
# a restore with new NamedShardings — no resharding pass over the bytes.
# (These lived in runtime/elastic.py before that module became the fleet
# router; restore-onto-a-mesh is this module's domain.)


def shardings_for_schema(schema, mesh):
    """NamedSharding pytree for a param schema under `mesh`."""
    from jax.sharding import NamedSharding

    from repro.distributed import sharding as shd
    from repro.models import layers as layers_lib

    with shd.activate(mesh):
        specs = layers_lib.param_specs(schema)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def reshard_tree(tree, mesh, specs):
    """Move a live pytree onto `mesh` with PartitionSpecs `specs`."""
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree, specs
    )


def rescale(manager: CheckpointManager, schema, new_mesh, step=None):
    """Restore the latest checkpoint onto a different-size mesh."""
    shards = shardings_for_schema(schema, new_mesh)
    return manager.restore(step=step, shardings=shards)
