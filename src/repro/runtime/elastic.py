"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints store full logical arrays (runtime/checkpoint.py), so rescaling
from N to M devices is a restore with new NamedShardings — no resharding
pass over the bytes is needed.  ``reshard_tree`` also supports live
mesh-to-mesh moves (shrink on failure, grow on capacity).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as shd
from repro.models import layers as layers_lib


def shardings_for_schema(schema, mesh: Mesh):
    """NamedSharding pytree for a param schema under `mesh`."""
    with shd.activate(mesh):
        specs = layers_lib.param_specs(schema)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def reshard_tree(tree, mesh: Mesh, specs):
    """Move a live pytree onto `mesh` with PartitionSpecs `specs`."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree, specs
    )


def rescale(manager, schema, new_mesh: Mesh, step=None):
    """Restore the latest checkpoint onto a different-size mesh."""
    shards = shardings_for_schema(schema, new_mesh)
    return manager.restore(step=step, shardings=shards)
