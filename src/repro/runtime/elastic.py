"""Elastic control plane: a fleet of multiplexer workers behind a
shape-aware router.

``runtime/worker.py`` is one worker — a ``Multiplexer`` behind a control
socket.  This module is the other half: the controller that spawns workers
as subprocesses, decides *where* each tenant runs, and moves them while
they stream:

  * **placement** (``Router.place``) is compiled-shape-affinity first:
    tenants sharing a ``multiplex.shape_key`` land on the same worker so
    its multiplexer can cohort-fuse them into one batched ``fleet_step``
    dispatch.  A per-worker ``capacity`` bounds packing, so four fusable
    tenants over two capacity-2 workers split 2+2 — two fused pairs, not
    one fused quad and an idle worker;
  * **migration** (``Router.migrate``) is extract → ship → admit: the
    source worker snapshots the tenant and returns it as wire bytes
    (``engine.snapshot.encode_snapshot``), the destination restores it
    and the run continues bit-for-bit (a snapshot-capable teacher's
    state — including its undelivered inbox — rides the snapshot; RPC
    teachers are quiesced first and re-ask in-flight tickets, metered
    as ``tickets_reasked``);
  * **rebalance** walks the same path under load: when one worker's
    aggregate tick throughput demand (Σ streams·tick_rate_EMA) exceeds the
    coldest worker's by ``factor``, the hottest tenant moves;
  * **scale-in** (``Router.scale_in``) drains a worker — migrates every
    live tenant off, collects finished results — then shuts it down.

Workers never talk to each other; every byte of tenant state moves through
the router, so the fleet-wide query-accounting identity
(``queries_issued == labels_applied + dropped + lost (+ coalesced)``)
survives any sequence of migrations — ``reconcile`` checks it from the
collected stats.
"""

from __future__ import annotations

import contextlib
import os
import socket
import subprocess
import sys
import time
from typing import Optional

from repro.engine import rpc, snapshot
from repro.runtime import lockdebug
from repro.runtime import telemetry as _telemetry
from repro.runtime import worker as worker_mod


class WorkerError(RuntimeError):
    """A worker replied with an error frame (the worker itself is fine)."""


# ---------------------------------------------------------------------------
# WorkerClient: one control-socket connection to one worker
# ---------------------------------------------------------------------------


class WorkerClient:
    """Controller-side handle on a worker: a persistent control connection
    plus (optionally) the worker subprocess itself."""

    def __init__(
        self,
        host: str,
        port: int,
        name: Optional[str] = None,
        proc: Optional[subprocess.Popen] = None,
        connect_timeout_s: float = 10.0,
    ):
        self.host, self.port = host, port
        self.name = name or f"{host}:{port}"
        self.proc = proc
        self._sock = socket.create_connection(
            (host, port), timeout=connect_timeout_s
        )
        # Commands block for as long as the worker needs (extract quiesces
        # the tenant, which can take many scheduler rounds).
        self._sock.settimeout(None)
        self._file = self._sock.makefile("rb")
        self._frames = rpc._iter_wire(self._file)
        self._lock = lockdebug.make_lock("elastic.WorkerClient._lock")

    def _request(self, header: dict, payload: bytes = b"") -> tuple[dict, bytes]:
        header = dict(header)
        header["payload_len"] = len(payload)
        with self._lock:
            self._sock.sendall(rpc._encode_frame(header, payload))
            _, reply, reply_payload = next(self._frames)
        if reply.get("kind") == "error":
            raise WorkerError(f"{self.name}: {reply['error']}")
        return reply, reply_payload

    # -- commands ----------------------------------------------------------

    def status(self) -> dict:
        """Live-tenant load report + finished-tenant names."""
        return self._request({"kind": "status"})[0]

    def admit(self, spec: dict, snapshot_wire: bytes = b"") -> dict:
        """Start a tenant from its spec; with ``snapshot_wire``, restore it
        from a migrated snapshot instead of fresh state."""
        return self._request({"kind": "admit", "spec": spec}, snapshot_wire)[0]

    def extract(self, name: str) -> tuple[dict, bytes]:
        """Quiesce + snapshot + remove a live tenant; returns its spec and
        the snapshot wire bytes, ready to ``admit`` elsewhere."""
        header, wire = self._request({"kind": "extract", "name": name})
        return header["spec"], wire

    def result(self, name: str) -> tuple[dict, dict]:
        """A finished tenant's (stats dict, {"state": ..., "outputs"?} tree)."""
        header, wire = self._request({"kind": "result", "name": name})
        return header["stats"], snapshot.decode_snapshot(wire)

    def report(self) -> dict:
        """Stats dicts of every finished tenant, keyed by name."""
        return self._request({"kind": "report"})[0]["results"]

    def metrics(self, trace: bool = False) -> tuple[dict, bytes]:
        """Live telemetry scrape: the reply header carries the worker's
        Prometheus exposition text (``"prometheus"``) and registry JSON
        (``"metrics"``); with ``trace`` the payload is the worker's span
        ring as Chrome ``trace_event`` JSON bytes."""
        return self._request({"kind": "metrics", "trace": bool(trace)})

    def shutdown(self) -> None:
        with contextlib.suppress(WorkerError, OSError, EOFError, StopIteration):
            self._request({"kind": "shutdown"})

    def close(self, shutdown: bool = True, timeout_s: float = 10.0) -> None:
        if shutdown:
            self.shutdown()
        rpc._shutdown_socket(self._sock)
        if self.proc is not None:
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()


def spawn_worker(
    name: str = "w0",
    host: str = "127.0.0.1",
    quantum: Optional[int] = None,
    sched: str = "rr",
    fuse: bool = True,
    pending: str = "auto",
    snapshot_dir: Optional[str] = None,
    snapshot_every: int = 0,
    snapshot_full_every: int = 8,
    env: Optional[dict] = None,
) -> WorkerClient:
    """Launch ``python -m repro.runtime.worker`` as a subprocess and dial
    its control port (the worker prints ``PORT <p>`` once listening)."""
    src_root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    child_env = dict(env if env is not None else os.environ)
    child_env["PYTHONPATH"] = src_root + (
        os.pathsep + child_env["PYTHONPATH"] if child_env.get("PYTHONPATH") else ""
    )
    cmd = [
        sys.executable, "-m", "repro.runtime.worker",
        "--host", host, "--port", "0", "--name", name,
        "--sched", sched,
        "--fuse-cohorts", "on" if fuse else "off",
        "--pending", pending,
        "--snapshot-every", str(snapshot_every),
        "--snapshot-full-every", str(snapshot_full_every),
    ]
    if quantum is not None:
        cmd += ["--quantum", str(quantum)]
    if snapshot_dir is not None:
        cmd += ["--snapshot-dir", snapshot_dir]
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, text=True, env=child_env
    )
    line = proc.stdout.readline()
    if not line.startswith("PORT "):
        proc.kill()
        raise RuntimeError(f"worker {name!r} failed to start: {line!r}")
    return WorkerClient(host, int(line.split()[1]), name=name, proc=proc)


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def _tenant_load(row: dict) -> float:
    """One live tenant's throughput demand: streams × achieved tick rate."""
    return row["s"] * row["tick_rate_ema"]


class Router:
    """Places tenants on workers, migrates them, scales the fleet in.

    ``capacity`` bounds live tenants per worker (None: unbounded).  The
    router keeps no authoritative state — placement decisions re-read
    worker ``status`` every time, so it recovers its world view from the
    fleet itself (the ``_placement`` map is just a fast path)."""

    def __init__(self, workers, capacity: Optional[int] = None):
        self.workers: list[WorkerClient] = list(workers)
        self.capacity = capacity
        self._placement: dict[str, WorkerClient] = {}

    # -- placement ---------------------------------------------------------

    def place(self, spec: dict, exclude=()) -> WorkerClient:
        """Pick the worker for a spec: under capacity first, then
        shape-affinity (a same-key tenant already lives there, so the pair
        cohort-fuses), then fewest tenants, then lowest load."""
        exclude = set(id(w) for w in exclude)
        key = worker_mod.spec_shape_key(spec)
        best, best_rank = None, None
        for w in self.workers:
            if id(w) in exclude:
                continue
            live = w.status()["live"]
            n = len(live)
            affinity = any(
                t["shape_key"] == key and not t["draining"] for t in live
            )
            rank = (
                self.capacity is not None and n >= self.capacity,
                not affinity,
                n,
                sum(_tenant_load(t) for t in live),
            )
            if best_rank is None or rank < best_rank:
                best, best_rank = w, rank
        if best is None:
            raise WorkerError("no worker available for placement")
        return best

    def admit(self, spec: dict) -> WorkerClient:
        w = self.place(spec)
        w.admit(spec)
        self._placement[spec["name"]] = w
        return w

    def worker_of(self, name: str) -> WorkerClient:
        w = self._placement.get(name)
        if w is not None and w in self.workers:
            return w
        for w in self.workers:  # recover from a stale map
            st = w.status()
            if any(t["name"] == name for t in st["live"]) or name in st["finished"]:
                self._placement[name] = w
                return w
        raise WorkerError(f"tenant {name!r} not found on any worker")

    # -- migration ---------------------------------------------------------

    def migrate(self, name: str, dst: Optional[WorkerClient] = None) -> WorkerClient:
        """Move a live tenant to ``dst`` (default: best non-source worker).
        The tenant resumes bit-for-bit from its wire snapshot."""
        src = self.worker_of(name)
        # Router-side span covers the whole ship (extract + place + admit);
        # the workers' own traces carry the migrate.extract / migrate.admit
        # halves.  No-op unless telemetry is enabled in *this* process.
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("migrate.ship") if tel is not None else None
        spec, wire = src.extract(name)
        if dst is None:
            dst = self.place(spec, exclude=(src,))
        dst.admit(spec, wire)
        if tok is not None:
            tel.tracer.end(tok, tenant=name, src=src.name, dst=dst.name,
                           wire_bytes=len(wire))
            tel.registry.count("odl_router_migrations")
        self._placement[name] = dst
        return dst

    def rebalance(self, factor: float = 2.0, max_moves: int = 1) -> list[dict]:
        """Migrate tenants off overloaded workers.  A worker is overloaded
        when its summed tenant load exceeds the coldest worker's by
        ``factor``; the hottest tenant moves there.  Returns the moves made
        (``{"name", "src", "dst"}`` each)."""
        moves = []
        for _ in range(max_moves):
            loads = []
            for w in self.workers:
                live = [t for t in w.status()["live"] if not t["draining"]]
                loads.append((sum(_tenant_load(t) for t in live), live, w))
            if len(loads) < 2:
                break
            loads.sort(key=lambda x: x[0])
            cold_load, _, cold = loads[0]
            hot_load, hot_live, hot = loads[-1]
            if len(hot_live) < 2 or hot_load <= factor * max(cold_load, 1e-9):
                break
            victim = max(hot_live, key=_tenant_load)["name"]
            self.migrate(victim, dst=cold)
            moves.append({"name": victim, "src": hot.name, "dst": cold.name})
        return moves

    # -- scale-in ----------------------------------------------------------

    def drain(self, w: WorkerClient) -> tuple[list[str], dict]:
        """Migrate every live tenant off ``w``; returns the migrated names
        and the stats of tenants that finished on ``w`` (collect them — they
        leave the fleet when the worker shuts down)."""
        migrated = []
        for row in w.status()["live"]:
            name = row["name"]
            try:
                spec, wire = w.extract(name)
            except WorkerError:
                continue  # finished between status and extract: in report
            dst = self.place(spec, exclude=(w,))
            dst.admit(spec, wire)
            self._placement[name] = dst
            migrated.append(name)
        return migrated, w.report()

    def scale_in(self, w: WorkerClient) -> tuple[list[str], dict]:
        """Drain ``w``, then shut it down and drop it from the fleet."""
        migrated, finished = self.drain(w)
        self.workers.remove(w)
        self._placement = {
            name: wk for name, wk in self._placement.items() if wk is not w
        }
        w.close(shutdown=True)
        return migrated, finished

    # -- fleet-wide views --------------------------------------------------

    def wait_finished(
        self, names, timeout_s: float = 300.0, poll_s: float = 0.05
    ) -> None:
        """Block until every named tenant has finished, wherever it ran."""
        remaining = set(names)
        deadline = time.monotonic() + timeout_s
        while remaining:
            for w in self.workers:
                st = w.status()
                remaining -= set(st["finished"])
            if not remaining:
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"tenants never finished: {sorted(remaining)}"
                )
            time.sleep(poll_s)

    def fleet_metrics(self, trace: bool = False) -> dict:
        """One live scrape of the whole fleet.

        Returns ``{"workers": {worker_name: metrics_header}, "traces":
        {worker_name: chrome_trace_dict}}`` where each metrics header is
        the worker's ``metrics`` reply (``"prometheus"`` exposition text +
        ``"metrics"`` registry JSON).  Traces are only fetched (and only
        present) when ``trace=True``.  Scraping is read-only — it never
        perturbs tenant state, so it is safe mid-run at any cadence.
        """
        import json as _json

        out: dict = {"workers": {}, "traces": {}}
        for w in self.workers:
            header, payload = w.metrics(trace=trace)
            out["workers"][w.name] = header
            if trace and payload:
                out["traces"][w.name] = _json.loads(payload)
        return out

    def fleet_results(self) -> dict:
        """Finished-tenant stats from every live worker, name → stats dict.
        (Stats collected by ``scale_in`` before a worker left must be
        merged by the caller — that worker is gone.)"""
        out = {}
        for w in self.workers:
            for name, stats in w.report().items():
                out[name] = stats
        return out

    def close(self, shutdown: bool = True) -> None:
        for w in self.workers:
            w.close(shutdown=shutdown)
        self.workers = []
        self._placement = {}


def reconcile(results: dict) -> dict:
    """Fleet-wide query accounting from collected stats dicts: sums every
    counter and checks the conservation identity
    ``queries_issued == labels_applied + dropped + lost (+ coalesced)``
    per tenant and in aggregate.  Migrations must not leak tickets."""
    keys = (
        "ticks", "stream_steps", "tickets_issued", "queries_issued",
        "labels_applied", "queries_dropped", "queries_lost",
        "queries_coalesced", "tickets_dropped", "tickets_lost",
        "tickets_coalesced", "replies_orphaned", "asks_deferred",
        "tickets_reasked",
    )
    totals = {k: 0 for k in keys}
    per_tenant_ok = {}
    for name, stats in results.items():
        for k in keys:
            totals[k] += int(stats.get(k, 0))
        per_tenant_ok[name] = bool(stats.get("reconciled", False))
    totals["reconciled"] = totals["queries_issued"] == (
        totals["labels_applied"]
        + totals["queries_dropped"]
        + totals["queries_lost"]
        + totals["queries_coalesced"]
    )
    totals["per_tenant"] = per_tenant_ok
    return totals
