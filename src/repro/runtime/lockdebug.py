"""Lock-order cycle detection behind ``REPRO_LOCK_DEBUG=1``.

The static side (odlint ODL001) proves writes hold the *right* lock;
this is the dynamic side: prove the locks themselves are acquired in a
consistent *order*.  ``make_lock``/``make_rlock``/``make_condition``
return plain ``threading`` primitives unless ``REPRO_LOCK_DEBUG=1`` is
set at creation time — zero overhead in production, full tracking in
debug runs (CI runs the rpc + telemetry suites under it).

Tracking model: a per-thread stack of currently-held locks plus one
process-global acquisition graph.  Acquiring ``B`` while holding ``A``
adds the edge ``A → B``; an edge that closes a cycle (``B …→ A``
already reachable) raises ``LockOrderError`` *before* blocking — the
deadlock is reported at the first inconsistent acquisition, not when
two threads finally interleave into it.

Reentrant acquires of the same RLock add no edge.  Condition variables
wrap a tracked lock, so waiting/notifying inherit the same discipline.
"""

from __future__ import annotations

import os
import threading

_ENV = "REPRO_LOCK_DEBUG"


class LockOrderError(RuntimeError):
    """Two locks were acquired in both orders — a latent deadlock."""


class _Graph:
    """The process-global acquisition graph (edges lock-name → lock-name)."""

    def __init__(self):
        self._edges: dict[str, set] = {}
        self._mu = threading.Lock()
        self._held = threading.local()

    def held_stack(self) -> list:
        stack = getattr(self._held, "stack", None)
        if stack is None:
            stack = self._held.stack = []
        return stack

    def edges(self) -> dict:
        with self._mu:
            return {k: set(v) for k, v in self._edges.items()}

    def clear(self) -> None:
        with self._mu:
            self._edges.clear()

    def before_acquire(self, name: str) -> None:
        stack = self.held_stack()
        if not stack:
            return
        holder = stack[-1]
        if holder == name:  # reentrant RLock acquire
            return
        with self._mu:
            self._edges.setdefault(holder, set()).add(name)
            path = self._find_path(name, holder)
        if path is not None:
            raise LockOrderError(
                f"lock-order cycle: acquiring {name!r} while holding "
                f"{holder!r}, but {holder!r} is already acquired after "
                f"{name!r} elsewhere (path: {' -> '.join(path + [name])})"
            )

    def _find_path(self, src: str, dst: str):
        """DFS path src → dst in the edge graph (caller holds _mu)."""
        stack = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def did_acquire(self, name: str) -> None:
        self.held_stack().append(name)

    def did_release(self, name: str) -> None:
        stack = self.held_stack()
        # remove the most recent entry (locks are not always released
        # LIFO; with-blocks are, manual acquire/release may not be)
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return


GRAPH = _Graph()


class _TrackedLock:
    """Proxy over Lock/RLock feeding the acquisition graph.

    Duck-types the full lock protocol (``acquire``/``release``/context
    manager/``locked``) so it drops in anywhere a real lock is used —
    including as the underlying lock of ``threading.Condition``.
    """

    def __init__(self, inner, name: str):
        self._inner = inner
        self._name = name
        # RLock reentrancy: count our own nesting so release only pops
        # the held-stack when the outermost hold ends.
        self._local = threading.local()

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    def acquire(self, blocking: bool = True, timeout: float = -1):
        if self._depth() == 0:
            GRAPH.before_acquire(self._name)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            if self._depth() == 0:
                GRAPH.did_acquire(self._name)
            self._local.depth = self._depth() + 1
        return ok

    def release(self) -> None:
        self._inner.release()
        self._local.depth = self._depth() - 1
        if self._depth() == 0:
            GRAPH.did_release(self._name)

    # context manager + misc protocol bits Condition relies on
    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked() if hasattr(self._inner, "locked") else False

    # Condition uses these when present to save/restore recursion state
    # around wait(); delegate so RLock-backed conditions keep working.
    def _release_save(self):
        saved = (self._depth(), self._name)
        if hasattr(self._inner, "_release_save"):
            inner_state = self._inner._release_save()
        else:
            self._inner.release()
            inner_state = None
        self._local.depth = 0
        GRAPH.did_release(self._name)
        return (saved, inner_state)

    def _acquire_restore(self, state):
        (depth, _name), inner_state = state
        GRAPH.before_acquire(self._name)
        if inner_state is not None and hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(inner_state)
        else:
            self._inner.acquire()
        GRAPH.did_acquire(self._name)
        self._local.depth = depth

    def _is_owned(self):
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        return self._depth() > 0


def _enabled() -> bool:
    return os.environ.get(_ENV, "") == "1"


def make_lock(name: str):
    """A ``threading.Lock`` — tracked when REPRO_LOCK_DEBUG=1."""
    lock = threading.Lock()
    return _TrackedLock(lock, name) if _enabled() else lock


def make_rlock(name: str):
    """A ``threading.RLock`` — tracked when REPRO_LOCK_DEBUG=1."""
    lock = threading.RLock()
    return _TrackedLock(lock, name) if _enabled() else lock


def make_condition(name: str):
    """A ``threading.Condition`` over a (possibly tracked) fresh lock."""
    if not _enabled():
        return threading.Condition()
    return threading.Condition(_TrackedLock(threading.Lock(), name))
