"""One multiplexer worker of the elastic fleet: a ``Multiplexer`` behind a
control socket.

The elastic control plane (``runtime/elastic.py``) scales the multi-tenant
runtime *horizontally*: N worker processes, each driving its own
``engine.multiplex.Multiplexer``, with a router placing tenants by
compiled-shape affinity and migrating them worker-to-worker over the
snapshot wire codec (``engine.snapshot.encode_snapshot``).  This module is
the worker half: the scheduler loop runs in the main thread, and a small
control protocol — v2 binary frames, the same conventions as the RPC
teacher wire (``engine/rpc.py``) — runs on a loopback socket:

  * ``status``   — per-tenant load (tick-rate EMA, ring occupancy,
    compiled-shape key — ``Multiplexer.load_report``) + finished names;
  * ``admit``    — start a tenant from a JSON *spec* (below), optionally
    restoring it from snapshot-wire bytes in the frame payload (the
    receiving half of a live migration);
  * ``extract``  — snapshot → remove a tenant (``Multiplexer.extract``
    quiesces first only for teachers that can't snapshot); the reply payload
    is the encoded snapshot and the header returns the spec, so the caller
    can re-admit it anywhere (the sending half of a migration);
  * ``result`` / ``report`` — finished tenants' final state/outputs/stats;
  * ``shutdown`` — stop the scheduler loop (the router drains live tenants
    off a worker *before* shutting it down — scale-in).

Tenants cross the wire as **specs**, not objects: a JSON dict naming the
engine config (``snapshot.config_to_dict``), the tick source, and the
teacher, so both sides of a migration can rebuild identical Python objects.
Tick sources are always seekable (``snapshot.ResumableTicks``) — the
destination worker seeks to the snapshot's cursor, never replays ticks.
Teacher kinds:

  * ``latency`` — in-process ``stream.LatencyTeacher`` answering the same
    deterministic rule as the RPC label server (``rpc.expected_label``).
    Its internal state (RNG, inbox) travels inside the snapshot, so a
    migrated tenant continues **bit-for-bit** the run it would have had
    uninterrupted (the PR 4/6 lock, now across processes).
  * ``rpc`` — a real label server endpoint; the worker keeps one shared
    ``rpc.BatchedRpcClient`` per endpoint (as ``shared_rpc_teachers``
    does).  Sockets cannot migrate, so in-flight tickets are re-asked on
    the destination and metered as ``tickets_reasked``.

Run standalone (the router spawns these as subprocesses)::

    PYTHONPATH=src python -m repro.runtime.worker --port 0
    # prints "PORT <p>" once listening
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import itertools
import socket
import threading
import time
from typing import Optional

import numpy as np

from repro.engine import fleet as fleet_mod
from repro.engine import multiplex, snapshot, stream
from repro.runtime import lockdebug
from repro.runtime import telemetry as _telemetry

TICK_KINDS = ("synth", "decode")
TEACHER_KINDS = ("latency", "rpc")

# Scheduler idle poll while the worker has no live tenants (waiting for the
# router to admit some).
_IDLE_SLEEP_S = 2e-3


# ---------------------------------------------------------------------------
# Tenant specs: tenants as JSON, rebuildable on either side of the wire
# ---------------------------------------------------------------------------


def tenant_spec(
    name: str,
    cfg,
    s: int,
    ticks: dict,
    teacher: dict,
    mode: str = "algo1",
    capacity: int = 64,
    backpressure: str = "drop_oldest",
    collect: bool = False,
    donate: Optional[bool] = None,
) -> dict:
    """Build a tenant spec dict.  ``cfg`` may be an ``EngineConfig`` or an
    already-encoded ``config_to_dict`` dict."""
    if not isinstance(cfg, dict):
        cfg = snapshot.config_to_dict(cfg)
    if ticks.get("kind") not in TICK_KINDS:
        raise ValueError(f"unknown tick-source kind {ticks.get('kind')!r}; "
                         f"choose one of {TICK_KINDS}")
    if teacher.get("kind") not in TEACHER_KINDS:
        raise ValueError(f"unknown teacher kind {teacher.get('kind')!r}; "
                         f"choose one of {TEACHER_KINDS}")
    return {
        "name": name, "cfg": cfg, "s": int(s), "ticks": ticks,
        "teacher": teacher, "mode": mode, "capacity": int(capacity),
        "backpressure": backpressure, "collect": bool(collect),
        "donate": donate,
    }


def spec_shape_key(spec: dict) -> str:
    """The compiled-shape affinity key of a spec — computable router-side,
    without building any engine objects; equals ``multiplex.shape_key`` of
    the tenant the spec builds."""
    return multiplex.shape_key(
        snapshot.config_from_dict(spec["cfg"]),
        spec.get("mode", "algo1"),
        spec.get("donate"),
        spec["s"],
    )


def synth_ticks_spec(seed: int, t_total: int, tick_sleep_ms: float = 0.0) -> dict:
    return {"kind": "synth", "seed": int(seed), "t_total": int(t_total),
            "tick_sleep_ms": float(tick_sleep_ms)}


def latency_teacher_spec(n_out: int, latency: int = 1, jitter: int = 0,
                         loss: float = 0.0, partial: float = 0.0,
                         seed: int = 0) -> dict:
    return {"kind": "latency", "n_out": int(n_out), "latency": int(latency),
            "jitter": int(jitter), "loss": float(loss),
            "partial": float(partial), "seed": int(seed)}


def rpc_teacher_spec(host: str, port: int, timeout_s: float = 5.0,
                     secret: Optional[str] = None, compress: bool = False) -> dict:
    return {"kind": "rpc", "host": host, "port": int(port),
            "timeout_s": float(timeout_s), "secret": secret,
            "compress": bool(compress)}


def _build_ticks(spec: dict, decode_cache: dict) -> snapshot.ResumableTicks:
    t = spec["ticks"]
    sleep_s = float(t.get("tick_sleep_ms", 0.0)) / 1e3
    if t["kind"] == "synth":
        # Per-tick seeded features: O(1) seek (no replay), identical in any
        # process — the fleet tests' cross-process reference depends on it.
        s, n_in = spec["s"], int(spec["cfg"]["elm"]["n_in"])
        seed, t_total = int(t["seed"]), int(t["t_total"])

        def factory(start):
            for tick in range(start, t_total):
                if sleep_s > 0:
                    time.sleep(sleep_s)
                rng = np.random.default_rng((seed, tick))
                yield np.tanh(rng.normal(size=(s, n_in))).astype(np.float32)

        return snapshot.ResumableTicks(factory)

    # "decode": one backbone decode step per tick (the serve path's tick
    # source).  The backbone is deterministic, so seek(k) replays the decode
    # to tick k; params/prefill are built once per distinct backbone spec
    # and shared by every tenant (and every seek) on this worker.
    import jax

    from repro import configs
    from repro.launch import serve as serve_lib
    from repro.models import model as model_lib

    key_fields = ("arch", "variant", "batch", "prompt_len", "max_len", "seed")
    cache_key = tuple(t.get(k) for k in key_fields)
    entry = decode_cache.get(cache_key)
    if entry is None:
        cfg = configs.get_config(t["arch"], t.get("variant", "smoke"))
        key = jax.random.PRNGKey(int(t.get("seed", 0)))
        params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)
        prompts = jax.random.randint(
            key, (int(t["batch"]), int(t.get("prompt_len", 16))), 0,
            cfg.vocab_size,
        )
        _, state = jax.jit(
            lambda p, tok: model_lib.prefill(p, tok, cfg, max_len=int(t.get("max_len", 128)))
        )(params, prompts)
        entry = decode_cache[cache_key] = (cfg, params, state, prompts)
    cfg, params, state, prompts = entry
    t_total = int(t["t_total"])

    def factory(start):
        it = itertools.islice(
            serve_lib._decode_feats(params, state, prompts, cfg, t_total),
            start, None,
        )
        for x in it:
            if sleep_s > 0:
                time.sleep(sleep_s)
            yield x

    return snapshot.ResumableTicks(factory)


def _build_teacher(spec: dict, rpc_clients: dict):
    t = spec["teacher"]
    if t["kind"] == "latency":
        from repro.engine.rpc import expected_label  # the deterministic rule

        n_out = int(t["n_out"])

        def label_fn(tick, feats, n_out=n_out):
            s = int(np.asarray(feats).shape[0])
            return np.asarray(
                [expected_label(tick, i, n_out) for i in range(s)], np.int32
            )

        return stream.LatencyTeacher(
            label_fn=label_fn, latency=int(t.get("latency", 1)),
            jitter=int(t.get("jitter", 0)), loss_prob=float(t.get("loss", 0.0)),
            partial_prob=float(t.get("partial", 0.0)), seed=int(t.get("seed", 0)),
        )
    # "rpc": one shared batched connection per endpoint for the whole
    # worker; per-tenant handles demux replies (multiplex.shared_rpc_teachers
    # semantics, cached worker-side so migrations reuse the socket).
    from repro.engine import rpc

    key = (t["host"], int(t["port"]))
    client = rpc_clients.get(key)
    if client is None:
        client = rpc_clients[key] = rpc.BatchedRpcClient(
            t["host"], int(t["port"]), timeout_s=float(t.get("timeout_s", 5.0)),
            secret=t.get("secret"), compress=bool(t.get("compress", False)),
        )
    return client.tenant(name=spec["name"])


def _stats_to_wire(stats: stream.StreamStats) -> dict:
    """Every StreamStats field as JSON-able values (deques become lists)."""
    out = {}
    for f in dataclasses.fields(stream.StreamStats):
        v = getattr(stats, f.name)
        if f.name in ("tick_ms", "label_latency_ticks"):
            out[f.name] = [float(x) for x in v]
        else:
            out[f.name] = v
    out["reconciled"] = stats.reconciled
    return out


def stats_from_wire(d: dict) -> stream.StreamStats:
    stats = stream.StreamStats()
    for k, v in d.items():
        if k == "reconciled":
            continue
        if k in ("tick_ms", "label_latency_ticks"):
            getattr(stats, k).extend(v)
        else:
            setattr(stats, k, type(getattr(stats, k))(v))
    return stats


# ---------------------------------------------------------------------------
# The worker
# ---------------------------------------------------------------------------


class Worker:
    """A ``Multiplexer`` wrapped in a control-socket server.

    The scheduler runs in whatever thread calls :meth:`serve_forever`;
    control connections are handled one thread each, and every command
    takes the scheduler lock, so admits/extracts land exactly between
    scheduler rounds — the same boundary in-process migration uses.
    """

    def __init__(
        self,
        name: str = "worker",
        host: str = "127.0.0.1",
        port: int = 0,
        quantum: int = multiplex.DEFAULT_QUANTUM,
        sched: str = "rr",
        fuse: bool = True,
        pending: str = "auto",
        snapshot_dir: Optional[str] = None,
        snapshot_every: int = 0,
        snapshot_full_every: int = 8,
        telemetry: bool = True,
    ):
        self.name = name
        if telemetry:
            # Process-wide: every session/client in this worker records into
            # the same registry; the ``metrics`` command scrapes it live.
            _telemetry.enable()
        self.mux = multiplex.Multiplexer(
            [], quantum=quantum, sched=sched, fuse=fuse, pending=pending,
            snapshot_dir=snapshot_dir, snapshot_every=snapshot_every,
            snapshot_full_every=snapshot_full_every,
        )
        self._specs: dict[str, dict] = {}
        self._decode_cache: dict = {}
        self._rpc_clients: dict = {}
        self._lock = lockdebug.make_rlock("worker.Worker._lock")
        self._stop = threading.Event()
        self._sock = socket.create_server((host, port))
        self.host, self.port = self._sock.getsockname()[:2]
        self._conns: list[socket.socket] = []
        self._threads: list[threading.Thread] = []
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True
        )
        self._accept_thread.start()

    # -- scheduler loop ----------------------------------------------------

    def serve_forever(self) -> None:
        """Drive the multiplexer until ``shutdown`` arrives.  An idle worker
        (no live tenants) keeps polling — the router admits tenants at any
        time."""
        while not self._stop.is_set():
            with self._lock:
                live = self.mux.round()
            if not live:
                time.sleep(_IDLE_SLEEP_S)

    def close(self) -> None:
        self._stop.set()
        from repro.engine.rpc import _shutdown_socket

        _shutdown_socket(self._sock)
        for conn in list(self._conns):
            _shutdown_socket(conn)
        for t in self._threads:
            t.join(timeout=5)
        for client in self._rpc_clients.values():
            with contextlib.suppress(Exception):
                client.close()

    # -- control protocol --------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,), daemon=True)
            self._threads.append(t)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        from repro.engine import rpc

        f = conn.makefile("rb")
        try:
            for kind, header, payload in rpc._iter_wire(f):
                if kind != "v2":
                    continue  # this port speaks only the control protocol
                reply, reply_payload = self._handle(header, payload)
                reply["payload_len"] = len(reply_payload)
                conn.sendall(rpc._encode_frame(reply, reply_payload))
                if header.get("kind") == "shutdown":
                    break
        except (EOFError, OSError, ValueError):
            pass  # dropped controller connection; worker keeps serving
        finally:
            rpc._shutdown_socket(conn)
            with contextlib.suppress(ValueError):
                self._conns.remove(conn)

    def _handle(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        cmd = header.get("kind")
        try:
            with self._lock:
                if cmd == "status":
                    return self._status(), b""
                if cmd == "metrics":
                    return self._metrics(bool(header.get("trace", False)))
                if cmd == "admit":
                    return self._admit(header["spec"], payload), b""
                if cmd == "extract":
                    return self._extract(header["name"])
                if cmd == "result":
                    return self._result(header["name"])
                if cmd == "report":
                    return {
                        "kind": "report_ok",
                        "results": {
                            name: _stats_to_wire(r.stats)
                            for name, r in self.mux.finished_results().items()
                        },
                    }, b""
                if cmd == "shutdown":
                    self._stop.set()
                    return {"kind": "ok"}, b""
            return {"kind": "error",
                    "error": f"unknown control command {cmd!r}"}, b""
        except Exception as e:  # command errors must not kill the worker
            return {"kind": "error", "error": f"{type(e).__name__}: {e}"}, b""

    def _status(self) -> dict:
        return {
            "kind": "status_ok",
            "worker": self.name,
            "live": self.mux.load_report(),
            "finished": sorted(self.mux.finished_results()),
        }

    def _metrics(self, trace: bool) -> tuple[dict, bytes]:
        """Live scrape: sync every meter into the registry, then export.

        Returns both renderings in the header (Prometheus exposition text
        + the registry's JSON snapshot); when ``trace`` is requested the
        reply payload carries the span ring as Chrome ``trace_event`` JSON
        (``chrome://tracing`` / Perfetto loads it directly).
        """
        tel = _telemetry.TELEMETRY
        if tel is None:
            return {"kind": "metrics_ok", "worker": self.name,
                    "enabled": False, "prometheus": "", "metrics": {}}, b""
        self.mux.sync_telemetry()
        for (host, port), client in self._rpc_clients.items():
            client.sync_telemetry(endpoint=f"{host}:{port}")
        header = {
            "kind": "metrics_ok",
            "worker": self.name,
            "enabled": True,
            "prometheus": tel.registry.prometheus_text(),
            "metrics": tel.registry.snapshot(),
        }
        payload = b""
        if trace:
            import json as _json
            payload = _json.dumps(tel.tracer.chrome_trace()).encode()
        return header, payload

    def _admit(self, spec: dict, payload: bytes) -> dict:  # odlint: holds-lock(_lock)
        tree = snapshot.decode_snapshot(payload) if payload else None
        cfg = snapshot.config_from_dict(spec["cfg"])
        tenant = multiplex.Tenant(
            name=spec["name"],
            # A migrated-in tenant's state rides the snapshot.
            state=None if tree is not None else fleet_mod.init_fleet(cfg, spec["s"]),
            ticks=_build_ticks(spec, self._decode_cache),
            cfg=cfg,
            teacher=_build_teacher(spec, self._rpc_clients),
            mode=spec.get("mode", "algo1"),
            capacity=spec.get("capacity", 64),
            backpressure=spec.get("backpressure", "drop_oldest"),
            collect=spec.get("collect", False),
            donate=spec.get("donate"),
        )
        self.mux.admit(tenant, snapshot=tree)
        self._specs[spec["name"]] = spec  # odlint: guarded-by(_lock)
        return {"kind": "ok", "name": spec["name"],
                "migrated": tree is not None}

    def _extract(self, name: str) -> tuple[dict, bytes]:  # odlint: holds-lock(_lock)
        tree, _it = self.mux.extract(name)
        # The partially-consumed iterator stays behind: specs only build
        # seekable sources, so the destination seeks to the snapshot cursor.
        spec = self._specs.pop(name)
        wire = snapshot.encode_snapshot(tree)
        return {"kind": "snapshot_ok", "spec": spec,
                "t": snapshot.ticks_consumed(tree)}, wire

    def _result(self, name: str) -> tuple[dict, bytes]:  # odlint: holds-lock(_lock)
        results = self.mux.finished_results()
        if name not in results:
            raise KeyError(f"tenant {name!r} has no finished result here")
        r = results[name]
        tree: dict = {"state": snapshot.state_to_tree(r.state)}
        if r.outputs is not None:
            tree["outputs"] = {
                k: np.asarray(v) for k, v in r.outputs._asdict().items()
            }
        wire = snapshot.encode_snapshot(tree)
        return {"kind": "result_ok", "stats": _stats_to_wire(r.stats)}, wire


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="control port (0: ephemeral, printed as 'PORT <p>')")
    ap.add_argument("--name", default="worker")
    ap.add_argument("--quantum", type=int, default=multiplex.DEFAULT_QUANTUM)
    ap.add_argument("--sched", default="rr", choices=multiplex.SCHEDULERS)
    ap.add_argument("--fuse-cohorts", default="on", choices=("on", "off"))
    ap.add_argument("--pending", default="auto", choices=snapshot.PENDING_POLICIES,
                    help="how admits-from-wire handle in-flight tickets")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=0)
    ap.add_argument("--snapshot-full-every", type=int, default=8,
                    help="cadence saves ship only changed leaves; every k-th "
                    "save is full (1: all saves full)")
    ap.add_argument("--telemetry", default="on", choices=("on", "off"),
                    help="process-local metrics registry + span tracer "
                    "(scraped via the 'metrics' control command)")
    args = ap.parse_args(argv)
    worker = Worker(
        name=args.name, host=args.host, port=args.port, quantum=args.quantum,
        sched=args.sched, fuse=args.fuse_cohorts == "on", pending=args.pending,
        snapshot_dir=args.snapshot_dir, snapshot_every=args.snapshot_every,
        snapshot_full_every=args.snapshot_full_every,
        telemetry=args.telemetry == "on",
    )
    print(f"PORT {worker.port}", flush=True)
    try:
        worker.serve_forever()
    finally:
        worker.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
