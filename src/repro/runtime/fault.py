"""Fault tolerance: NaN guard, teacher deadlines, retry/skip, restart loop.

The paper's own fault policy — "if such a nearby teacher is not available,
the queries to the teacher will be retried later or skipped" — generalizes
to the pod-scale straggler policy implemented here:

  * ``DeadlineTeacher`` wraps any teacher callable with a deadline; a miss
    returns availability=False and the ODL step trains on nothing (exact
    identity, see oselm mask semantics) instead of stalling the fleet.
  * ``NaNGuard`` watches train metrics; on non-finite loss it rolls back to
    the last good checkpoint and skips the offending data shard (the
    standard large-run recipe for data-poisoned steps).
  * ``run_with_restarts`` is the supervisor loop: run -> crash -> restore ->
    continue, bounded restarts (checkpoint/restart requirement).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import numpy as np

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class DeadlineTeacher:
    """Teacher with a response deadline + bounded retries (paper §2.2)."""

    teacher: Callable
    deadline_s: float = 0.05
    max_retries: int = 1
    # test hook: callable returning simulated latency per call
    latency_fn: Optional[Callable[[], float]] = None
    outages: int = 0

    def __call__(self, idx, x):
        for _ in range(self.max_retries + 1):
            t0 = time.monotonic()
            lat = self.latency_fn() if self.latency_fn else 0.0
            if lat <= self.deadline_s:
                return self.teacher(idx, x), True
            # missed deadline -> retry
            del t0
        self.outages += 1
        return None, False


class NaNGuard:
    """Detects non-finite metrics and triggers rollback."""

    def __init__(self, manager, tolerate: int = 0):
        self.manager = manager
        self.tolerate = tolerate
        self.bad_steps = 0
        self.rollbacks = 0

    def check(self, step: int, metrics: dict, state):
        loss = float(np.asarray(metrics.get("loss", 0.0)))
        if np.isfinite(loss):
            self.bad_steps = 0
            return state, step, False
        self.bad_steps += 1
        if self.bad_steps <= self.tolerate:
            return state, step, False
        log.warning("non-finite loss at step %d; rolling back", step)
        self.rollbacks += 1
        self.bad_steps = 0
        restored_step, tree = self.manager.restore()
        return tree, restored_step, True


def run_with_restarts(
    make_state: Callable[[], object],
    run: Callable[[object, int], tuple],
    manager,
    max_restarts: int = 3,
):
    """Supervisor: (re)start `run(state, start_step)` after failures,
    restoring from the latest published checkpoint each time."""
    restarts = 0
    while True:
        try:
            if manager.latest_step() is not None:
                start, state = manager.restore()
            else:
                start, state = 0, make_state()
            return run(state, start)
        except Exception as e:  # noqa: BLE001 — supervisor must catch all
            restarts += 1
            log.warning("run failed (%s); restart %d/%d", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
