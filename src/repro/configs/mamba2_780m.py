"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 ssm_state=128 vocab=50280 [arXiv:2405.21060; unverified]
Attention-free => long_500k runs; decode cache is the (H, P, N) SSM state.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=1,  # unused (attn-free)
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=128,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, vocab_size=512, ssm_state=16, ssm_headdim=16,
        ssm_chunk=16,
    )
