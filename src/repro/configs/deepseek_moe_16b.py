"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16 => MHA) d_ff_expert=1408 vocab=102400
[arXiv:2401.06066; hf]
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102_400,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        d_ff_expert=48,
    )
