"""deepseek-coder-33b [dense] — llama-arch code model.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 [arXiv:2401.14196; hf]
Full attention => long_500k skipped (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19_200,
        vocab_size=32_256,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512
    )
