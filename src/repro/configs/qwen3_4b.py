"""qwen3-4b [dense] — GQA with per-head QK-RMSNorm.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936 [hf:Qwen/Qwen3-8B; hf]
Full attention => long_500k skipped.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512,
    )
