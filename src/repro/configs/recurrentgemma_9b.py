"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention, 1:2.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified].  Pattern (rec, rec, attn) x12 + 2-rec tail;
sub-quadratic => long_500k runs.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12_288,
        vocab_size=256_000,
        sliding_window=2048,
        hybrid_pattern=("rec", "rec", "attn"),
        lru_width=4096,
        attention_kind="swa",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4,  # one (rec, rec, attn) group + 1-layer tail
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sliding_window=16,
        lru_width=64,
    )
