"""whisper-small [audio] — encoder-decoder, conv frontend stubbed.

12L d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356;
unverified].  ``input_specs`` provides precomputed frame embeddings
(B, T, d_model) in place of the log-mel conv stem (assignment: frontend is
a STUB).  Decode = decoder self-attn cache + precomputed cross K/V.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-small",
        family="audio",
        n_layers=12,  # decoder layers
        n_enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51_865,
        enc_dec=True,
        frontend_stub=True,
        tie_embeddings=True,
        max_source_len=32_768,  # covers decode_32k's decoder positions
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, max_source_len=64,
    )
