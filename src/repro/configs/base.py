"""Config dataclasses: model architecture, input shapes, ODL head, mesh."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ODLHeadConfig:
    """The paper's technique attached to a backbone (DESIGN.md §3)."""

    n_hidden: int = 128
    n_out: int = 6
    variant: str = "hash"  # 'hash' (ODLHash) | 'base' (ODLBase)
    seed: int = 0x2D2A
    ridge: float = 1e-2
    enabled: bool = True
    use_kernel: bool = False  # route head training through the Pallas kernels


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA width (h2o-danube, local attn)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # --- MoE (deepseek fine-grained) ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # 'dense': pjit scatter-dispatch (XLA SPMD replicates it — the measured
    # baseline); 'ep': explicit shard_map expert parallelism with
    # all-to-all dispatch (hillclimb variant, EXPERIMENTS.md §Perf H1).
    moe_impl: str = "dense"

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma: RG-LRU + local attn, pattern 1 attn : 2 rec)
    hybrid_pattern: Tuple[str, ...] = ()  # e.g. ('rec', 'rec', 'attn')
    lru_width: Optional[int] = None

    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    max_source_len: int = 4096  # stubbed frame embeddings length

    # --- modality stub ---
    frontend_stub: bool = False  # vlm/audio: input_specs yields embeddings/tokens

    # --- the paper's ODL head ---
    odl: ODLHeadConfig = ODLHeadConfig()

    # --- attention policy ---
    attention_kind: str = "full"  # 'full' | 'swa' — long_500k requires != full
    # 'naive' materializes (Sq, Sk) scores; 'chunked' = flash-style online
    # softmax over KV chunks, O(S * chunk) memory (hillclimb variant).
    attention_impl: str = "naive"
    attention_chunk: int = 1024
    # Decode cache write: 'onehot' (per-stream positions, but rewrites the
    # whole cache: O(S) HBM traffic per token) or 'dus' (dynamic-update-
    # slice at pos[0]: O(1) traffic; requires synchronized stream positions
    # — the common serving case).  §Perf H3.
    cache_update: str = "onehot"

    # Dry-run cost extrapolation: execute layer stacks as a Python loop
    # instead of lax.scan (XLA cost_analysis counts a loop body ONCE, so the
    # roofline compiles unrolled 1- and 2-layer variants and extrapolates).
    unroll_layers: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch lower long_500k (DESIGN.md §4)?"""
        if self.family == "ssm":
            return True
        if self.hybrid_pattern:
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient accumulation inside train_step
    remat: bool = True
    zero1: bool = True  # shard optimizer state over the data axis
    # 'float32' master params, or 'bfloat16' for models whose f32 state
    # exceeds pod HBM (deepseek-v2-236b: 12 B/param x 236e9 = 2.83 TB > the
    # 4 TB 256-chip pod; bf16 params + f32 moments = 2.36 TB fits).
    param_dtype: str = "float32"
