"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff_expert=1536 vocab=102400 [arXiv:2405.04434; hf]
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,  # qk_nope(128) + qk_rope(64)
        d_ff=1536,
        vocab_size=102_400,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1536,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=24,
        d_ff=64,
        vocab_size=512,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        d_ff_expert=48,
        q_lora_rank=32,
        kv_lora_rank=24,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
    )
