"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]
SWA => sub-quadratic => long_500k runs for this arch (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32_000,
        sliding_window=4096,
        attention_kind="swa",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, sliding_window=16,
    )
