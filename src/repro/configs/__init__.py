"""Config registry: ``get_config(arch_id, variant)`` for all assigned archs."""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    ModelConfig,
    ODLHeadConfig,
    ShapeConfig,
    TrainConfig,
    shape_by_name,
)

ARCH_IDS = (
    "deepseek-moe-16b",
    "deepseek-v2-236b",
    "h2o-danube-1.8b",
    "deepseek-coder-33b",
    "mistral-nemo-12b",
    "qwen3-4b",
    "mamba2-780m",
    "recurrentgemma-9b",
    "chameleon-34b",
    "whisper-small",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    """Load an assigned architecture config ('full' or 'smoke')."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return getattr(mod, variant)()


def cells(arch_id: str):
    """The (shape, runnable, reason) dry-run cells for an arch (DESIGN.md §4)."""
    cfg = get_config(arch_id)
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            out.append((s, False, "full attention is quadratic at 524k"))
        else:
            out.append((s, True, ""))
    return out
