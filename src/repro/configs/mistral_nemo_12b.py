"""mistral-nemo-12b [dense] — 128k-context dense model (head_dim 128).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407; hf].  Full attention => long_500k skip.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,  # nemo decouples head_dim from d_model/n_heads
        d_ff=14_336,
        vocab_size=131_072,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512,
    )
