"""chameleon-34b [vlm] — early-fusion mixed-modal; VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified].

Early fusion means image patches are VQ-quantized into ordinary vocabulary
ids by a frozen tokenizer — the modality frontend is a STUB per the
assignment: ``input_specs`` supplies precomputed (text + image) token ids,
so the backbone is a plain dense transformer with qk-norm (the chameleon
training-stability trick).  Full attention => long_500k skipped.
"""

from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22_016,
        vocab_size=65_536,
        qk_norm=True,
        frontend_stub=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512
    )
