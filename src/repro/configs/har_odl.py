"""har-odl — the paper's own configuration (no backbone).

OS-ELM core with n=561, N=128, m=6 (paper §2.3 prototype), ODLHash variant,
auto data pruning with the {1, .64, .32, .16, .08} ladder and X=10.
"""

from repro import engine
from repro.core import drift, oselm, pruning


def full(n_hidden: int = 128, variant: str = "hash") -> engine.EngineConfig:
    elm = oselm.OSELMConfig(
        n_in=561, n_hidden=n_hidden, n_out=6, variant=variant, ridge=1e-2
    )
    return engine.EngineConfig(
        elm=elm,
        prune=pruning.PruneConfig.for_hidden(n_hidden),
        drift=drift.DriftConfig(),
    )


def smoke() -> engine.EngineConfig:
    return full(n_hidden=16)
