"""Whisper-style encoder-decoder backbone (audio family, frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings (B, T_frames, d_model).  A learned adapter
linear stands in for the conv stack's output projection; sinusoidal
positions on the encoder, learned positions on the decoder (as in Whisper).

Decode caches: per-decoder-layer self-attention K/V ring + cross-attention
K/V computed ONCE at prefill from the encoder output (cross K/V are
position-independent, Whisper's serving trick).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import attention
from repro.models.layers import (
    Leaf,
    cast,
    gelu_mlp,
    layernorm,
    sinusoidal_embedding,
    stack_schema,
)
from repro.models.transformer import scan_or_loop


def _ln(d):
    return {"w": Leaf((d,), ("embed",), init="ones"), "b": Leaf((d,), ("embed",), init="zeros")}


def _enc_layer_schema(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln1": _ln(d),
        "attn": attention.gqa_schema(cfg),
        "ln2": _ln(d),
        "mlp": {"wi": Leaf((d, ff), ("embed", "mlp")), "wo": Leaf((ff, d), ("mlp", "embed"))},
    }


def _dec_layer_schema(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln1": _ln(d),
        "self": attention.gqa_schema(cfg),
        "ln2": _ln(d),
        "cross": attention.cross_schema(cfg),
        "ln3": _ln(d),
        "mlp": {"wi": Leaf((d, ff), ("embed", "mlp")), "wo": Leaf((ff, d), ("mlp", "embed"))},
    }


def encdec_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "adapter": Leaf((d, d), ("embed", None)),  # stands for the conv stem out-proj
        "enc_layers": stack_schema(_enc_layer_schema(cfg), cfg.n_enc_layers),
        "enc_norm": _ln(d),
        "embed": Leaf((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "dec_pos": Leaf((cfg.max_source_len, d), (None, "embed"), init="embed", scale=0.02),
        "dec_layers": stack_schema(_dec_layer_schema(cfg), cfg.n_layers),
        "dec_norm": _ln(d),
    }


def encode(params: dict, frames: jnp.ndarray, cfg: ModelConfig, remat: bool = True):
    """frames: (B, T, d) stubbed frame embeddings -> encoder states."""
    t = frames.shape[1]
    h = frames.astype(jnp.bfloat16) @ cast(params["adapter"])
    h = h + sinusoidal_embedding(t, cfg.d_model)[None].astype(h.dtype)
    h = sharding.constrain(h, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], frames.shape[:2])

    def layer(hh, lp):
        hn = layernorm(hh, lp["ln1"]["w"], lp["ln1"]["b"])
        hh = hh + attention.gqa_attention(hn, lp["attn"], cfg, positions, causal=False)
        hn = layernorm(hh, lp["ln2"]["w"], lp["ln2"]["b"])
        return hh + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["wo"]), 0.0

    fn = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable) if remat else layer

    def body(carry, lp):
        hh, _ = fn(carry, lp)
        return hh, None

    h, _ = scan_or_loop(body, h, params["enc_layers"], cfg.unroll_layers)
    return layernorm(h, params["enc_norm"]["w"], params["enc_norm"]["b"])


def decode_train(params: dict, tokens: jnp.ndarray, enc: jnp.ndarray, cfg: ModelConfig,
                 remat: bool = True):
    """Teacher-forced decoder -> final hidden (B, S, d)."""
    s = tokens.shape[1]
    # Pin the table replicated: sharding propagation otherwise re-shards the
    # gather operand's feature dim, which XLA's gather partitioner rejects
    # for non-mesh-divisible vocabs (51865).
    emb = sharding.constrain(cast(params["embed"]), None, None)
    h = emb[tokens] + cast(params["dec_pos"])[None, :s]
    h = sharding.constrain(h, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], tokens.shape)

    def layer(hh, lp):
        hn = layernorm(hh, lp["ln1"]["w"], lp["ln1"]["b"])
        hh = hh + attention.gqa_attention(hn, lp["self"], cfg, positions, causal=True)
        hn = layernorm(hh, lp["ln2"]["w"], lp["ln2"]["b"])
        hh = hh + attention.cross_attention(hn, lp["cross"], enc)
        hn = layernorm(hh, lp["ln3"]["w"], lp["ln3"]["b"])
        return hh + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["wo"]), 0.0

    fn = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable) if remat else layer

    def body(carry, lp):
        hh, _ = fn(carry, lp)
        return hh, None

    h, _ = scan_or_loop(body, h, params["dec_layers"], cfg.unroll_layers)
    h = layernorm(h, params["dec_norm"]["w"], params["dec_norm"]["b"])
    return h


def logits(params: dict, hidden: jnp.ndarray) -> jnp.ndarray:
    out = jnp.einsum("bsd,vd->bsv", hidden, cast(params["embed"]))  # tied head
    return sharding.constrain(out, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_caches(params: dict, enc: jnp.ndarray, cfg: ModelConfig, max_len: int):
    """Self-attn ring caches + cross K/V precomputed from encoder states."""
    b = enc.shape[0]

    def one_layer(lp):
        ck = jnp.einsum("bsd,dhe->bshe", enc, cast(lp["cross"]["wk"]))
        cv = jnp.einsum("bsd,dhe->bshe", enc, cast(lp["cross"]["wv"]))
        return ck, cv

    ck, cv = jax.vmap(one_layer)(params["dec_layers"])  # (L, B, T, H, hd)
    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
        attention.gqa_init_cache(cfg, b, max_len),
    )
    return {"self": self_cache, "cross_k": ck, "cross_v": cv}


def decode_step(params: dict, token: jnp.ndarray, caches: dict, pos: jnp.ndarray,
                cfg: ModelConfig):
    """One decoder token; cross-attn reads precomputed K/V."""
    h = cast(params["embed"])[token] + cast(params["dec_pos"])[pos][:, None]

    def layer(hh, xs):
        lp, sc, ck, cv = xs
        hn = layernorm(hh, lp["ln1"]["w"], lp["ln1"]["b"])
        a, new_sc = attention.gqa_decode(hn, lp["self"], cfg, sc, pos)
        hh = hh + a
        hn = layernorm(hh, lp["ln2"]["w"], lp["ln2"]["b"])
        hh = hh + _cross_from_cache(hn, lp["cross"], ck, cv)
        hn = layernorm(hh, lp["ln3"]["w"], lp["ln3"]["b"])
        return hh + gelu_mlp(hn, lp["mlp"]["wi"], lp["mlp"]["wo"]), new_sc

    h, new_self = scan_or_loop(
        layer, h,
        (params["dec_layers"], caches["self"], caches["cross_k"], caches["cross_v"]),
        cfg.unroll_layers,
    )
    h = layernorm(h, params["dec_norm"]["w"], params["dec_norm"]["b"])
    return h, {"self": new_self, "cross_k": caches["cross_k"], "cross_v": caches["cross_v"]}


def _cross_from_cache(x, p, ck, cv):
    import numpy as np

    q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"]))
    scores = jnp.einsum("bqhe,bkhe->bhqk", q, ck.astype(q.dtype), preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(scores / np.sqrt(q.shape[-1]), -1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhe->bqhe", probs, cv.astype(x.dtype))
    return jnp.einsum("bshe,hed->bsd", o, cast(p["wo"]))
