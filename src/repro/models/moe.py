"""Fine-grained MoE (DeepSeekMoE): shared experts + routed top-k experts.

Dispatch is scatter-based (capacity-bounded buffers), the standard pure-JAX
formulation whose FLOPs match the *active* parameter count (capacity slots =
top_k * tokens * capacity_factor), so roofline numbers reflect real MoE
compute rather than a dense-all-experts surrogate.

Sharding: expert weight tensors and the (E, C, d) dispatch buffers carry the
"experts" logical axis -> EP over the "model" mesh axis.  Token buffers stay
batch-sharded; XLA inserts the dispatch all-to-alls at the EP boundary.

Router: softmax over all experts, top-k selection, renormalize among the
selected (DeepSeek's gating), plus the standard load-balancing auxiliary
loss (Switch/GShard form).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models.layers import Leaf, cast


def moe_schema(cfg: ModelConfig) -> dict:
    d, fe = cfg.d_model, cfg.d_ff_expert
    e = cfg.n_experts
    s = {
        "router": Leaf((d, e), ("embed", "experts"), scale=0.02),
        "wg": Leaf((e, d, fe), ("experts", "embed", "mlp")),
        "wu": Leaf((e, d, fe), ("experts", "embed", "mlp")),
        "wd": Leaf((e, fe, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        s["shared"] = {
            "wg": Leaf((d, fs), ("embed", "mlp")),
            "wu": Leaf((d, fs), ("embed", "mlp")),
            "wd": Leaf((fs, d), ("mlp", "embed")),
        }
    return s


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_block(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss).  Dispatches on cfg.moe_impl."""
    if cfg.moe_impl == "ep":
        mesh = sharding.mesh_or_none()
        if mesh is not None and "model" in mesh.axis_names:
            return moe_block_ep(x, p, cfg, mesh)
    return _moe_block_dense(x, p, cfg)


def _moe_block_dense(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """Scatter-dispatch top-k MoE under plain pjit (the baseline)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)

    # --- router ---
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gate_k, idx_k = jax.lax.top_k(probs, k)  # (T, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (mean prob * mean assignment per expert).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx_k, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)

    # --- dispatch: position of each (token, choice) within its expert ---
    flat_e = idx_k.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < cap

    buf = jnp.zeros((e, cap, d), x.dtype)
    tok_rep = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[flat_e, jnp.minimum(pos, cap - 1)].add(
        jnp.where(keep[:, None], xt[tok_rep], 0).astype(x.dtype)
    )
    buf = sharding.constrain(buf, "experts", "expert_cap", "embed")

    # --- expert computation: batched GEMMs over the expert axis ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, cast(p["wg"]))) * jnp.einsum(
        "ecd,edf->ecf", buf, cast(p["wu"])
    )
    h = sharding.constrain(h, "experts", "expert_cap", "mlp")
    out_e = jnp.einsum("ecf,efd->ecd", h, cast(p["wd"]))
    out_e = sharding.constrain(out_e, "experts", "expert_cap", "embed")

    # --- combine: gather each (token, choice) slot, weight by gate ---
    gathered = out_e[flat_e, jnp.minimum(pos, cap - 1)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_k.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_rep].add(gathered * w)

    # --- shared experts (always-on dense path) ---
    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(xt @ cast(sh["wg"])) * (xt @ cast(sh["wu"]))
        y = y + hs @ cast(sh["wd"])

    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Explicit expert parallelism (shard_map + all-to-all) — §Perf H1
# ---------------------------------------------------------------------------
#
# Why: under plain pjit, the scatter that builds the (E, C, d) dispatch
# buffer has data-dependent indices, so XLA's SPMD partitioner REPLICATES
# the buffer and with it the expert GEMMs — measured 177x useful-FLOP waste
# on deepseek-moe-16b train_4k (EXPERIMENTS.md §Perf).  The fix is the
# production formulation: explicit shard_map where
#   * tokens stay local to their data shard (dispatch scatter is LOCAL),
#   * one all-to-all over the model axis routes capacity buffers to the
#     expert's home shard: (E, C_loc, d) -> (E/m, C_loc * m, d),
#   * expert GEMMs run on local weights (E/m, d, f),
#   * a reverse all-to-all brings expert outputs back to the token shard.
# Collective cost per layer: 2 all-to-alls of E*C_loc*d bytes, the textbook
# EP exchange (GShard), instead of replicated compute.


def moe_block_ep(x: jnp.ndarray, p: dict, cfg: ModelConfig, mesh):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    m = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    # Tokens are sharded over EVERY mesh axis inside the MoE region (the
    # model axis included) — otherwise the m model-shards of a data shard
    # dispatch identical copies and the expert GEMMs run m-fold redundant.
    # Degrade gracefully for small token counts (decode: 128 streams) by
    # dropping axes from the right until the split divides.
    tok_axes = dp_axes + ("model",)
    while tok_axes:
        n_split = 1
        for a in tok_axes:
            n_split *= mesh.shape[a]
        if (b * s) % n_split == 0:
            break
        tok_axes = tok_axes[:-1]
    if not tok_axes:
        return _moe_block_dense(x, p, cfg)
    t_loc = (b * s) // n_split
    cap_loc = max(8, -(-int(k * t_loc * cfg.capacity_factor / e) // 8) * 8)
    e_loc = e // m

    from jax.sharding import PartitionSpec as P

    def local_moe(xt, router_w, wg, wu, wd):
        # xt: (T_loc, d) — this data shard's tokens (replicated over model).
        # wg/wu/wd: (E/m, d, f)-local expert weights.  All math below is
        # per-device; collectives are explicit.
        xt = xt.reshape(-1, d)
        logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gate_k, idx_k = jax.lax.top_k(probs, k)
        gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx_k, e, dtype=jnp.float32), 1), 0)
        aux = e * jnp.sum(me * ce)
        for a in tok_axes:
            aux = jax.lax.pmean(aux, a)

        flat_e = idx_k.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, 0) - onehot, flat_e[:, None], 1
        )[:, 0]
        keep = pos < cap_loc
        slot = jnp.minimum(pos, cap_loc - 1)
        tok_rep = jnp.repeat(jnp.arange(t_loc), k)

        buf = jnp.zeros((e, cap_loc, d), x.dtype)
        buf = buf.at[flat_e, slot].add(
            jnp.where(keep[:, None], xt[tok_rep], 0).astype(x.dtype)
        )

        # Dispatch a2a: (E, C_loc, d) -> (E/m, C_loc * m, d).
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, cast(wg))) * jnp.einsum(
            "ecd,edf->ecf", buf, cast(wu)
        )
        out = jnp.einsum("ecf,efd->ecd", h, cast(wd))

        # Return a2a: (E/m, C_loc * m, d) -> (E, C_loc, d).
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0, tiled=True)

        gathered = out[flat_e, slot]
        gathered = jnp.where(keep[:, None], gathered, 0)
        w = gate_k.reshape(-1)[:, None].astype(x.dtype)
        y = jnp.zeros((t_loc, d), x.dtype).at[tok_rep].add(gathered * w)
        return y, aux

    tok_spec = tok_axes
    xt_all = x.reshape(b * s, d)
    y, aux = sharding.shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            P(tok_spec, None),
            P(None, None),  # router replicated
            P("model", None, None),  # expert weights: E sharded locally
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(tok_spec, None), P()),
        check=False,
    )(xt_all, p["router"], p["wg"], p["wu"], p["wd"])
    y = y.reshape(b, s, d)

    if "shared" in p:
        sh = p["shared"]
        xt = x.reshape(b * s, d)
        hs = jax.nn.silu(xt @ cast(sh["wg"])) * (xt @ cast(sh["wu"]))
        hs = sharding.constrain(hs, "batch", "mlp")
        y = y + (hs @ cast(sh["wd"])).reshape(b, s, d)

    return y, aux
