"""Shared neural building blocks + a tiny param-schema system.

Params are plain nested dicts of jnp arrays.  Every leaf is declared once via
``Leaf(shape, axes, init)`` so the SAME declaration yields (a) materialized
arrays for real runs, (b) ShapeDtypeStructs for the dry-run, and (c)
PartitionSpecs (through ``distributed.sharding.resolve``) — no parallel
bookkeeping to drift out of sync.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding

# ---------------------------------------------------------------------------
# Param schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: Optional[float] = None  # override fan-in scaling

    def initializer(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        if self.init == "embed":
            s = 1.0
        else:
            s = self.scale if self.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * s).astype(dtype)


def _iter_leaves(schema, path=()):
    if isinstance(schema, Leaf):
        yield path, schema
        return
    for k, v in schema.items():
        yield from _iter_leaves(v, path + (k,))


def init_params(schema, key, dtype=jnp.float32):
    """Materialize a schema into arrays (per-leaf fold_in keys)."""
    out = {}
    for path, leaf in _iter_leaves(schema):
        sub = out
        for k in path[:-1]:
            sub = sub.setdefault(k, {})
        lk = jax.random.fold_in(key, abs(hash("/".join(map(str, path)))) % (2**31))
        sub[path[-1]] = leaf.initializer(lk, dtype)
    return out


_BIG = 1 << 20  # params above this get the ensure-model-sharded post-pass
_FSDP = 1 << 22  # params above this are additionally FSDP-sharded over data


def _leaf_spec(leaf: Leaf):
    spec = sharding.resolve(*leaf.axes, shape=leaf.shape)
    if leaf.init == "embed":
        # Gather-indexed tables only shard via their natural 'vocab' rule:
        # post-pass sharding of the feature dim trips XLA's gather
        # partitioner when the vocab is not mesh-divisible (50280, 51865).
        return spec
    n = int(np.prod(leaf.shape))
    if n >= _BIG:
        spec = sharding.ensure_axis_sharded(spec, leaf.shape, "model")
    if n >= _FSDP:
        # ZeRO-3: master params (and, via moment_of, the Adam moments)
        # shard over the data axis; XLA inserts the per-layer all-gather /
        # grad reduce-scatter.
        spec = sharding.ensure_axis_sharded(spec, leaf.shape, "data")
    return spec


def abstract_params(schema, dtype=jnp.float32):
    """ShapeDtypeStructs with NamedShardings (for .lower() without allocation)."""
    mesh = sharding.mesh_or_none()
    out = {}
    for path, leaf in _iter_leaves(schema):
        sub = out
        for k in path[:-1]:
            sub = sub.setdefault(k, {})
        ns = None
        if mesh is not None:
            from jax.sharding import NamedSharding

            ns = NamedSharding(mesh, _leaf_spec(leaf))
        sub[path[-1]] = jax.ShapeDtypeStruct(leaf.shape, dtype, sharding=ns)
    return out


def param_specs(schema):
    """PartitionSpec pytree matching the schema structure."""
    out = {}
    for path, leaf in _iter_leaves(schema):
        sub = out
        for k in path[:-1]:
            sub = sub.setdefault(k, {})
        sub[path[-1]] = _leaf_spec(leaf)
    return out


def stack_schema(schema, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layers dim to every leaf (for lax.scan)."""
    if isinstance(schema, Leaf):
        return Leaf(
            shape=(n,) + schema.shape,
            axes=(axis_name,) + schema.axes,
            init=schema.init,
            scale=schema.scale,
        )
    return {k: stack_schema(v, n, axis_name) for k, v in schema.items()}


def count_params(schema) -> int:
    return sum(int(np.prod(leaf.shape)) for _, leaf in _iter_leaves(schema))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, wg, wu, wd):
    """LLaMA-style gated MLP.  x: (..., d); wg/wu: (d, ff); wd: (ff, d)."""
    h = jax.nn.silu(x @ cast(wg)) * (x @ cast(wu))
    h = sharding.constrain(h, "batch", "seq", "mlp")
    return h @ cast(wd)


def gelu_mlp(x, wi, wo):
    h = jax.nn.gelu(x @ cast(wi), approximate=True)
    h = sharding.constrain(h, "batch", "seq", "mlp")
    return h @ cast(wo)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(n_pos: int, dim: int) -> jnp.ndarray:
    pos = np.arange(n_pos)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (..., V) f32; labels (...) int32 -> mean loss (f32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
