"""Attention variants: GQA (full / sliding-window), MLA, cross-attention.

All functions are shape-polymorphic over (B, S) and share one KV-cache
convention for decode:

  GQA cache:  {"k": (B, S_max, KV, hd), "v": (B, S_max, KV, hd), "pos": (B,)}
  MLA cache:  {"ckv": (B, S_max, kv_lora), "k_rope": (B, S_max, rope_dim), "pos": (B,)}

MLA caches the *compressed* latent (DeepSeek-V2's serving advantage: 576
floats/token vs 2 * KV * hd) — the property that makes deepseek-v2 the
cheapest decode_32k cell in the roofline table.

Grouped einsums keep K/V un-repeated for GQA (no head-replication traffic).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import layers
from repro.models.layers import Leaf, apply_rope, cast, rmsnorm

NEG_INF = -1e9  # bf16-safe large negative


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def gqa_schema(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    s = {
        "wq": Leaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Leaf((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Leaf((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Leaf((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = Leaf((hd,), ("head_dim",), init="zeros")
        s["k_norm"] = Leaf((hd,), ("head_dim",), init="zeros")
    return s


def mla_schema(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim
    return {
        "wq_a": Leaf((d, cfg.q_lora_rank), ("embed", None)),
        "q_a_norm": Leaf((cfg.q_lora_rank,), (None,), init="zeros"),
        "wq_b": Leaf(
            (cfg.q_lora_rank, h, qk + cfg.qk_rope_dim), (None, "heads", "head_dim")
        ),
        "wkv_a": Leaf((d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", None)),
        "kv_a_norm": Leaf((cfg.kv_lora_rank,), (None,), init="zeros"),
        "wkv_b": Leaf(
            (cfg.kv_lora_rank, h, qk + cfg.v_head_dim), (None, "heads", "head_dim")
        ),
        "wo": Leaf((h, cfg.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def cross_schema(cfg: ModelConfig) -> dict:
    d, hd, h = cfg.d_model, cfg.resolved_head_dim, cfg.n_heads
    return {
        "wq": Leaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Leaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wv": Leaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wo": Leaf((h, hd, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------


def _mask_bias(
    q_pos: jnp.ndarray,  # (B, Sq)
    k_pos: jnp.ndarray,  # (B, Sk)
    causal: bool,
    window: Optional[int],
    k_valid: Optional[jnp.ndarray] = None,  # (B, Sk) bool
) -> jnp.ndarray:
    """(B, 1, 1, Sq, Sk) additive bias."""
    dq = q_pos[:, :, None]
    dk = k_pos[:, None, :]
    ok = jnp.ones_like(dq + dk, dtype=bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dq - dk < window
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :].astype(jnp.float32)


def _grouped_attention(q, k, v, bias):
    """q: (B,Sq,KV,G,hd); k/v: (B,Sk,KV,hd); bias: (B,1,1,Sq,Sk) -> (B,Sq,KV,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd) + jnp.transpose(bias, (0, 2, 1, 3, 4))
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v, preferred_element_type=jnp.float32)


def _chunked_grouped_attention(
    q, k, v, q_pos, k_pos, causal: bool, window: Optional[int], chunk: int
):
    """Flash-style online-softmax attention, scanned over KV chunks.

    Never materializes the (Sq, Sk) score matrix: per chunk the working set
    is (B, KV, G, Sq, chunk) — O(S * chunk) instead of O(S^2).  Numerics:
    running max/denominator in f32 (the FlashAttention recurrence).
    q: (B,Sq,KV,G,hd); k/v: (B,Sk,KV,hd); q_pos/k_pos: (B, Sq)/(B, Sk).
    """
    b, sq, kv, g, hd = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    assert sk % chunk == 0, f"kv len {sk} not divisible by chunk {chunk}"
    nc = sk // chunk
    scale = np.float32(1.0 / np.sqrt(hd))

    kc = k.reshape(b, nc, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry  # (B,KV,G,Sq) f32, same, (B,Sq,KV,G,hd) f32
        k_i, v_i, kp_i = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, k_i, preferred_element_type=jnp.float32)
        s = s * scale
        ok = jnp.ones((b, sq, chunk), bool)
        dq = q_pos[:, :, None]
        dk = kp_i[:, None, :]
        if causal:
            ok &= dk <= dq
        if window is not None:
            ok &= dq - dk < window
        ok &= dk >= 0  # ring slots never written stay masked
        s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]

        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)  # rescale old accumulator
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum(
            "bkgqs,bskh->bqkgh", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, kpc))
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return acc / denom


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _qkv(x, p, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    g = cfg.n_heads // kv
    q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"]))
    k = jnp.einsum("bsd,dhe->bshe", x, cast(p["wk"]))
    v = jnp.einsum("bsd,dhe->bshe", x, cast(p["wv"]))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = sharding.constrain(q, "batch", "seq", "heads", "head_dim")
    k = sharding.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = sharding.constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q.reshape(b, s, kv, g, hd), k, v


def gqa_attention(
    x: jnp.ndarray,  # (B, S, d) compute dtype
    p: dict,
    cfg: ModelConfig,
    positions: jnp.ndarray,  # (B, S)
    causal: bool = True,
    window: Optional[int] = None,
    return_kv: bool = False,
):
    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions)
    if window is None:
        window = cfg.sliding_window
    if cfg.attention_impl == "chunked":
        o = _chunked_grouped_attention(
            q, k, v, positions, positions, causal, window, cfg.attention_chunk
        )
    else:
        bias = _mask_bias(positions, positions, causal, window)
        o = _grouped_attention(q, k, v, bias)
    o = o.reshape(b, s, cfg.n_heads, cfg.resolved_head_dim).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", o, cast(p["wo"]))
    if return_kv:
        return out, (k, v)
    return out


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=layers.COMPUTE_DTYPE):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def gqa_decode(
    x: jnp.ndarray,  # (B, 1, d)
    p: dict,
    cfg: ModelConfig,
    cache: dict,
    pos: jnp.ndarray,  # (B,) ABSOLUTE position (tokens already cached)
    window: Optional[int] = None,
    ring: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """One decode step.  ``ring=True`` treats the cache as a circular buffer
    of the last ``cache_len`` tokens (sliding-window archs): K/V are written
    at slot ``pos % cache_len`` but RoPE always uses absolute positions, and
    each slot's absolute position is reconstructed for masking."""
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    q, k_new, v_new = _qkv(x, p, cfg, pos[:, None])  # RoPE at absolute pos
    slot = pos % cache_len if ring else pos
    if cfg.cache_update == "dus":
        # O(1)-traffic write at the (synchronized) stream position.
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot[0], 0, 0)
        )
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot[0], 0, 0)
        )
    else:
        oh = jax.nn.one_hot(slot, cache_len, dtype=cache["k"].dtype)  # (B, S_max)
        k = _scatter_cache(cache["k"], k_new, oh)
        v = _scatter_cache(cache["v"], v_new, oh)

    j = jnp.arange(cache_len)[None]  # (1, S_max)
    if ring:
        # Absolute position last written to slot j (negative -> never written).
        k_pos = pos[:, None] - jnp.mod(pos[:, None] - j, cache_len)
        valid = k_pos >= 0
    else:
        k_pos = jnp.broadcast_to(j, (b, cache_len))
        valid = k_pos <= pos[:, None]
    bias = _mask_bias(
        pos[:, None], k_pos, causal=False, window=window or cfg.sliding_window,
        k_valid=valid,
    )
    o = _grouped_attention(q, k.astype(x.dtype), v.astype(x.dtype), bias)
    o = o.reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", o, cast(p["wo"]))
    return out, {"k": k, "v": v}


def _scatter_cache(buf, new, oh):
    """buf (B,S,KV,hd); new (B,1,KV,hd); oh (B,S) one-hot at write position."""
    keep = (1.0 - oh)[:, :, None, None].astype(buf.dtype)
    return buf * keep + oh[:, :, None, None].astype(buf.dtype) * new.astype(buf.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def _mla_q(x, p, cfg, positions):
    q_a = rmsnorm(x @ cast(p["wq_a"]), p["q_a_norm"])
    q = jnp.einsum("bsr,rhe->bshe", q_a, cast(p["wq_b"]))
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv(ckv, p, cfg):
    """Expand compressed latent (B,S,kv_lora) -> per-head k_nope, v."""
    kv = jnp.einsum("bsr,rhe->bshe", ckv, cast(p["wkv_b"]))
    return jnp.split(kv, [cfg.qk_nope_dim], axis=-1)  # k_nope, v


def mla_attention(x, p, cfg: ModelConfig, positions, causal: bool = True,
                  return_kv: bool = False):
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(x, p, cfg, positions)
    kv_a = x @ cast(p["wkv_a"])
    ckv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    ckv = rmsnorm(ckv, p["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head
    k_nope, v = _mla_kv(ckv, p, cfg)

    bias = _mask_bias(positions, positions, causal, None)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (
        jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope[:, :, 0], preferred_element_type=jnp.float32)
    ) * scale + bias[:, 0]
    probs = jax.nn.softmax(scores, -1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhe->bqhe", probs, v, preferred_element_type=jnp.float32)
    o = o.astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", o, cast(p["wo"]))
    if return_kv:
        return out, (ckv, k_rope[:, :, 0])
    return out


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=layers.COMPUTE_DTYPE):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(x, p, cfg: ModelConfig, cache, pos):
    b = x.shape[0]
    max_len = cache["ckv"].shape[1]
    q_nope, q_rope = _mla_q(x, p, cfg, pos[:, None])

    kv_a = x @ cast(p["wkv_a"])
    ckv_new, k_rope_new = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    ckv_new = rmsnorm(ckv_new, p["kv_a_norm"])
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]

    if cfg.cache_update == "dus":
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos[0], 0)
        )
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos[0], 0)
        )
    else:
        oh = jax.nn.one_hot(pos, max_len, dtype=cache["ckv"].dtype)
        ckv = _scatter_flat(cache["ckv"], ckv_new, oh)
        k_rope = _scatter_flat(cache["k_rope"], k_rope_new, oh)

    k_nope, v = _mla_kv(ckv.astype(x.dtype), p, cfg)
    k_pos = jnp.broadcast_to(jnp.arange(max_len)[None], (b, max_len))
    valid = k_pos <= pos[:, None]
    bias = _mask_bias(pos[:, None], k_pos, causal=False, window=None, k_valid=valid)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (
        jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope.astype(x.dtype), preferred_element_type=jnp.float32)
    ) * scale + bias[:, 0]
    probs = jax.nn.softmax(scores, -1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhe->bqhe", probs, v)
    out = jnp.einsum("bshe,hed->bsd", o.astype(x.dtype), cast(p["wo"]))
    return out, {"ckv": ckv, "k_rope": k_rope}


def _scatter_flat(buf, new, oh):
    """buf (B,S,D); new (B,1,D); oh (B,S)."""
    keep = 1.0 - oh
    return buf * keep[:, :, None] + oh[:, :, None] * new.astype(buf.dtype)


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attention(x, p, ctx, src_valid=None):
    """x: (B, Sq, d) queries; ctx: (B, Sk, d) encoder output."""
    b, sq, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"]))
    k = jnp.einsum("bsd,dhe->bshe", ctx, cast(p["wk"]))
    v = jnp.einsum("bsd,dhe->bshe", ctx, cast(p["wv"]))
    hd = q.shape[-1]
    scores = jnp.einsum("bqhe,bkhe->bhqk", q, k, preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd)
    if src_valid is not None:
        scores += jnp.where(src_valid, 0.0, NEG_INF)[:, None, None, :]
    probs = jax.nn.softmax(scores, -1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhe->bqhe", probs, v)
    return jnp.einsum("bshe,hed->bsd", o.astype(x.dtype), cast(p["wo"]))
