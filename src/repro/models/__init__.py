"""repro.models"""
