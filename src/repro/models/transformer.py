"""Decoder-only LM assembly: scan-over-layers, remat, heterogeneous stacks.

One code path serves all eight decoder-only assigned archs:
  dense  — GQA attention (+ optional SWA / qk_norm) + SwiGLU       (h2o-danube,
           deepseek-coder, mistral-nemo, qwen3, chameleon)
  moe    — GQA or MLA attention + fine-grained MoE                  (deepseek-moe,
           deepseek-v2)
  ssm    — Mamba-2 SSD mixer only                                   (mamba2)
  hybrid — Griffin pattern (rec, rec, attn) with per-block MLPs     (recurrentgemma)

Layers are stacked (leading L dim on every leaf) and executed with
``jax.lax.scan`` + ``jax.checkpoint`` so the unrolled HLO stays one layer
deep — this is what keeps 60-layer/160-expert configs compilable and remat
memory bounded.  Hybrid stacks scan over pattern *groups* plus an explicit
tail stack when n_layers % len(pattern) != 0.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models import attention, moe, rglru, ssm
from repro.models.layers import (
    Leaf,
    cast,
    rmsnorm,
    stack_schema,
    swiglu,
)

# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def _mixer_schema(cfg: ModelConfig) -> dict:
    if cfg.family == "ssm":
        return {"norm": Leaf((cfg.d_model,), ("embed",), init="zeros"),
                "ssd": ssm.ssd_schema(cfg)}
    s: dict = {"ln1": Leaf((cfg.d_model,), ("embed",), init="zeros")}
    s["attn"] = attention.mla_schema(cfg) if cfg.use_mla else attention.gqa_schema(cfg)
    s["ln2"] = Leaf((cfg.d_model,), ("embed",), init="zeros")
    if cfg.n_experts:
        s["moe"] = moe.moe_schema(cfg)
    else:
        d, ff = cfg.d_model, cfg.d_ff
        s["mlp"] = {
            "wg": Leaf((d, ff), ("embed", "mlp")),
            "wu": Leaf((d, ff), ("embed", "mlp")),
            "wd": Leaf((ff, d), ("mlp", "embed")),
        }
    return s


def _hybrid_sub_schema(cfg: ModelConfig, kind: str) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    mlp = {
        "wg": Leaf((d, ff), ("embed", "mlp")),
        "wu": Leaf((d, ff), ("embed", "mlp")),
        "wd": Leaf((ff, d), ("mlp", "embed")),
    }
    if kind == "rec":
        return {
            "ln1": Leaf((d,), ("embed",), init="zeros"),
            "rec": rglru.rglru_schema(cfg),
            "ln2": Leaf((d,), ("embed",), init="zeros"),
            "mlp": mlp,
        }
    return {
        "ln1": Leaf((d,), ("embed",), init="zeros"),
        "attn": attention.gqa_schema(cfg),
        "ln2": Leaf((d,), ("embed",), init="zeros"),
        "mlp": mlp,
    }


def lm_schema(cfg: ModelConfig) -> dict:
    s: dict = {
        "embed": Leaf((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_norm": Leaf((cfg.d_model,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Leaf((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))

    if cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern
        n_groups, tail = divmod(cfg.n_layers, len(pat))
        group = {f"b{i}_{k}": _hybrid_sub_schema(cfg, k) for i, k in enumerate(pat)}
        s["groups"] = stack_schema(group, n_groups)
        if tail:
            tail_group = {
                f"b{i}_{k}": _hybrid_sub_schema(cfg, k)
                for i, k in enumerate(pat[:tail])
            }
            s["tail"] = stack_schema(tail_group, 1)
    else:
        s["layers"] = stack_schema(_mixer_schema(cfg), cfg.n_layers)
    return s


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _dense_layer(h, lp, cfg: ModelConfig, positions):
    hn = rmsnorm(h, lp["ln1"])
    if cfg.use_mla:
        h = h + attention.mla_attention(hn, lp["attn"], cfg, positions)
    else:
        h = h + attention.gqa_attention(hn, lp["attn"], cfg, positions)
    hn = rmsnorm(h, lp["ln2"])
    if cfg.n_experts:
        y, aux = moe.moe_block(hn, lp["moe"], cfg)
        return h + y, aux
    return h + swiglu(hn, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"]), 0.0


def _ssm_layer(h, lp, cfg: ModelConfig, positions):
    del positions
    return h + ssm.ssd_block(rmsnorm(h, lp["norm"]), lp["ssd"], cfg), 0.0


def _hybrid_sub(h, sp, kind: str, cfg: ModelConfig, positions):
    hn = rmsnorm(h, sp["ln1"])
    if kind == "rec":
        h = h + rglru.rglru_block(hn, sp["rec"], cfg)
    else:
        h = h + attention.gqa_attention(
            hn, sp["attn"], cfg, positions, window=cfg.sliding_window
        )
    hn = rmsnorm(h, sp["ln2"])
    return h + swiglu(hn, sp["mlp"]["wg"], sp["mlp"]["wu"], sp["mlp"]["wd"])


def _stack_len(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def scan_or_loop(body, init, xs, unroll: bool):
    """lax.scan drop-in that can unroll to a Python loop (cost extrapolation).

    Supports pytree ys (stacked along axis 0) like lax.scan.
    """
    if not unroll:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    for i in range(_stack_len(xs)):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked_ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked_ys = None
    return carry, stacked_ys


def _scan_stack(h, stacked, layer_fn, remat: bool, unroll: bool = False):
    fn = layer_fn
    if remat:
        fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable
        )

    if unroll:  # dry-run cost extrapolation path (see ModelConfig)
        aux = 0.0
        for i in range(_stack_len(stacked)):
            h, a = fn(h, jax.tree.map(lambda x: x[i], stacked))
            aux = aux + a
        return h, aux

    def body(carry, lp):
        hh, aux = carry
        hh, a = fn(hh, lp)
        return (hh, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, 0.0), stacked)
    return h, aux


def lm_hidden(
    params: dict,
    tokens: jnp.ndarray,  # (B, S) int32
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
    remat: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids -> final hidden states (B, S, d) [compute dtype], aux loss."""
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
        )
    h = cast(params["embed"])[tokens]
    h = sharding.constrain(h, "batch", "seq", "embed")
    unroll = cfg.unroll_layers

    if cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern

        def group_fn(hh, gp):
            for i, kind in enumerate(pat):
                key = f"b{i}_{kind}"
                if key in gp:
                    hh = _hybrid_sub(hh, gp[key], kind, cfg, positions)
            return hh, 0.0

        h, aux = _scan_stack(h, params["groups"], group_fn, remat, unroll)
        if "tail" in params:
            def tail_fn(hh, gp):
                for i, kind in enumerate(pat):
                    key = f"b{i}_{kind}"
                    if key in gp:
                        hh = _hybrid_sub(hh, gp[key], kind, cfg, positions)
                return hh, 0.0

            h, _ = _scan_stack(h, params["tail"], tail_fn, remat, unroll)
    elif cfg.family == "ssm":
        h, aux = _scan_stack(
            h, params["layers"],
            functools.partial(_ssm_layer, cfg=cfg, positions=positions), remat, unroll,
        )
    else:
        h, aux = _scan_stack(
            h,
            params["layers"],
            functools.partial(_dense_layer, cfg=cfg, positions=positions),
            remat,
            unroll,
        )

    h = rmsnorm(h, params["final_norm"])
    h = sharding.constrain(h, "batch", "seq", "embed")
    return h, aux


def lm_logits(params: dict, hidden: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden, cast(params["embed"]))
    else:
        logits = hidden @ cast(params["lm_head"])
    return sharding.constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Prefill: single forward pass that also builds decode caches
# ---------------------------------------------------------------------------


def _pad_full_cache(k, v, max_len):
    """Full-attention cache: (B,S,KV,hd) K/V padded to max_len slots."""
    b, s, kv, hd = k.shape
    if s == max_len:
        return {"k": k, "v": v}
    kp = jnp.zeros((b, max_len, kv, hd), k.dtype).at[:, :s].set(k)
    vp = jnp.zeros((b, max_len, kv, hd), v.dtype).at[:, :s].set(v)
    return {"k": kp, "v": vp}


def _ring_cache(k, v, win, s_total):
    """Sliding-window ring: last win tokens at slots (abs_pos % win)."""
    b, s, kv, hd = k.shape
    take = min(s, win)
    idx = (jnp.arange(s_total - take, s_total)) % win
    kr = jnp.zeros((b, win, kv, hd), k.dtype).at[:, idx].set(k[:, -take:])
    vr = jnp.zeros((b, win, kv, hd), v.dtype).at[:, idx].set(v[:, -take:])
    return {"k": kr, "v": vr}


def lm_prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, max_len=None):
    """Forward the prompt once, collecting per-layer decode caches as scan ys.

    Returns (final_hidden (B,S,d), caches, pos (B,)) with cache structure
    identical to ``init_caches``.
    """
    b, s = tokens.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], tokens.shape)
    h = cast(params["embed"])[tokens]
    h = sharding.constrain(h, "batch", "seq", "embed")

    if cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern

        def group_fn(hh, gp):
            new_c = {}
            for i, kind in enumerate(pat):
                key = f"b{i}_{kind}"
                if key not in gp:
                    continue
                sp = gp[key]
                hn = rmsnorm(hh, sp["ln1"])
                if kind == "rec":
                    y, new_c[key] = rglru.rglru_block(hn, sp["rec"], cfg, return_cache=True)
                else:
                    y, (k, v) = attention.gqa_attention(
                        hn, sp["attn"], cfg, positions, window=cfg.sliding_window,
                        return_kv=True,
                    )
                    new_c[key] = _ring_cache(k, v, min(max_len, cfg.sliding_window or max_len), s)
                hh = hh + y
                hn = rmsnorm(hh, sp["ln2"])
                hh = hh + swiglu(hn, sp["mlp"]["wg"], sp["mlp"]["wu"], sp["mlp"]["wd"])
            return hh, new_c

        h, groups_c = scan_or_loop(group_fn, h, params["groups"], cfg.unroll_layers)
        caches = {"groups": groups_c}
        if "tail" in params:
            h, tail_c = scan_or_loop(group_fn, h, params["tail"], cfg.unroll_layers)
            caches["tail"] = tail_c
    elif cfg.family == "ssm":

        def ssm_fn(hh, lp):
            y, c = ssm.ssd_block(rmsnorm(hh, lp["norm"]), lp["ssd"], cfg, return_cache=True)
            return hh + y, c

        h, layer_c = scan_or_loop(ssm_fn, h, params["layers"], cfg.unroll_layers)
        caches = {"layers": layer_c}
    else:

        def dense_fn(hh, lp):
            hn = rmsnorm(hh, lp["ln1"])
            if cfg.use_mla:
                y, (ckv, k_rope) = attention.mla_attention(
                    hn, lp["attn"], cfg, positions, return_kv=True
                )
                if s == max_len:
                    c = {"ckv": ckv, "k_rope": k_rope}
                else:
                    c = {
                        "ckv": jnp.zeros((b, max_len, ckv.shape[-1]), ckv.dtype).at[:, :s].set(ckv),
                        "k_rope": jnp.zeros((b, max_len, k_rope.shape[-1]), k_rope.dtype).at[:, :s].set(k_rope),
                    }
            else:
                y, (k, v) = attention.gqa_attention(
                    hn, lp["attn"], cfg, positions, return_kv=True
                )
                if cfg.sliding_window is not None:
                    c = _ring_cache(k, v, min(max_len, cfg.sliding_window), s)
                else:
                    c = _pad_full_cache(k, v, max_len)
            hh = hh + y
            hn = rmsnorm(hh, lp["ln2"])
            if cfg.n_experts:
                y2, _ = moe.moe_block(hn, lp["moe"], cfg)
                hh = hh + y2
            else:
                hh = hh + swiglu(hn, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
            return hh, c

        h, layer_c = scan_or_loop(dense_fn, h, params["layers"], cfg.unroll_layers)
        caches = {"layers": layer_c}

    h = rmsnorm(h, params["final_norm"])
    pos = jnp.full((b,), s, jnp.int32)
    return h, caches, pos


# ---------------------------------------------------------------------------
# Decode (one token, stacked caches)
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-layer caches (leading dim = n stacked layers/groups)."""

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    if cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern
        n_groups, tail = divmod(cfg.n_layers, len(pat))
        group = {}
        for i, kind in enumerate(pat):
            if kind == "rec":
                group[f"b{i}_{kind}"] = rglru.rglru_init_cache(cfg, batch)
            else:
                group[f"b{i}_{kind}"] = attention.gqa_init_cache(
                    cfg, batch, min(max_len, cfg.sliding_window or max_len)
                )
        caches = {"groups": rep(group, n_groups)}
        if tail:
            tail_group = {
                f"b{i}_{k}": (
                    rglru.rglru_init_cache(cfg, batch)
                    if k == "rec"
                    else attention.gqa_init_cache(cfg, batch, min(max_len, cfg.sliding_window or max_len))
                )
                for i, k in enumerate(pat[:tail])
            }
            caches["tail"] = rep(tail_group, 1)
        return caches
    if cfg.family == "ssm":
        return {"layers": rep(ssm.ssd_init_cache(cfg, batch), cfg.n_layers)}
    if cfg.use_mla:
        return {"layers": rep(attention.mla_init_cache(cfg, batch, max_len), cfg.n_layers)}
    eff = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    return {"layers": rep(attention.gqa_init_cache(cfg, batch, eff), cfg.n_layers)}


def _decode_dense_layer(h, lp, cache_l, cfg: ModelConfig, pos):
    hn = rmsnorm(h, lp["ln1"])
    if cfg.use_mla:
        a, new_cache = attention.mla_decode(hn, lp["attn"], cfg, cache_l, pos)
    else:
        ring = cfg.sliding_window is not None
        a, new_cache = attention.gqa_decode(hn, lp["attn"], cfg, cache_l, pos, ring=ring)
    h = h + a
    hn = rmsnorm(h, lp["ln2"])
    if cfg.n_experts:
        y, _ = moe.moe_block(hn, lp["moe"], cfg)
        h = h + y
    else:
        h = h + swiglu(hn, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    return h, new_cache


def _decode_ssm_layer(h, lp, cache_l, cfg: ModelConfig, pos):
    del pos
    y, new_cache = ssm.ssd_decode(rmsnorm(h, lp["norm"]), lp["ssd"], cfg, cache_l)
    return h + y, new_cache


def _decode_hybrid_sub(h, sp, cache_s, kind, cfg: ModelConfig, pos):
    hn = rmsnorm(h, sp["ln1"])
    if kind == "rec":
        y, new_cache = rglru.rglru_decode(hn, sp["rec"], cfg, cache_s)
    else:
        # Local attention over a ring buffer of the last `window` tokens.
        y, new_cache = attention.gqa_decode(hn, sp["attn"], cfg, cache_s, pos, ring=True)
    h = h + y
    hn = rmsnorm(h, sp["ln2"])
    return h + swiglu(hn, sp["mlp"]["wg"], sp["mlp"]["wu"], sp["mlp"]["wd"]), new_cache


def lm_decode_hidden(
    params: dict,
    token: jnp.ndarray,  # (B, 1) int32
    caches: dict,
    pos: jnp.ndarray,  # (B,) int32 number of tokens already in cache
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, dict]:
    """One decode step -> final hidden (B, 1, d) + updated caches."""
    h = cast(params["embed"])[token]

    if cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern

        def group_fn(hh, xs):
            gp, gc = xs
            new_gc = {}
            for i, kind in enumerate(pat):
                key = f"b{i}_{kind}"
                if key in gp:
                    hh, new_gc[key] = _decode_hybrid_sub(hh, gp[key], gc[key], kind, cfg, pos)
            return hh, new_gc

        h, new_groups = scan_or_loop(group_fn, h, (params["groups"], caches["groups"]), cfg.unroll_layers)
        new_caches = {"groups": new_groups}
        if "tail" in params:
            h, new_tail = scan_or_loop(group_fn, h, (params["tail"], caches["tail"]), cfg.unroll_layers)
            new_caches["tail"] = new_tail
    else:
        layer = _decode_ssm_layer if cfg.family == "ssm" else _decode_dense_layer

        def body(hh, xs):
            lp, lc = xs
            hh, nc = layer(hh, lp, lc, cfg, pos)
            return hh, nc

        h, new_layers = scan_or_loop(body, h, (params["layers"], caches["layers"]), cfg.unroll_layers)
        new_caches = {"layers": new_layers}

    h = rmsnorm(h, params["final_norm"])
    return h, new_caches
