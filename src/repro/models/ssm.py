"""Mamba-2 SSD (state-space duality) block — chunked linear-time scan.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): within
chunks of length Q the recurrence is computed as a masked quadratic form
(MXU-friendly), between chunks a tiny recurrent state (H, P, N_state) is
carried by an associative scan — O(S * Q) work, O(S/Q) sequential depth.
This is what makes the ``long_500k`` cell lowerable for mamba2-780m.

Decode keeps the (B, H, P, N) state + a (B, W-1, conv_dim) conv tail and
advances one token in O(1).

Shapes follow the reference implementation:
  d_inner = expand * d_model;  H = d_inner / headdim;  N = ssm_state
  in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models.layers import Leaf, cast, rmsnorm


def ssd_schema(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * n
    return {
        "in_proj": Leaf((d, 2 * di + 2 * n + h), ("embed", "mlp")),
        "conv_w": Leaf((cfg.ssm_conv_width, conv_dim), (None, "mlp"), scale=0.5),
        "conv_b": Leaf((conv_dim,), ("mlp",), init="zeros"),
        "a_log": Leaf((h,), ("ssm_heads",), init="zeros"),
        "dt_bias": Leaf((h,), ("ssm_heads",), init="zeros"),
        "d_skip": Leaf((h,), ("ssm_heads",), init="ones"),
        "norm": Leaf((di,), ("mlp",), init="zeros"),
        "out_proj": Leaf((di, d), ("mlp", "embed")),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    z, x, b, c, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], -1)
    return z, x, b, c, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv; x: (B, S, C), w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * cast(w)[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + cast(b))


def ssd_scan(xh, dt, a, bmat, cmat, chunk: int):
    """Chunked SSD.  xh: (B,S,H,P); dt: (B,S,H) >=0; a: (H,) <0 decay rates;
    bmat/cmat: (B,S,N).  Returns (B,S,H,P) and final state (B,H,P,N)."""
    bsz, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"

    # log-decay per step: dA = dt * a   (negative)
    da = dt * a[None, None, :]  # (B,S,H)
    da_c = da.reshape(bsz, nc, q, h)
    xs = (xh * dt[..., None]).reshape(bsz, nc, q, h, p)  # dt-weighted input
    bs = bmat.reshape(bsz, nc, q, n)
    cs = cmat.reshape(bsz, nc, q, n)

    cum = jnp.cumsum(da_c, axis=2)  # (B,nc,q,H) inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,q_i,q_j,H)
    iota = jnp.arange(q)
    causal = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    # Mask the EXPONENT (not the exp) — exp of a large positive non-causal
    # entry would be inf and poison the backward pass via where's 0 * inf.
    seg = jnp.where(causal, seg, -1e9)
    decay_ij = jnp.exp(seg)  # (B,nc,i,j,H)

    # Intra-chunk: Y_intra[i] = sum_j<=i C_i.B_j decay(i,j) X_j
    cb = jnp.einsum("bnim,bnjm->bnij", cs, bs, preferred_element_type=jnp.float32)
    y_intra = jnp.einsum(
        "bnij,bnijh,bnjhp->bnihp", cb, decay_ij, xs, preferred_element_type=jnp.float32
    )

    # Chunk summary states: S_n = sum_j decay(end, j) B_j^T X_j  -> (B,nc,H,P,N)
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,q,H)
    s_chunk = jnp.einsum(
        "bnjm,bnjh,bnjhp->bnhpm", bs, decay_end, xs, preferred_element_type=jnp.float32
    )

    # Inter-chunk recurrence over nc: S_{n} = exp(sum da_n) S_{n-1} + s_chunk_n
    chunk_decay = jnp.exp(jnp.sum(da_c, axis=2))  # (B,nc,H)

    def assoc(eL, eR):
        aL, sL = eL
        aR, sR = eR
        return aL * aR, sR + aR[..., None, None] * sL

    a_acc, s_acc = jax.lax.associative_scan(
        assoc, (chunk_decay, s_chunk), axis=1
    )  # inclusive: state at end of each chunk
    # State entering chunk n = exclusive scan.
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_acc[:, :1]), s_acc[:, :-1]], axis=1
    )  # (B,nc,H,P,N)

    # Inter-chunk output: Y_inter[i] = C_i decay(i,start) S_prev
    decay_in = jnp.exp(cum)  # decay from chunk start to i (inclusive of i)
    y_inter = jnp.einsum(
        "bnim,bnih,bnhpm->bnihp", cs, decay_in, s_prev, preferred_element_type=jnp.float32
    )

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, s_acc[:, -1]  # final state (B,H,P,N)


def ssd_block(x: jnp.ndarray, p: dict, cfg: ModelConfig, return_cache: bool = False):
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    hd = cfg.ssm_headdim
    zxbcdt = x @ cast(p["in_proj"])
    z, xr, b, c, dt = _split_proj(zxbcdt, cfg)
    xbc_raw = jnp.concatenate([xr, b, c], -1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xr, b, c = jnp.split(xbc, [di, di + n], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative rates
    xh = xr.reshape(*xr.shape[:2], h, hd).astype(jnp.float32)
    xh = sharding.constrain(xh, "batch", "seq", "ssm_heads", None)

    y, final_state = ssd_scan(
        xh, dt, a, b.astype(jnp.float32), c.astype(jnp.float32), cfg.ssm_chunk
    )
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])  # gated RMSNorm (mamba2)
    out = y @ cast(p["out_proj"])
    if return_cache:
        w = cfg.ssm_conv_width
        cache = {
            "state": final_state,
            "conv": xbc_raw[:, -(w - 1):].astype(jnp.float32),
        }
        return out, cache
    return out


# ---------------------------------------------------------------------------
# Decode (O(1) per token)
# ---------------------------------------------------------------------------


def ssd_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    conv_dim = di + 2 * n
    return {
        "state": jnp.zeros((batch, h, hd, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def ssd_decode(x: jnp.ndarray, p: dict, cfg: ModelConfig, cache: dict):
    """x: (B, 1, d) -> (y, cache')."""
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = x @ cast(p["in_proj"])
    z, xr, b, c, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([xr, b, c], -1)  # (B,1,conv_dim)

    win = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], 1)
    w = cast(p["conv_w"])
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(w.dtype), w) + cast(p["conv_b"])
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    xr, b, c = jnp.split(xbc1, [di, di + n], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None, :])  # (B,H)
    xh = xr[:, 0].reshape(-1, h, hd).astype(jnp.float32)
    bx = jnp.einsum("bhp,bm->bhpm", xh * dt[..., None], b[:, 0].astype(jnp.float32))
    state = cache["state"] * da[:, :, None, None] + bx
    y = jnp.einsum("bhpm,bm->bhp", state, c[:, 0].astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ cast(p["out_proj"])
    return out, {"state": state, "conv": win[:, 1:]}
