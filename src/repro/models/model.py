"""Top-level model API: build / train_step / prefill / serve_step.

This is where the paper's technique becomes a first-class feature of the
framework (DESIGN.md §3): every backbone carries an ODL head —

  train_step: backbone CE loss -> grads -> AdamW, PLUS the OS-ELM head
    trained by rank-k RLS on pooled features with P1P2 pruning deciding
    which rows may skip the teacher (label) entirely.  The pruning mask
    feeds the masked RLS update, so a skipped sample costs zero compute
    and zero label traffic — the paper's comm saving, fused into the step.

  serve_step: one decode token, plus the head's prediction and the
    P1P2/auto-theta gate per stream.  The gate's output (a ``GateOutput``
    with the ``queried`` mask and the plan-time decision context) is the
    cascade signal: which streams must consult the teacher.  Label
    application is asynchronous (BLE round-trip in the paper; a separate
    `serve_apply_labels` call here, fed the same GateOutput so delayed
    answers are judged at query-time context).  ``decode_step`` is the
    gate-free variant for the multiplexed serving path.

All functions are pure and pjit-friendly; `input_specs` yields weak-typed
ShapeDtypeStructs for the multi-pod dry-run.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import drift as drift_mod
from repro.core import oselm, pruning
from repro.distributed import sharding
from repro.engine import fleet as engine
from repro.models import encdec, layers, transformer
from repro.models.layers import softmax_cross_entropy
from repro.optim import adam


# ---------------------------------------------------------------------------
# Schema / state
# ---------------------------------------------------------------------------


def build_schema(cfg: ModelConfig) -> dict:
    if cfg.enc_dec:
        return encdec.encdec_schema(cfg)
    return transformer.lm_schema(cfg)


def elm_config(cfg: ModelConfig) -> oselm.OSELMConfig:
    return oselm.OSELMConfig(
        n_in=cfg.d_model,
        n_hidden=cfg.odl.n_hidden,
        n_out=cfg.odl.n_out,
        variant=cfg.odl.variant,
        seed=cfg.odl.seed,
        ridge=cfg.odl.ridge,
        use_kernel=cfg.odl.use_kernel,
    )


def core_config(cfg: ModelConfig) -> engine.EngineConfig:
    """Fleet-engine config for this backbone's per-stream ODL heads."""
    ecfg = elm_config(cfg)
    return engine.EngineConfig(
        elm=ecfg,
        prune=pruning.PruneConfig.for_hidden(ecfg.n_hidden),
        drift=drift_mod.DriftConfig(),
    )


class ODLState(NamedTuple):
    elm: oselm.OSELMState
    prune: pruning.PruneState


class TrainState(NamedTuple):
    params: dict
    opt: adam.AdamState
    odl: ODLState


def init_odl_state(cfg: ModelConfig) -> ODLState:
    return ODLState(elm=oselm.init_state(elm_config(cfg)), prune=pruning.init_state())


def init_train_state(cfg: ModelConfig, key, tcfg: TrainConfig = TrainConfig()) -> TrainState:
    params = layers.init_params(build_schema(cfg), key, dtype=jnp.dtype(tcfg.param_dtype))
    return TrainState(params=params, opt=adam.init(params), odl=init_odl_state(cfg))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def _forward_loss(params, batch, cfg: ModelConfig, remat: bool):
    if cfg.enc_dec:
        enc = encdec.encode(params, batch["frames"], cfg, remat=remat)
        hidden = encdec.decode_train(params, batch["tokens"], enc, cfg, remat=remat)
        logits = encdec.logits(params, hidden)
        aux = 0.0
    else:
        hidden, aux = transformer.lm_hidden(params, batch["tokens"], cfg, remat=remat)
        logits = transformer.lm_logits(params, hidden, cfg)
    ce = softmax_cross_entropy(logits, batch["labels"])
    loss = ce + 0.01 * aux
    feats = jnp.mean(hidden.astype(jnp.float32), axis=1)  # (B, d) pooled
    return loss, (ce, feats)


# ---------------------------------------------------------------------------
# ODL head update (the paper's technique, fused into the train step)
# ---------------------------------------------------------------------------


def odl_update(
    odl: ODLState,
    feats: jnp.ndarray,  # (B, d_model) f32
    odl_labels: jnp.ndarray,  # (B,) int32 teacher labels
    cfg: ModelConfig,
    drift_active: Optional[jnp.ndarray] = None,
) -> tuple[ODLState, dict]:
    ecfg = elm_config(cfg)
    pcfg = pruning.PruneConfig.for_hidden(ecfg.n_hidden)
    if drift_active is None:
        drift_active = jnp.zeros((), jnp.bool_)

    preds, outs = oselm.predict(odl.elm, feats, ecfg)  # (B,), (B, m)
    conf = pruning.confidence(outs)
    theta = pruning.theta_of(odl.prune, pcfg)
    warm = odl.elm.count >= pcfg.min_trained
    prune_mask = warm & jnp.logical_not(drift_active) & (conf > theta)
    queried = jnp.logical_not(prune_mask)  # (B,)

    y = jax.nn.one_hot(odl_labels, ecfg.n_out)
    new_elm = oselm.sequential_update(
        odl.elm, feats, y, ecfg, mask=queried.astype(jnp.float32)
    )
    agree = preds == odl_labels
    new_prune = pruning.scan_update(odl.prune, queried, agree, conf, pcfg)

    metrics = {
        "odl_query_frac": jnp.mean(queried.astype(jnp.float32)),
        "odl_acc": jnp.mean(agree.astype(jnp.float32)),
        "odl_theta": theta,
    }
    return ODLState(elm=new_elm, prune=new_prune), metrics


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def train_step(
    state: TrainState,
    batch: dict,
    cfg: ModelConfig,
    tcfg: TrainConfig = TrainConfig(),
) -> tuple[TrainState, dict]:
    """One optimizer step with optional gradient accumulation.

    batch: tokens/labels (B, S) [+ frames for enc-dec] + odl_labels (B,).
    """
    grad_fn = jax.value_and_grad(_forward_loss, has_aux=True)

    if tcfg.microbatches > 1:
        mb = tcfg.microbatches

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        mbatch = {k: split(v) for k, v in batch.items()}

        def body(carry, mb_batch):
            gsum, lsum = carry
            (loss, (ce, feats)), grads = grad_fn(state.params, mb_batch, cfg, tcfg.remat)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / mb, gsum, grads
            )
            return (gsum, lsum + loss / mb), feats

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (grads, loss), feats_mb = jax.lax.scan(body, (zeros, 0.0), mbatch)
        feats = feats_mb.reshape((-1, feats_mb.shape[-1]))
    else:
        (loss, (ce, feats)), grads = grad_fn(state.params, batch, cfg, tcfg.remat)

    new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, tcfg)
    new_odl, odl_metrics = odl_update(state.odl, feats, batch["odl_labels"], cfg)

    metrics = {"loss": loss, "grad_norm": gnorm, **odl_metrics}
    return TrainState(params=new_params, opt=new_opt, odl=new_odl), metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


class ServeState(NamedTuple):
    caches: dict
    pos: jnp.ndarray  # (B,) int32
    odl: engine.EngineState  # fleet engine: elm/prune/drift/meter, leading B


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> ServeState:
    return ServeState(
        caches=transformer.init_caches(cfg, batch, max_len),
        pos=jnp.zeros((batch,), jnp.int32),
        odl=engine.init_fleet(core_config(cfg), batch),
    )


def serve_step(
    params: dict,
    state: ServeState,
    token: jnp.ndarray,  # (B, 1) int32
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, ServeState, engine.GateOutput]:
    """One decode token + the fleet engine's predict/gate on stream features.

    Returns (logits (B, V), state', odl_out) where odl_out is the engine's
    ``GateOutput``: the per-stream prediction, confidence, ``queried`` mask
    (True -> this stream must consult the teacher), and the plan-time
    decision context (h/pred/confidence/theta) that ``serve_apply_labels``
    judges the — possibly delayed — teacher answer against.  The engine
    also runs the per-stream drift detector (a drifting stream is forced to
    query — pruning condition 2) and meters query traffic.
    """
    hidden, new_caches = transformer.lm_decode_hidden(
        params, token, state.caches, state.pos, cfg
    )
    logits = transformer.lm_logits(params, hidden, cfg)[:, 0]

    feats = hidden[:, 0].astype(jnp.float32)  # (B, d)
    new_odl, odl_out = engine.gate(state.odl, feats, core_config(cfg))
    new_state = ServeState(caches=new_caches, pos=state.pos + 1, odl=new_odl)
    return logits, new_state, odl_out


def decode_step(
    params: dict,
    state: ServeState,
    token: jnp.ndarray,  # (B, 1) int32
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, ServeState]:
    """One decode token, *without* the ODL gate: (logits, feats, state').

    The multiplexed serving path (``launch/serve.py`` + ``engine.multiplex``)
    runs the backbone once and fans the per-tick features out to N tenant
    fleets, each with its own engine state — so the gate/learn halves live
    in the tenants' ``StreamSession``s, not here.  ``state.odl`` passes
    through untouched.
    """
    hidden, new_caches = transformer.lm_decode_hidden(
        params, token, state.caches, state.pos, cfg
    )
    logits = transformer.lm_logits(params, hidden, cfg)[:, 0]
    feats = hidden[:, 0].astype(jnp.float32)  # (B, d)
    return logits, feats, state._replace(caches=new_caches, pos=state.pos + 1)


def serve_apply_labels(
    state: ServeState,
    ctx: engine.GateOutput,  # gate output captured at query time
    labels: jnp.ndarray,  # (B,) teacher labels (valid where mask)
    mask: jnp.ndarray,  # (B,) bool — streams whose teacher answered
    cfg: ModelConfig,
) -> ServeState:
    """Asynchronous label acquisition: RLS-train the per-stream heads.

    ``ctx`` is the ``GateOutput`` returned by the ``serve_step`` that issued
    the query, so a delayed reply trains on the query-time activations and
    is judged against the query-time prediction/threshold (never against
    weights that changed while the answer was in flight).
    """
    new_odl = engine.apply_labels(state.odl, ctx, labels, mask, core_config(cfg))
    return state._replace(odl=new_odl)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig, max_len: Optional[int] = None):
    """Forward the prompt once and build decode caches (single-pass).

    Returns (final_hidden, ServeState ready for serve_step).
    """
    b = tokens.shape[0]
    hidden, caches, pos = transformer.lm_prefill(params, tokens, cfg, max_len)
    state = ServeState(
        caches=caches,
        pos=pos,
        odl=engine.init_fleet(core_config(cfg), b),
    )
    return hidden, state


def encdec_prefill(params: dict, frames: jnp.ndarray, cfg: ModelConfig, max_len: int):
    enc = encdec.encode(params, frames, cfg)
    return enc, encdec.init_caches(params, enc, cfg, max_len)


# ---------------------------------------------------------------------------
# Abstract state builders (dry-run: ShapeDtypeStruct + NamedSharding only)
# ---------------------------------------------------------------------------


def _abstract_like(tree, axes_tree):
    """eval_shape pytree + logical-axes pytree -> SDS with NamedShardings."""

    def one(sds, axes):
        ns = sharding.named_sharding(*axes, shape=sds.shape)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=ns)

    return jax.tree.map(one, tree, axes_tree)


def _axes_like(tree, fn):
    """Build an axes pytree with the same structure as `tree` via fn(path, leaf)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    axes = [fn(tuple(str(k) for k in path), leaf) for path, leaf in flat]
    return jax.tree.unflatten(treedef, axes)


def cache_axes(path: tuple, leaf) -> tuple:
    """Logical axes for one decode-cache leaf, keyed by leaf name + rank.

    KV/latent caches shard their sequence dim over 'model' (flash-decoding
    style length sharding — the natural decode TP axis) and batch over
    ('pod','data'); recurrent states shard heads/width over 'model'.
    """
    name = path[-1].strip("'[]")
    nd = leaf.ndim
    lead: tuple = ("layers",)  # stacked layer/group dim
    if name in ("k", "v"):  # (L, B, S, KV, hd)
        return lead + ("batch", "seq_kv", "kv_heads", None)[: nd - 1]
    if name in ("ckv", "k_rope"):  # (L, B, S, R)
        return lead + ("batch", "seq_kv", None)[: nd - 1]
    if name == "state":  # (L, B, H, P, N)
        return lead + ("batch", "ssm_heads", None, None)[: nd - 1]
    if name == "conv":  # (L, B, W-1, C)
        return lead + ("batch", None, "mlp")[: nd - 1]
    if name == "h":  # (L, B, W)
        return lead + ("batch", "mlp")[: nd - 1]
    return lead + ("batch",) + (None,) * (nd - 2)


def abstract_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> ServeState:
    """ServeState of ShapeDtypeStructs with shardings (no allocation)."""
    shapes = jax.eval_shape(lambda: init_serve_state(cfg, batch, max_len))
    caches = _abstract_like(shapes.caches, _axes_like(shapes.caches, cache_axes))
    pos = _sds((batch,), jnp.int32, "stream")

    def odl_axes(path, leaf):
        return ("stream",) + (None,) * (leaf.ndim - 1)

    odl = _abstract_like(shapes.odl, _axes_like(shapes.odl, odl_axes))
    return ServeState(caches=caches, pos=pos, odl=odl)


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()) -> TrainState:
    """TrainState of ShapeDtypeStructs: params TP+FSDP-sharded, moments ZeRO."""
    schema = build_schema(cfg)
    params = layers.abstract_params(schema, dtype=jnp.dtype(tcfg.param_dtype))

    mesh = sharding.mesh_or_none()

    def moment_of(sds):
        """ZeRO-1: moments get 'data' (and 'pod') on a free dim — unless the
        param is already FSDP-sharded over data (then moments match it)."""
        spec = sds.sharding.spec if sds.sharding is not None else None
        if mesh is None or spec is None:
            return jax.ShapeDtypeStruct(sds.shape, jnp.float32)
        from jax.sharding import NamedSharding, PartitionSpec as P

        msh = dict(zip(mesh.axis_names, mesh.devices.shape))
        entries = list(spec) + [None] * (len(sds.shape) - len(spec))
        flat = []
        for e in entries:
            flat.extend(e if isinstance(e, tuple) else (e,))
        if "data" not in flat:
            for axes_try in ((("pod", "data") if "pod" in msh else None), "data"):
                if axes_try is None:
                    continue
                size = (
                    msh["pod"] * msh["data"] if isinstance(axes_try, tuple) else msh["data"]
                )
                placed = False
                for i, e in enumerate(entries):
                    if e is None and sds.shape[i] % size == 0 and sds.shape[i] >= size:
                        entries[i] = axes_try
                        placed = True
                        break
                if placed:
                    break
        return jax.ShapeDtypeStruct(
            sds.shape, jnp.float32, sharding=NamedSharding(mesh, P(*entries))
        )

    m = jax.tree.map(moment_of, params)
    v = jax.tree.map(moment_of, params)
    opt = adam.AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), m=m, v=v)

    ecfg = elm_config(cfg)
    odl = ODLState(
        elm=oselm.OSELMState(
            beta=jax.ShapeDtypeStruct((ecfg.n_hidden, ecfg.n_out), jnp.float32),
            P=jax.ShapeDtypeStruct((ecfg.n_hidden, ecfg.n_hidden), jnp.float32),
            count=jax.ShapeDtypeStruct((), jnp.int32),
        ),
        prune=jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            jax.eval_shape(pruning.init_state),
        ),
    )
    return TrainState(params=params, opt=opt, odl=odl)


# ---------------------------------------------------------------------------
# Dry-run input specs (weak-type-correct, shardable, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape_tuple, dtype, *names):
    ns = sharding.named_sharding(*names, shape=shape_tuple)
    return jax.ShapeDtypeStruct(shape_tuple, dtype, sharding=ns)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        specs = {
            "tokens": _sds((b, s), jnp.int32, "batch", "seq"),
            "labels": _sds((b, s), jnp.int32, "batch", "seq"),
            "odl_labels": _sds((b,), jnp.int32, "batch"),
        }
        if cfg.enc_dec:
            specs["frames"] = _sds((b, s, cfg.d_model), jnp.float32, "batch", "seq", "embed")
        return specs
    if shape.kind == "prefill":
        if cfg.enc_dec:
            return {"frames": _sds((b, s, cfg.d_model), jnp.float32, "batch", "seq", "embed")}
        return {"tokens": _sds((b, s), jnp.int32, "batch", "seq")}
    # decode: one new token against an S-long cache
    return {"token": _sds((b, 1), jnp.int32, "batch", None)}
