"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)           (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over time (log-depth, linear
work — the reason recurrentgemma lowers long_500k); decode carries (B, D)
state in O(1).  The full residual block is Griffin's: conv1d(4) temporal
mixing + RG-LRU inside a gated (GeGLU-style) branch pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.models.layers import Leaf, cast

_C = 8.0


def rglru_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "in_x": Leaf((d, w), ("embed", "mlp")),
        "in_gate": Leaf((d, w), ("embed", "mlp")),
        "conv_w": Leaf((4, w), (None, "mlp"), scale=0.5),
        "conv_b": Leaf((w,), ("mlp",), init="zeros"),
        "w_r": Leaf((w, w), ("mlp", None), scale=0.02),
        "w_i": Leaf((w, w), ("mlp", None), scale=0.02),
        "lam": Leaf((w,), ("mlp",), init="ones"),  # softplus(lam) > 0
        "out": Leaf((w, d), ("mlp", "embed")),
    }


def _conv1d(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * cast(w)[i][None, None, :] for i in range(width)
    )
    return out + cast(b)


def _gates(xw, p):
    r = jax.nn.sigmoid(xw @ cast(p["w_r"]))
    i = jax.nn.sigmoid(xw @ cast(p["w_i"]))
    log_a = (
        -_C
        * jax.nn.softplus(p["lam"].astype(jnp.float32))[None, None, :]
        * r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i.astype(jnp.float32) * xw.astype(jnp.float32)
    )
    return a, gated


def rglru_block(x: jnp.ndarray, p: dict, cfg: ModelConfig, return_cache: bool = False):
    """x: (B, S, d) -> (B, S, d).  Associative scan over time."""
    gate = jax.nn.gelu(x @ cast(p["in_gate"]), approximate=True)
    xw_raw = x @ cast(p["in_x"])
    xw = _conv1d(xw_raw, p["conv_w"], p["conv_b"])
    xw = sharding.constrain(xw, "batch", "seq", "mlp")

    a, gated = _gates(xw, p)

    def assoc(l, r):
        al, hl = l
        ar, hr = r
        return al * ar, hr + ar * hl

    _, h = jax.lax.associative_scan(assoc, (a, gated), axis=1)
    out = (h.astype(x.dtype) * gate) @ cast(p["out"])
    if return_cache:
        cache = {"h": h[:, -1], "conv": xw_raw[:, -3:].astype(jnp.float32)}
        return out, cache
    return out


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, 3, w), dtype),
    }


def rglru_decode(x: jnp.ndarray, p: dict, cfg: ModelConfig, cache: dict):
    """x: (B, 1, d) -> (y, cache')."""
    gate = jax.nn.gelu(x @ cast(p["in_gate"]), approximate=True)
    xw_new = x @ cast(p["in_x"])  # (B,1,W)
    win = jnp.concatenate([cache["conv"], xw_new.astype(cache["conv"].dtype)], 1)
    w = cast(p["conv_w"])
    xw = (jnp.einsum("bwc,wc->bc", win.astype(w.dtype), w) + cast(p["conv_b"]))[:, None, :]

    a, gated = _gates(xw, p)
    h = a[:, 0] * cache["h"] + gated[:, 0]  # (B, W)
    y = h[:, None, :].astype(x.dtype) * gate
    return y @ cast(p["out"]), {"h": h, "conv": win[:, 1:]}
