"""The paper's top-level ODL loop (Algorithm 1) — scalar S=1 shim.

The actual state machine lives in ``repro/engine`` (the batched fleet
engine); this module keeps the original single-stream API for the
paper-repro tests and small examples by adding a leading stream axis of 1,
delegating to ``engine.fleet_step`` / ``engine.run_fleet``, and stripping
the axis again.  Semantics are bit-identical per stream; new code that
handles more than one stream should use ``repro.engine`` directly (this
scalar API is deprecated for fleet work — see ROADMAP "Open items").

``ODLCoreConfig`` / ``ODLCoreState`` / ``StepOutput`` are defined here (the
lowest layer) and re-exported by the engine as ``EngineConfig`` /
``EngineState`` / ``FleetStepOutput``: the same pytrees serve both the
scalar and the fleet view, so existing checkpoints and configs keep working.
The engine import is deferred to call time to keep ``repro.core`` importable
on its own.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import drift as drift_mod
from repro.core import labels as labels_mod
from repro.core import oselm, pruning


@dataclasses.dataclass(frozen=True)
class ODLCoreConfig:
    """ODL configuration (identical semantics for S = 1 and a fleet)."""

    elm: oselm.OSELMConfig = oselm.OSELMConfig()
    prune: pruning.PruneConfig = None  # type: ignore[assignment]
    drift: drift_mod.DriftConfig = drift_mod.DriftConfig()

    def __post_init__(self):
        if self.prune is None:
            object.__setattr__(
                self, "prune", pruning.PruneConfig.for_hidden(self.elm.n_hidden)
            )


class ODLCoreState(NamedTuple):
    """elm/prune/drift/meter; scalar leaves here, leading-S leaves in the
    fleet engine (which aliases this class as ``EngineState``)."""

    elm: oselm.OSELMState
    prune: pruning.PruneState
    drift: drift_mod.DriftState
    meter: labels_mod.CommMeter


class StepOutput(NamedTuple):
    pred: jnp.ndarray  # int32 local predicted class c
    outputs: jnp.ndarray  # (.., m) raw outputs O
    queried: jnp.ndarray  # bool
    trained: jnp.ndarray  # bool
    theta: jnp.ndarray  # f32 current threshold
    confidence: jnp.ndarray  # f32 p1 - p2
    mode_training: jnp.ndarray  # bool


def _engine():
    from repro.engine import fleet  # deferred: engine sits above core

    return fleet


def init_state(cfg: ODLCoreConfig) -> ODLCoreState:
    return ODLCoreState(
        elm=oselm.init_state(cfg.elm),
        prune=pruning.init_state(),
        drift=drift_mod.init_state(),
        meter=labels_mod.CommMeter.zero(),
    )


def _expand(tree):
    """Scalar state/arrays -> fleet of one stream (leading axis 1)."""
    return jax.tree.map(lambda a: jnp.asarray(a)[None], tree)


def _squeeze(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _scalar_step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
    mode: str,
    teacher_available: Optional[jnp.ndarray],
    drift_active: Optional[jnp.ndarray],
) -> tuple[ODLCoreState, StepOutput]:
    t = teacher(idx, x)  # always traced (static shapes), used only if queried
    fstate, fout = _engine().fleet_step(
        _expand(state),
        x[None],
        jnp.asarray(t, jnp.int32)[None],
        cfg,
        mode=mode,
        teacher_available=None if teacher_available is None else _expand(teacher_available),
        drift_active=None if drift_active is None else _expand(drift_active),
    )
    return _squeeze(fstate), _squeeze(fout)


def train_phase_step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
    drift_active: Optional[jnp.ndarray] = None,
    teacher_available: Optional[jnp.ndarray] = None,
) -> tuple[ODLCoreState, StepOutput]:
    """One sample of the paper's retraining phase (pruning always armed).

    ``drift_active`` models pruning condition 2 (default: not detected).
    ``teacher_available`` models the paper's retry-or-skip fault policy: when
    False the query is suppressed *and* no training happens this step.
    """
    return _scalar_step(
        state, x, idx, teacher, cfg, "train_phase", teacher_available, drift_active
    )


def step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Full Algorithm 1: drift detector switches predicting <-> training."""
    return _scalar_step(state, x, idx, teacher, cfg, "algo1", None, None)


def run_training_phase(
    state: ODLCoreState,
    xs: jnp.ndarray,  # (T, n_in)
    teacher_labels: jnp.ndarray,  # (T,) int32
    cfg: ODLCoreConfig,
    teacher_available: Optional[jnp.ndarray] = None,  # (T,) bool
) -> tuple[ODLCoreState, StepOutput]:
    """Scan the retraining phase over a stream (paper §3 step 3) — a one-
    stream ``engine.run_fleet``.

    Condition 1 is lifetime trained count — initial training (step 1) already
    satisfies max(N, 288), so pruning is armed from the first stream sample,
    exactly as required to reproduce Fig. 3/4 (see should_query docstring).
    """
    state = state._replace(prune=pruning.reset_phase(state.prune))
    avail = None if teacher_available is None else teacher_available[:, None]
    fstate, fouts = _engine().run_fleet(
        _expand(state),
        xs[:, None],
        jnp.asarray(teacher_labels, jnp.int32)[:, None],
        cfg,
        mode="train_phase",
        teacher_available=avail,
    )
    return _squeeze(fstate), jax.tree.map(lambda a: a[:, 0], fouts)


def run_stream(
    state: ODLCoreState,
    xs: jnp.ndarray,
    teacher_labels: jnp.ndarray,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Scan the full Algorithm-1 ``step`` over a stream (one-stream fleet)."""
    fstate, fouts = _engine().run_fleet(
        _expand(state),
        xs[:, None],
        jnp.asarray(teacher_labels, jnp.int32)[:, None],
        cfg,
        mode="algo1",
    )
    return _squeeze(fstate), jax.tree.map(lambda a: a[:, 0], fouts)


def accuracy(
    state: ODLCoreState, xs: jnp.ndarray, ys: jnp.ndarray, cfg: ODLCoreConfig
) -> jnp.ndarray:
    """Batch test accuracy of the current head."""
    preds, _ = oselm.predict(state.elm, xs, cfg.elm)
    return jnp.mean((preds == ys).astype(jnp.float32))
