"""DEPRECATED alias — the scalar ODL API lives in ``repro.engine.scalar``.

This module completes the ROADMAP deprecation path: PR 1 turned it into an
S=1 shim over the fleet engine; this PR folds the implementation into
``repro/engine`` and leaves this documented alias so the paper-repro tests
(and any external notebooks pinned to the original import path) keep
working.  Nothing else in this repository may import it — enforced by
``tests/test_stream.py::test_scalar_api_confined_to_engine``.

Use instead:
  * fleets / serving:  ``repro.engine`` — ``init_fleet`` / ``run_fleet`` /
    ``gate`` + ``apply_labels`` / ``stream.run`` (async teacher runtime)
  * single stream:     ``repro.engine.scalar`` — this exact API, same names

``ODLCoreConfig`` / ``ODLCoreState`` / ``StepOutput`` are the engine's own
``EngineConfig`` / ``EngineState`` / ``FleetStepOutput`` classes (see
``engine/types.py``), so states built through either name are identical
pytrees: checkpoints and configs round-trip across the rename.
"""

from repro.engine.scalar import (  # noqa: F401
    ODLCoreConfig,
    ODLCoreState,
    StepOutput,
    accuracy,
    init_state,
    run_stream,
    run_training_phase,
    step,
    train_phase_step,
)
