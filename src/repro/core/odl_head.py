"""The paper's top-level ODL loop (Algorithm 1) as composable JAX.

``ODLCore`` bundles OS-ELM + P1P2 auto-pruning + drift detection + comm
metering into one pytree state with a pure step function, usable three ways:

  * ``step``            — full Algorithm 1 (drift detector switches modes);
  * ``train_phase_step``— the paper's evaluation protocol (§3: an explicit
                          retraining phase over a sample stream);
  * attached to a backbone (``models/model.py``) where backbone features are
    the ``x`` inputs — the fleet-scale deployment.

All steps are ``lax.scan``-able and vmap-able over streams.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import drift as drift_mod
from repro.core import labels as labels_mod
from repro.core import oselm, pruning


@dataclasses.dataclass(frozen=True)
class ODLCoreConfig:
    elm: oselm.OSELMConfig = oselm.OSELMConfig()
    prune: pruning.PruneConfig = None  # type: ignore[assignment]
    drift: drift_mod.DriftConfig = drift_mod.DriftConfig()

    def __post_init__(self):
        if self.prune is None:
            object.__setattr__(
                self, "prune", pruning.PruneConfig.for_hidden(self.elm.n_hidden)
            )


class ODLCoreState(NamedTuple):
    elm: oselm.OSELMState
    prune: pruning.PruneState
    drift: drift_mod.DriftState
    meter: labels_mod.CommMeter


class StepOutput(NamedTuple):
    pred: jnp.ndarray  # () int32 local predicted class c
    outputs: jnp.ndarray  # (m,) raw outputs O
    queried: jnp.ndarray  # () bool
    trained: jnp.ndarray  # () bool
    theta: jnp.ndarray  # () f32 current threshold
    confidence: jnp.ndarray  # () f32 p1 - p2
    mode_training: jnp.ndarray  # () bool


def init_state(cfg: ODLCoreConfig) -> ODLCoreState:
    return ODLCoreState(
        elm=oselm.init_state(cfg.elm),
        prune=pruning.init_state(),
        drift=drift_mod.init_state(),
        meter=labels_mod.CommMeter.zero(),
    )


def _train_if(state: ODLCoreState, x, y, do_train, cfg: ODLCoreConfig) -> oselm.OSELMState:
    """Masked rank-1 RLS update: shapes stay static, a skipped step is exact
    identity on (P, beta, count)."""
    mask = do_train.astype(jnp.float32)[None]
    return oselm.sequential_update(state.elm, x[None], y[None], cfg.elm, mask=mask)


def train_phase_step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
    drift_active: Optional[jnp.ndarray] = None,
    teacher_available: Optional[jnp.ndarray] = None,
) -> tuple[ODLCoreState, StepOutput]:
    """One sample of the paper's retraining phase (pruning always armed).

    ``drift_active`` models pruning condition 2 (default: not detected).
    ``teacher_available`` models the paper's retry-or-skip fault policy: when
    False the query is suppressed *and* no training happens this step.
    """
    if drift_active is None:
        drift_active = jnp.zeros((), jnp.bool_)
    if teacher_available is None:
        teacher_available = jnp.ones((), jnp.bool_)

    c, o = oselm.predict(state.elm, x, cfg.elm)
    conf = pruning.confidence(o)
    want_query = pruning.should_query(
        state.prune, o, state.elm.count, drift_active, cfg.prune
    )
    queried = jnp.logical_and(want_query, teacher_available)

    t, y, meter = labels_mod.acquire(
        teacher, idx, x, queried, cfg.elm.n_out, state.meter
    )
    agree = c == t
    new_elm = _train_if(state, x, y, queried, cfg)
    # Auto-theta update only observes steps where pruning was in play: a
    # teacher outage is neither success nor failure.
    new_prune = jax.tree.map(
        lambda new, old: jnp.where(teacher_available, new, old),
        pruning.update(state.prune, queried, agree, conf, cfg.prune),
        state.prune,
    )
    new_state = ODLCoreState(elm=new_elm, prune=new_prune, drift=state.drift, meter=meter)
    out = StepOutput(
        pred=c,
        outputs=o,
        queried=queried,
        trained=queried,
        theta=pruning.theta_of(state.prune, cfg.prune),
        confidence=conf,
        mode_training=jnp.ones((), jnp.bool_),
    )
    return new_state, out


def step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Full Algorithm 1: drift detector switches predicting <-> training."""
    c, o = oselm.predict(state.elm, x, cfg.elm)
    conf = pruning.confidence(o)

    # IsDrift / IsTrainDone: one detector with hysteresis (drift.py).
    s = drift_mod.score(x, o, cfg.drift)
    new_drift = drift_mod.update(state.drift, s, cfg.drift)
    training = new_drift.active

    # Rising edge of `active` == IsDrift fired: a new phase begins (the
    # per-phase counter is diagnostic only; condition 1 is lifetime count).
    entering = jnp.logical_and(training, jnp.logical_not(state.drift.active))
    prune_st = jax.tree.map(
        lambda r, o_: jnp.where(entering, r, o_),
        pruning.reset_phase(state.prune),
        state.prune,
    )

    # Condition 2: during an active drift phase the early samples must query
    # until the detector's confidence recovers; we pass the detector state
    # straight through (drift_active = still in training mode).
    want_query = pruning.should_query(
        prune_st, o, state.elm.count, jnp.zeros((), jnp.bool_), cfg.prune
    )
    queried = jnp.logical_and(training, want_query)

    t, y, meter = labels_mod.acquire(
        teacher, idx, x, queried, cfg.elm.n_out, state.meter
    )
    agree = c == t
    new_elm = _train_if(state, x, y, queried, cfg)
    new_prune = jax.tree.map(
        lambda new, old: jnp.where(training, new, old),
        pruning.update(prune_st, queried, agree, conf, cfg.prune),
        prune_st,
    )
    new_state = ODLCoreState(elm=new_elm, prune=new_prune, drift=new_drift, meter=meter)
    out = StepOutput(
        pred=c,
        outputs=o,
        queried=queried,
        trained=queried,
        theta=pruning.theta_of(prune_st, cfg.prune),
        confidence=conf,
        mode_training=training,
    )
    return new_state, out


def run_training_phase(
    state: ODLCoreState,
    xs: jnp.ndarray,  # (T, n_in)
    teacher_labels: jnp.ndarray,  # (T,) int32
    cfg: ODLCoreConfig,
    teacher_available: Optional[jnp.ndarray] = None,  # (T,) bool
) -> tuple[ODLCoreState, StepOutput]:
    """Scan ``train_phase_step`` over a stream (paper §3 step 3).

    Condition 1 is lifetime trained count — initial training (step 1) already
    satisfies max(N, 288), so pruning is armed from the first stream sample,
    exactly as required to reproduce Fig. 3/4 (see should_query docstring).
    """
    state = state._replace(prune=pruning.reset_phase(state.prune))
    teacher = labels_mod.ArrayTeacher(labels=teacher_labels)
    avail = (
        jnp.ones(xs.shape[0], jnp.bool_) if teacher_available is None else teacher_available
    )

    def body(st, inp):
        i, x, av = inp
        return train_phase_step(st, x, i, teacher, cfg, teacher_available=av)

    idxs = jnp.arange(xs.shape[0], dtype=jnp.int32)
    return jax.lax.scan(body, state, (idxs, xs, avail))


def run_stream(
    state: ODLCoreState,
    xs: jnp.ndarray,
    teacher_labels: jnp.ndarray,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Scan the full Algorithm-1 ``step`` over a stream."""
    teacher = labels_mod.ArrayTeacher(labels=teacher_labels)

    def body(st, inp):
        i, x = inp
        return step(st, x, i, teacher, cfg)

    idxs = jnp.arange(xs.shape[0], dtype=jnp.int32)
    return jax.lax.scan(body, state, (idxs, xs))


def accuracy(
    state: ODLCoreState, xs: jnp.ndarray, ys: jnp.ndarray, cfg: ODLCoreConfig
) -> jnp.ndarray:
    """Batch test accuracy of the current head."""
    preds, _ = oselm.predict(state.elm, xs, cfg.elm)
    return jnp.mean((preds == ys).astype(jnp.float32))
