"""Label acquisition via a teacher device (paper §2.2, Fig. 2(c)).

The edge device ships ``x_i`` to the teacher and receives the teacher's
predicted class ``t_i``, converted to a one-hot ``y_i``.  Communication is
metered exactly as the paper's BLE accounting: one query uploads the feature
vector (n * 4 bytes, 32-bit values) and downloads one label byte.

In the paper's evaluation the dataset's ground-truth labels play the role of
the teacher's predictions; ``ArrayTeacher`` reproduces that.  ``ModelTeacher``
wraps any jit-compatible predictor (e.g. a large backbone on the pod) — the
fleet-scale deployment described in DESIGN.md §3.

Fault policy (paper: "queries will be retried later or skipped"): a teacher
call is issued with a deadline; `runtime/fault.py` wraps teachers so a missed
deadline yields ``available=False`` and the caller skips the training step —
the straggler-mitigation pattern at pod scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

BYTES_PER_FEATURE = 4  # 32-bit fixed-point features (paper §3.3)
BYTES_PER_LABEL = 1


class CommMeter(NamedTuple):
    """Bytes moved between edge and teacher (a pytree; vmap for fleets)."""

    up_bytes: jnp.ndarray  # () int64-ish f32 accumulator
    down_bytes: jnp.ndarray

    @staticmethod
    def zero() -> "CommMeter":
        return CommMeter(jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    def charge_query(self, n_features: int, queried: jnp.ndarray) -> "CommMeter":
        q = queried.astype(jnp.float32)
        return CommMeter(
            up_bytes=self.up_bytes + q * (n_features * BYTES_PER_FEATURE),
            down_bytes=self.down_bytes + q * BYTES_PER_LABEL,
        )

    @property
    def total(self) -> jnp.ndarray:
        return self.up_bytes + self.down_bytes


def one_hot(t: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    return jax.nn.one_hot(t, n_classes, dtype=jnp.float32)


@dataclasses.dataclass(frozen=True)
class ArrayTeacher:
    """Teacher whose answers are a precomputed label array (paper's eval)."""

    labels: jnp.ndarray  # (T,) int32

    def __call__(self, idx: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        del x
        return self.labels[idx]


@dataclasses.dataclass(frozen=True)
class ModelTeacher:
    """Teacher backed by a predictor fn(x) -> class (e.g. backbone ensemble)."""

    predict_fn: Callable[[jnp.ndarray], jnp.ndarray]

    def __call__(self, idx: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        del idx
        return self.predict_fn(x)


def acquire(
    teacher: Callable,
    idx: jnp.ndarray,
    x: jnp.ndarray,
    queried: jnp.ndarray,
    n_classes: int,
    meter: CommMeter,
) -> tuple[jnp.ndarray, jnp.ndarray, CommMeter]:
    """Fig. 2(c): returns (t, y_onehot, meter').

    The teacher is always *traced* (shapes must be static under jit) but the
    result is used — and communication charged — only when ``queried``.
    """
    t = teacher(idx, x)
    y = one_hot(t, n_classes)
    meter = meter.charge_query(x.shape[-1], queried)
    return t, y, meter
