"""Auto data pruning with the P1P2 confidence metric (paper §2.2).

A teacher query (and the subsequent sequential-train step) is SKIPPED iff all
three hold:
  1. at least ``min_trained`` samples have been trained (paper: max(N, 288)),
  2. drift is not currently detected,
  3. confidence p1 - p2 > theta.

``theta`` is auto-tuned on a fixed ladder (paper §3.2: {1, .64, .32, .16, .08}):
  * start at the top (theta = 1 ⇒ never skip ⇒ pure supervised ODL);
  * after X consecutive "successes" — (p1-p2 > theta), or the query happened
    and the local prediction agreed with the teacher (c == t) — step DOWN;
  * whenever a query reveals disagreement (c != t), step UP (and reset).

Everything is a jit-compatible pure state transition so it can be vmapped
over thousands of streams (fleet mode) and fused into serve_step.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Paper ladder, ordered from most conservative (never prune) downward.
DEFAULT_LADDER = (1.0, 0.64, 0.32, 0.16, 0.08)
DEFAULT_X = 10  # consecutive successes required to relax theta


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    ladder: tuple = DEFAULT_LADDER
    x_consec: int = DEFAULT_X
    min_trained: int = 288  # paper: max(N, 288); resolved by caller
    enabled: bool = True

    @staticmethod
    def for_hidden(n_hidden: int, **kw) -> "PruneConfig":
        return PruneConfig(min_trained=max(n_hidden, 288), **kw)


class PruneState(NamedTuple):
    """Auto-theta controller state (per stream; a pytree)."""

    level: jnp.ndarray  # () int32 — index into the ladder
    streak: jnp.ndarray  # () int32 — consecutive successes
    queries: jnp.ndarray  # () int32 — total teacher queries issued
    skips: jnp.ndarray  # () int32 — total queries pruned
    phase_trained: jnp.ndarray  # () int32 — samples trained this phase (cond. 1)


def init_state() -> PruneState:
    # One fresh buffer per field: sharing a single zeros() array across
    # fields breaks donation (same buffer donated twice).
    def z():
        return jnp.zeros((), jnp.int32)

    return PruneState(level=z(), streak=z(), queries=z(), skips=z(), phase_trained=z())


def reset_phase(state: PruneState) -> PruneState:
    """New training phase (drift detected): re-arm condition 1.

    Shape-polymorphic: works on scalar and fleet ((S,)-leaf) states alike.
    """
    return state._replace(phase_trained=jnp.zeros_like(state.phase_trained))


def theta_of(state: PruneState, cfg: PruneConfig) -> jnp.ndarray:
    ladder = jnp.asarray(cfg.ladder, jnp.float32)
    return ladder[jnp.clip(state.level, 0, len(cfg.ladder) - 1)]


def confidence(outputs: jnp.ndarray) -> jnp.ndarray:
    """P1P2 metric: difference of the top-2 outputs along the last axis.

    OS-ELM regresses one-hot targets, so outputs approximate class posteriors;
    we clamp to [0, 1] so theta = 1 means "never prune" exactly as in the
    paper (probability differences cannot exceed 1).
    """
    top2 = jax.lax.top_k(outputs, 2)[0]
    return jnp.clip(top2[..., 0] - top2[..., 1], 0.0, 1.0)


def should_query(
    state: PruneState,
    outputs: jnp.ndarray,
    trained_count: jnp.ndarray,
    drift_active: jnp.ndarray,
    cfg: PruneConfig,
) -> jnp.ndarray:
    """True iff the teacher must be queried for this sample (bool scalar).

    Condition 1 compares the *lifetime* trained-sample count (OS-ELM's
    ``count``, which includes initial training) against max(N, 288).  The
    paper's Fig. 4 theta=0.08 point implies a communication volume (~26 %)
    below the would-be 28.6 % floor of a per-phase warm-up, so the counter
    cannot reset when the retraining phase starts; drifts are instead handled
    by condition 2 (``drift_active`` forces querying).
    """
    if not cfg.enabled:
        return jnp.asarray(True)
    conf = confidence(outputs)
    high_conf = conf > theta_of(state, cfg)
    warm = trained_count >= cfg.min_trained
    prune = warm & jnp.logical_not(drift_active) & high_conf
    return jnp.logical_not(prune)


def update(
    state: PruneState,
    queried: jnp.ndarray,  # bool — did we query the teacher this step?
    agree: jnp.ndarray,  # bool — c == t (only meaningful when queried)
    conf: jnp.ndarray,  # f32 — p1 - p2 of this sample
    cfg: PruneConfig,
    theta: jnp.ndarray = None,  # threshold the decision was made against
) -> PruneState:
    """Auto-theta transition (paper §2.2, verbatim):

      * success  = (p1-p2 > theta)  OR  (c == t when querying with p1-p2 <= theta)
      * mismatch = (c != t when querying with p1-p2 <= theta)

    A query forced for other reasons (warm-up, drift) with high confidence
    still counts as a success via the first clause; a *forced* query that
    disagrees only raises theta when the sample was genuinely low-confidence.

    ``theta`` defaults to the current ladder value; a caller applying a
    *deferred* teacher answer (the streaming runtime) passes the theta that
    was in force when the query was issued, so a label delayed past a
    ladder step is still judged against the decision it belongs to.
    """
    n_levels = len(cfg.ladder)
    if theta is None:
        theta = theta_of(state, cfg)
    high = conf > theta
    low_query = jnp.logical_and(queried, jnp.logical_not(high))
    success = jnp.logical_or(high, jnp.logical_and(low_query, agree))
    mismatch = jnp.logical_and(low_query, jnp.logical_not(agree))

    streak = jnp.where(success, state.streak + 1, 0)
    hit_x = streak >= cfg.x_consec
    level = state.level
    level = jnp.where(hit_x, jnp.minimum(level + 1, n_levels - 1), level)
    level = jnp.where(mismatch, jnp.maximum(level - 1, 0), level)
    streak = jnp.where(hit_x | mismatch, 0, streak)

    return PruneState(
        level=level,
        streak=streak,
        queries=state.queries + queried.astype(jnp.int32),
        skips=state.skips + (1 - queried.astype(jnp.int32)),
        phase_trained=state.phase_trained + queried.astype(jnp.int32),
    )


def comm_volume_fraction(state: PruneState) -> jnp.ndarray:
    """Queries / (queries + skips) — Fig. 3's communication-volume metric."""
    total = state.queries + state.skips
    return jnp.where(total > 0, state.queries / jnp.maximum(total, 1), 1.0)


def scan_update(
    state: PruneState,
    queried: jnp.ndarray,  # (k,) bool
    agree: jnp.ndarray,  # (k,) bool
    conf: jnp.ndarray,  # (k,) f32
    cfg: PruneConfig,
) -> PruneState:
    """Exact sequential controller semantics over a batch of k samples
    (used by train_step, which gates a whole microbatch against the
    batch-start theta, then replays the controller sample-by-sample)."""

    def body(st, inp):
        q, a, c = inp
        return update(st, q, a, c, cfg), None

    st, _ = jax.lax.scan(body, state, (queried, agree, conf))
    return st


# ---------------------------------------------------------------------------
# Fleet mode
# ---------------------------------------------------------------------------


def init_fleet(n_streams: int) -> PruneState:
    one = init_state()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_streams,) + a.shape), one)


def fleet_should_query(state, outputs, trained_count, drift_active, cfg):
    return jax.vmap(lambda s, o, tc, da: should_query(s, o, tc, da, cfg))(
        state, outputs, trained_count, drift_active
    )


def fleet_update(state, queried, agree, conf, cfg):
    return jax.vmap(lambda s, q, a, c: update(s, q, a, c, cfg))(
        state, queried, agree, conf
    )
