"""Xorshift16 pseudo-random weight generation (paper §2.3, ODLHash).

The paper replaces the stored random input projection ``alpha`` of OS-ELM with
a 16-bit Xorshift function with shift coefficients (7, 9, 8), evaluated by a
sequential state machine inside the 45nm core.  Two semantics live here:

* ``xorshift16_stream`` — the paper's *sequential* generator (state machine
  semantics).  Used by the memory/cycle models and as a CPU-side oracle.
* ``alpha_hash`` — the TPU-native *counter-based* variant: each matrix entry
  ``alpha[k, j]`` is derived independently from ``seed ^ (k*N + j + 1)`` by
  applying the same (7, 9, 8) Xorshift step ``rounds`` times.  This gives the
  random-access addressing a systolic MXU needs (DESIGN.md §2) while keeping
  the paper's arithmetic (16-bit xor/shift only).

Both map uint16 lattice points to floats in [-1, 1) via ``u16_to_unit``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Paper coefficients: x ^= x << 7; x ^= x >> 9; x ^= x << 8  (mod 2^16).
SHIFT_A, SHIFT_B, SHIFT_C = 7, 9, 8
_MASK16 = jnp.uint16(0xFFFF)
DEFAULT_ROUNDS = 3
DEFAULT_SEED = 0x2D2A  # arbitrary nonzero 16-bit constant


def xorshift16_step(x: jnp.ndarray) -> jnp.ndarray:
    """One (7, 9, 8) Xorshift16 step.  Input/output dtype uint16."""
    x = x.astype(jnp.uint16)
    x = x ^ (x << SHIFT_A)
    x = x ^ (x >> SHIFT_B)
    x = x ^ (x << SHIFT_C)
    return x


def xorshift16_rounds(x: jnp.ndarray, rounds: int = DEFAULT_ROUNDS) -> jnp.ndarray:
    """Apply ``rounds`` Xorshift16 steps (counter-based hash round function)."""
    for _ in range(rounds):
        x = xorshift16_step(x)
    return x


def u16_to_unit(x: jnp.ndarray) -> jnp.ndarray:
    """Map uint16 -> float32 in [-1, 1): x/32768 - 1."""
    return x.astype(jnp.float32) * jnp.float32(1.0 / 32768.0) - jnp.float32(1.0)


def xorshift16_stream(seed: int, length: int) -> np.ndarray:
    """The paper's sequential Xorshift16 state machine (numpy, host-side).

    Zero state is a fixed point of xorshift; seeds are forced nonzero.
    Returns ``length`` uint16 values (the state after each step).
    """
    s = np.uint16(seed if (seed & 0xFFFF) != 0 else 1)
    out = np.empty(length, dtype=np.uint16)
    for i in range(length):
        s = np.uint16(s ^ np.uint16((int(s) << SHIFT_A) & 0xFFFF))
        s = np.uint16(s ^ np.uint16(int(s) >> SHIFT_B))
        s = np.uint16(s ^ np.uint16((int(s) << SHIFT_C) & 0xFFFF))
        out[i] = s
    return out


# Odd 16-bit constants interleaved between xorshift rounds.  Xorshift alone
# is LINEAR over GF(2): xorshift(a) ^ xorshift(b) = xorshift(a ^ b), so
# sequential counters produce structurally correlated outputs no matter how
# many rounds (measured adjacent-column corr ~ -0.3 on the raw variant —
# enough to cost the ELM ~7 accuracy points vs stored-random weights).
# One multiply per round is non-linear in GF(2) and removes the correlation;
# a multiplier is cheap for the MXU-class adaptation target (DESIGN.md §2).
MIX_CONSTANTS = (0x2D2B, 0x9E35, 0xC2B3)


def mix16(x: jnp.ndarray, rounds: int = DEFAULT_ROUNDS) -> jnp.ndarray:
    """Counter hash: (xorshift16 round; odd-constant multiply) x rounds."""
    x = x.astype(jnp.uint16)
    for r in range(rounds):
        x = xorshift16_step(x)
        x = x * jnp.uint16(MIX_CONSTANTS[r % len(MIX_CONSTANTS)])
    return x


def alpha_hash(
    seed: int,
    n_in: int,
    n_hidden: int,
    rounds: int = DEFAULT_ROUNDS,
    row_offset: int = 0,
    col_offset: int = 0,
) -> jnp.ndarray:
    """Counter-based ODLHash weights: alpha[k, j] for a tile of the matrix.

    ``alpha[k, j] = u16_to_unit(mix16(seed ^ (gk*N_total + gj + 1)))``
    where (gk, gj) are *global* indices — offsets let a Pallas kernel generate
    any tile independently with identical values (tested bit-exact vs this).

    Note ``n_hidden`` here is the *global* number of columns N (it fixes the
    linear counter layout); pass ``row_offset/col_offset`` + a smaller shape
    via broadcasting by slicing the returned tile externally if needed.
    """
    rows = jnp.arange(n_in, dtype=jnp.uint32) + jnp.uint32(row_offset)
    cols = jnp.arange(n_hidden, dtype=jnp.uint32) + jnp.uint32(col_offset)
    # Counter = gk * N + gj + 1 (mod 2^16), xor'd into the seed.
    ctr = rows[:, None] * jnp.uint32(n_hidden) + cols[None, :] + jnp.uint32(1)
    x = (jnp.uint32(seed) ^ ctr).astype(jnp.uint16)
    # Avoid the zero fixed point.
    x = jnp.where(x == 0, jnp.uint16(0x9E37), x)
    x = mix16(x, rounds)
    return u16_to_unit(x)


def alpha_dense(seed: int, n_in: int, n_hidden: int, scale: float = 1.0) -> jnp.ndarray:
    """ODLBase weights: stored dense random alpha ~ U[-1, 1) from a jax PRNG.

    The paper stores 32-bit random numbers; the exact distribution is not
    specified, so we use uniform [-1, 1) to match ODLHash's range.
    """
    import jax

    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(
        key, (n_in, n_hidden), dtype=jnp.float32, minval=-1.0, maxval=1.0
    ) * jnp.float32(scale)


def alpha_for_variant(
    variant: str, seed: int, n_in: int, n_hidden: int
) -> jnp.ndarray | None:
    """Materialized alpha for 'base', or None for 'hash' (generated on the fly)."""
    if variant == "base":
        return alpha_dense(seed, n_in, n_hidden)
    if variant == "hash":
        return None
    raise ValueError(f"unknown ODL variant: {variant!r}")
