"""Analytic memory / parameter models of the ODL core (paper Tables 1 & 2).

Reverse-engineered from the published tables (verified exact to 0.01 kB for
every entry, see tests/test_memory_model.py):

  NoODL   = 4 (nN + Nm + n)            bytes   (alpha, beta, input buffer)
  ODLBase = 4 (nN + Nm + n + 2 N^2)    bytes   (+ P and its update temporary)
  ODLHash = 4 (Nm + n + 2 N^2)         bytes   (alpha replaced by 16-bit PRNG)

Table 2's "# of parameters" counts the ODL state that must persist across
updates, P (N^2) + beta (Nm), double-buffered: params = 2 (N^2 + Nm)
(ODLHash N=128 -> 34,304 ~ "34k"; N=256 -> 134,144 ~ "133k").
"""

from __future__ import annotations

import dataclasses

BYTES_PER_WORD = 4  # 32-bit fixed point (paper §3.3)


@dataclasses.dataclass(frozen=True)
class CoreShape:
    n: int = 561  # input nodes
    N: int = 128  # hidden nodes
    m: int = 6  # output nodes


def noodl_bytes(s: CoreShape) -> int:
    """Inference-only MLP of the same shape (alpha + beta + input buffer)."""
    return BYTES_PER_WORD * (s.n * s.N + s.N * s.m + s.n)


def odlbase_bytes(s: CoreShape) -> int:
    """ODLBase: NoODL + P (N^2) + P-update temporary (N^2)."""
    return noodl_bytes(s) + BYTES_PER_WORD * 2 * s.N * s.N


def odlhash_bytes(s: CoreShape) -> int:
    """ODLHash: alpha (nN words) replaced by a 16-bit Xorshift seed (~0 B)."""
    return odlbase_bytes(s) - BYTES_PER_WORD * s.n * s.N


def memory_kb(variant: str, s: CoreShape) -> float:
    fn = {"noodl": noodl_bytes, "base": odlbase_bytes, "hash": odlhash_bytes}[variant]
    return fn(s) / 1000.0  # paper uses kB = 1000 B


def odl_param_count(s: CoreShape) -> int:
    """Table 2 parameter count: double-buffered persistent ODL state."""
    return 2 * (s.N * s.N + s.N * s.m)


def table1(n: int = 561, m: int = 6, hidden=(32, 64, 128, 256, 512)):
    """Reproduce paper Table 1: memory size [kB] per variant per N."""
    rows = {}
    for variant in ("noodl", "base", "hash"):
        rows[variant] = [memory_kb(variant, CoreShape(n, N, m)) for N in hidden]
    return {"hidden": list(hidden), **rows}


# Paper Table 1 ground truth for verification [kB].
PAPER_TABLE1 = {
    "hidden": [32, 64, 128, 256, 512],
    "noodl": [74.82, 147.40, 292.55, 582.85, 1163.46],
    "base": [83.01, 180.16, 423.62, 1107.14, 3260.61],
    "hash": [11.20, 36.55, 136.39, 532.68, 2111.68],
}

# Paper Table 2 parameter counts.
PAPER_TABLE2 = {128: 34_000, 256: 133_000}  # reported as "34k" / "133k"
