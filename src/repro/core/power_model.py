"""Timing / power model of the 45nm ODL core + BLE link (paper Table 4, Fig 4).

We cannot re-run post-layout simulation in this container, so the model is
built from the paper's *published operating points* and calibrated once:

Cycle model (10 MHz core, Table 4):
  * prediction  = CPM_PROJ * (nN + Nm) cycles            (H + O matvecs)
  * seq. train  = prediction-H part + CPM_RLS * rls_ops  (rank-1 Woodbury)
    with rls_ops = 3N^2 + N^2 m + 2Nm + N
  CPM_PROJ and CPM_RLS are calibrated from the two published times at
  (n,N,m) = (561,128,6): 36.40 ms and 171.28 ms -> CPM_PROJ ~ 5.02,
  CPM_RLS ~ 9.07 cycles/op.  The model then *predicts* times for other shapes.

Energy model (Fig. 4):
  E(q, T) = E_pred + q (E_train + E_comm) + P_sleep (T - t_pred - q(t_train + t_comm))
  per event of period T, where q = communication volume (fraction of events
  that query the teacher; Fig. 3's line).  E_comm is the effective BLE energy
  per query (nRF52840, 1 Mbps, 0 dBm, 3.0 V, 561 features x 4 B): raw payload
  energy is ~0.3 mJ, but the Nordic online tool's connection-event overhead
  dominates; we calibrate E_comm to the paper's Auto @ 1 event/s reduction
  (49.4 %) -> E_comm ~ 10.69 mJ/query, then *validate* against the untouched
  1/5 s and 1/10 s cases: model gives 34.6 % and 25.2 % vs paper's 34.7 % and
  25.2 % (tests/test_power_model.py).
"""

from __future__ import annotations

import dataclasses

from repro.core.memory_model import CoreShape

# --- Published operating points (Table 4) ----------------------------------
FREQ_HZ = 10e6
T_PRED_MS = 36.40
T_TRAIN_MS = 171.28
P_PRED_MW = 3.39
P_TRAIN_MW = 3.37
P_IDLE_MW = 3.06
P_SLEEP_MW = 1.33

# --- BLE link (paper §3.3) --------------------------------------------------
BLE_RATE_BPS = 1e6
BLE_SUPPLY_V = 3.0
BLE_TX_CURRENT_A = 4.8e-3  # nRF52840 @ 0 dBm, DC/DC, 3 V
QUERY_BYTES_UP = 561 * 4
QUERY_BYTES_DOWN = 1
# Effective energy per query, calibrated once to Fig. 4 "Auto" @ 1 event/s
# (includes BLE connection-event/protocol overhead beyond raw payload).
E_COMM_UJ = 10_691.0
T_COMM_MS = (QUERY_BYTES_UP + QUERY_BYTES_DOWN) * 8 / BLE_RATE_BPS * 1e3


def _calibration_shape() -> CoreShape:
    return CoreShape(n=561, N=128, m=6)


def proj_ops(s: CoreShape) -> int:
    return s.n * s.N + s.N * s.m


def rls_ops(s: CoreShape) -> int:
    return 3 * s.N * s.N + s.N * s.N * s.m + 2 * s.N * s.m + s.N


def _cpm_proj() -> float:
    s = _calibration_shape()
    return (T_PRED_MS * 1e-3 * FREQ_HZ) / proj_ops(s)


def _cpm_rls() -> float:
    s = _calibration_shape()
    h_cycles = _cpm_proj() * s.n * s.N  # H recomputed inside training
    return (T_TRAIN_MS * 1e-3 * FREQ_HZ - h_cycles) / rls_ops(s)


def predict_time_ms(s: CoreShape, freq_hz: float = FREQ_HZ) -> float:
    return _cpm_proj() * proj_ops(s) / freq_hz * 1e3


def train_time_ms(s: CoreShape, freq_hz: float = FREQ_HZ) -> float:
    cycles = _cpm_proj() * s.n * s.N + _cpm_rls() * rls_ops(s)
    return cycles / freq_hz * 1e3


def raw_ble_energy_uj() -> float:
    """Payload-only BLE energy (for reference; E_COMM_UJ is what Fig.4 needs)."""
    t_s = (QUERY_BYTES_UP + QUERY_BYTES_DOWN) * 8 / BLE_RATE_BPS
    return BLE_SUPPLY_V * BLE_TX_CURRENT_A * t_s * 1e6


@dataclasses.dataclass(frozen=True)
class EventEnergy:
    """Per-event energy breakdown [uJ] during the training mode."""

    predict: float
    train: float
    comm: float
    sleep: float

    @property
    def total(self) -> float:
        return self.predict + self.train + self.comm + self.sleep


def event_energy_uj(
    q: float, period_s: float, s: CoreShape | None = None
) -> EventEnergy:
    """Energy of one sense->predict->(query+train)? cycle with query rate q.

    q = communication volume fraction (1.0 = no pruning).  The logic part
    powers off outside active windows (paper: stateless logic), so inactive
    time burns P_SLEEP (SRAM retention).
    """
    s = s or _calibration_shape()
    t_pred = predict_time_ms(s)
    t_train = train_time_ms(s)
    e_pred = P_PRED_MW * t_pred  # mW * ms = uJ
    e_train = P_TRAIN_MW * t_train
    sleep_ms = period_s * 1e3 - t_pred - q * (t_train + T_COMM_MS)
    return EventEnergy(
        predict=e_pred,
        train=q * e_train,
        comm=q * E_COMM_UJ,
        sleep=P_SLEEP_MW * max(sleep_ms, 0.0),
    )


def avg_power_mw(q: float, period_s: float, s: CoreShape | None = None) -> float:
    return event_energy_uj(q, period_s, s).total / (period_s * 1e3)


def power_reduction_pct(q: float, period_s: float, s: CoreShape | None = None) -> float:
    """Fig. 4's metric: % reduction vs no pruning (q = 1)."""
    base = avg_power_mw(1.0, period_s, s)
    return 100.0 * (base - avg_power_mw(q, period_s, s)) / base


# Paper Fig. 4 ground truth: power reduction with Auto theta (q = 0.443).
PAPER_AUTO_COMM_VOLUME = 1.0 - 0.557
PAPER_AUTO_REDUCTION = {1.0: 49.4, 5.0: 34.7, 10.0: 25.2}
# Paper Table 4 ground truth.
PAPER_TABLE4 = {
    "predict_ms": T_PRED_MS,
    "train_ms": T_TRAIN_MS,
    "predict_mw": P_PRED_MW,
    "train_mw": P_TRAIN_MW,
    "idle_mw": P_IDLE_MW,
    "sleep_mw": P_SLEEP_MW,
}
