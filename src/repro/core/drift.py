"""Lightweight concept-drift detection (paper Alg. 1 line 3, citing Yamada+23).

The paper delegates to "existing data drift detection algorithms"; we provide
a jit/vmap-compatible detector in the same spirit as the cited lightweight
on-device method: exponentially-weighted moving statistics of a scalar score
with a k-sigma test, plus hysteresis (consecutive hits to enter drift,
consecutive calm steps to leave).

Two score sources are supported:
  * feature-moment score: ||x||_1 / n (cheap input-distribution proxy),
  * confidence score: P1P2 of the local prediction (model-aware proxy).
The default combines both (max of normalized deviations).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    ewma_decay: float = 0.98  # mean/var tracker decay
    k_sigma: float = 4.0  # deviation threshold
    warmup: int = 64  # steps before the test is armed
    enter_hits: int = 3  # consecutive outliers to declare drift
    exit_calm: int = 32  # consecutive calm steps to end the training phase
    use_confidence: bool = True
    use_features: bool = True


class DriftState(NamedTuple):
    mean: jnp.ndarray  # () f32 EWMA of score
    var: jnp.ndarray  # () f32 EWMA of squared deviation
    steps: jnp.ndarray  # () int32
    hits: jnp.ndarray  # () int32 consecutive outliers
    calm: jnp.ndarray  # () int32 consecutive calm steps
    active: jnp.ndarray  # () bool — currently in drift (training) mode


def init_state() -> DriftState:
    return DriftState(
        mean=jnp.zeros((), jnp.float32),
        var=jnp.ones((), jnp.float32),
        steps=jnp.zeros((), jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        calm=jnp.zeros((), jnp.int32),
        active=jnp.zeros((), jnp.bool_),
    )


def score(x: jnp.ndarray, outputs: jnp.ndarray, cfg: DriftConfig) -> jnp.ndarray:
    """Drift score; x: (..., n_in), outputs: (..., m) -> score (...,).

    Batched over any leading axes (the fleet engine passes (S, n_in)), and
    all transitions below are elementwise, so the same detector runs scalar
    or fleet-wide unchanged.
    """
    parts = []
    if cfg.use_features:
        parts.append(jnp.mean(jnp.abs(x.astype(jnp.float32)), axis=-1))
    if cfg.use_confidence:
        top2 = jax.lax.top_k(outputs, 2)[0]
        parts.append(-(top2[..., 0] - top2[..., 1]))  # low confidence -> high score
    return jnp.stack(parts, axis=0).mean(axis=0)


def update(state: DriftState, s: jnp.ndarray, cfg: DriftConfig) -> DriftState:
    """One detector step on scalar score ``s``; returns the new state.

    ``state.active`` is the mode bit from the paper's Alg. 1: False=predicting,
    True=training.  IsDrift == rising edge of active; IsTrainDone == falling.
    """
    d = s - state.mean
    # Relative variance floor (0.1% of the signal): the bootstrap estimate
    # can collapse on near-constant streams, which would turn measurement
    # noise into permanent "drift".
    var_floor = jnp.square(1e-3 * jnp.abs(state.mean)) + 1e-12
    std = jnp.sqrt(jnp.maximum(state.var, var_floor))
    armed = state.steps >= cfg.warmup
    outlier = jnp.logical_and(armed, jnp.abs(d) > cfg.k_sigma * std)

    # Track statistics only on non-outlier samples (robustness).
    decay = jnp.float32(cfg.ewma_decay)
    upd = jnp.logical_not(outlier)
    new_mean = jnp.where(upd, decay * state.mean + (1 - decay) * s, state.mean)
    new_var = jnp.where(
        upd, decay * state.var + (1 - decay) * jnp.square(d), state.var
    )
    # Early steps: bootstrap the tracker with running (not last-sample) stats.
    boot = state.steps < 8
    new_mean = jnp.where(boot, (state.mean * state.steps + s) / (state.steps + 1), new_mean)
    boot_var = (state.var * state.steps + jnp.square(d)) / (state.steps + 1)
    new_var = jnp.where(boot, jnp.maximum(boot_var, 1e-9), new_var)

    hits = jnp.where(outlier, state.hits + 1, 0)
    calm = jnp.where(outlier, 0, state.calm + 1)

    enter = hits >= cfg.enter_hits
    leave = calm >= cfg.exit_calm
    active = jnp.where(
        state.active, jnp.logical_not(leave), enter
    )

    return DriftState(
        mean=new_mean,
        var=new_var,
        steps=state.steps + 1,
        hits=jnp.where(enter, 0, hits),
        calm=jnp.where(leave, 0, calm),
        active=active,
    )


def init_fleet(n_streams: int) -> DriftState:
    one = init_state()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_streams,) + a.shape), one)


def fleet_update(state: DriftState, s: jnp.ndarray, cfg: DriftConfig) -> DriftState:
    return jax.vmap(lambda st, ss: update(st, ss, cfg))(state, s)
