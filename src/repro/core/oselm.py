"""OS-ELM: Online Sequential Extreme Learning Machine (paper §2.1).

Single-hidden-layer network.  ``alpha`` (input->hidden) is fixed random and
never trained; ``beta`` (hidden->output) is trained by recursive least squares
(rank-k Woodbury update of the inverse Gram matrix ``P``):

    H   = G(x @ alpha)                                  (k, N)
    S   = I_k + H P H^T                                 (k, k)
    P'  = P - P H^T S^{-1} H P                          (N, N)
    beta' = beta + P' H^T (Y - H beta)                  (N, m)

Variants (paper §2.3):
  * ``base``  — alpha stored dense (ODLBase).
  * ``hash``  — alpha regenerated on the fly from Xorshift16 (ODLHash); on
    TPU the Pallas kernel ``kernels/xorshift_proj.py`` generates alpha tiles
    in VMEM so they never touch HBM.

Training targets are one-hot labels; the output layer is linear (least
squares regresses E[y|x] = class posterior), so raw outputs are used directly
as the probabilities p1/p2 for the P1P2 confidence metric.

All functions are jit/vmap-friendly; a "fleet" of independent heads is just a
leading stream axis vmapped over ``OSELMState``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import xorshift


@dataclasses.dataclass(frozen=True)
class OSELMConfig:
    n_in: int = 561
    n_hidden: int = 128
    n_out: int = 6
    variant: str = "hash"  # 'base' | 'hash'
    seed: int = xorshift.DEFAULT_SEED
    activation: str = "sigmoid"  # 'sigmoid' | 'relu' | 'tanh' | 'identity'
    ridge: float = 1e-2  # epsilon for P_0 = (H0^T H0 + ridge I)^{-1}
    alpha_scale: float = 1.0  # scales alpha; sigmoid saturates if n_in large
    use_kernel: bool = False  # route hidden() through the Pallas kernel path

    def replace(self, **kw) -> "OSELMConfig":
        return dataclasses.replace(self, **kw)


class OSELMState(NamedTuple):
    """Trainable state of one ODL head (a pytree)."""

    beta: jnp.ndarray  # (N, m) f32
    P: jnp.ndarray  # (N, N) f32 inverse Gram
    count: jnp.ndarray  # () int32 — samples trained so far


def _activate(z: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "sigmoid":
        return jax.nn.sigmoid(z)
    if kind == "relu":
        return jax.nn.relu(z)
    if kind == "tanh":
        return jnp.tanh(z)
    if kind == "identity":
        return z
    raise ValueError(f"unknown activation {kind!r}")


def make_alpha(cfg: OSELMConfig) -> Optional[jnp.ndarray]:
    """Materialized alpha for 'base'; None for 'hash' (regenerated per call)."""
    if cfg.variant == "base":
        return xorshift.alpha_dense(cfg.seed, cfg.n_in, cfg.n_hidden, cfg.alpha_scale)
    return None


def hidden(
    x: jnp.ndarray, cfg: OSELMConfig, alpha: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Hidden activations H = G(x @ alpha * scale / sqrt(n)).  x: (..., n_in).

    The 1/sqrt(n_in) factor keeps pre-activations O(1) for any input width
    (the ASIC tunes fixed-point ranges instead; DESIGN.md §5).
    """
    inv_sqrt_n = jnp.float32(1.0) / jnp.sqrt(jnp.float32(cfg.n_in))
    if cfg.variant == "hash":
        if cfg.use_kernel:
            from repro.kernels import ops  # lazy: kernels are optional at import

            z = ops.xorshift_projection(
                x.astype(jnp.float32), cfg.seed, cfg.n_hidden, scale=cfg.alpha_scale
            )
        else:
            a = xorshift.alpha_hash(cfg.seed, cfg.n_in, cfg.n_hidden)
            z = x.astype(jnp.float32) @ (a * jnp.float32(cfg.alpha_scale))
    else:
        if alpha is None:
            alpha = make_alpha(cfg)
        z = x.astype(jnp.float32) @ alpha
    return _activate(z * inv_sqrt_n, cfg.activation)


def init_state(cfg: OSELMConfig) -> OSELMState:
    """Pure-online init: P_0 = I/ridge, beta_0 = 0 (no initial batch needed)."""
    return OSELMState(
        beta=jnp.zeros((cfg.n_hidden, cfg.n_out), jnp.float32),
        P=jnp.eye(cfg.n_hidden, dtype=jnp.float32) / jnp.float32(cfg.ridge),
        count=jnp.zeros((), jnp.int32),
    )


def init_state_batch(
    cfg: OSELMConfig,
    x0: jnp.ndarray,
    y0: jnp.ndarray,
    alpha: Optional[jnp.ndarray] = None,
) -> OSELMState:
    """Classic OS-ELM boot: P_0 = (H0^T H0 + ridge I)^{-1}, beta_0 = P0 H0^T Y0."""
    h0 = hidden(x0, cfg, alpha)
    gram = h0.T @ h0 + jnp.float32(cfg.ridge) * jnp.eye(cfg.n_hidden, dtype=jnp.float32)
    # Solve instead of explicit inverse for conditioning; P0 itself is needed
    # downstream, so invert via Cholesky solve against identity.
    p0 = jax.scipy.linalg.cho_solve(
        jax.scipy.linalg.cho_factor(gram), jnp.eye(cfg.n_hidden, dtype=jnp.float32)
    )
    beta0 = p0 @ (h0.T @ y0.astype(jnp.float32))
    return OSELMState(beta=beta0, P=p0, count=jnp.asarray(x0.shape[0], jnp.int32))


def predict_logits(
    state: OSELMState, x: jnp.ndarray, cfg: OSELMConfig, alpha=None
) -> jnp.ndarray:
    """Linear outputs O = H beta (approximate class posteriors)."""
    return hidden(x, cfg, alpha) @ state.beta


def predict(
    state: OSELMState, x: jnp.ndarray, cfg: OSELMConfig, alpha=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (predicted class c, outputs O) — Fig. 2(b)."""
    o = predict_logits(state, x, cfg, alpha)
    return jnp.argmax(o, axis=-1), o


def sequential_update(
    state: OSELMState,
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: OSELMConfig,
    alpha: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
    use_kernel: Optional[bool] = None,
) -> OSELMState:
    """Rank-k RLS update (Fig. 2(d)).  x: (k, n_in) or (n_in,); y one-hot.

    ``mask`` (k,) in {0,1} soft-deletes rows (pruned samples inside a fixed
    batch shape — pruning must not change trace shapes under jit). A masked
    row contributes exactly nothing: H_row := 0 ⇒ S row/col = identity's,
    and the beta innovation term is zeroed.

    ``use_kernel`` defaults to ``cfg.use_kernel``, so configuring the Pallas
    path on the config reaches every training entry point.
    """
    if use_kernel is None:
        use_kernel = cfg.use_kernel
    if x.ndim == 1:
        x = x[None]
        y = y[None]
        if mask is not None:
            mask = mask[None]
    k = x.shape[0]
    h = hidden(x, cfg, alpha)  # (k, N)
    if mask is not None:
        h = h * mask[:, None].astype(h.dtype)
    y = y.astype(jnp.float32)
    if mask is not None:
        y = y * mask[:, None].astype(jnp.float32)

    if use_kernel:
        from repro.kernels import ops

        new_p, new_beta = ops.oselm_rls_update(state.P, state.beta, h, y)
    else:
        pht = state.P @ h.T  # (N, k)
        s = jnp.eye(k, dtype=jnp.float32) + h @ pht  # (k, k)
        g = jnp.linalg.solve(s, pht.T)  # (k, N) = S^{-1} H P
        new_p = state.P - pht @ g
        new_p = 0.5 * (new_p + new_p.T)  # enforce symmetry (numerics)
        new_beta = state.beta + new_p @ (h.T @ (y - h @ state.beta))

    inc = (
        jnp.sum(mask.astype(jnp.int32))
        if mask is not None
        else jnp.asarray(k, jnp.int32)
    )
    return OSELMState(beta=new_beta, P=new_p, count=state.count + inc)


def fit_closed_form(
    cfg: OSELMConfig, x: jnp.ndarray, y: jnp.ndarray, alpha=None
) -> jnp.ndarray:
    """Ridge least-squares solution over the whole dataset (test oracle).

    Sequential OS-ELM over all rows must converge to this beta exactly
    (Woodbury identity) — used by tests/test_oselm.py.
    """
    h = hidden(x, cfg, alpha)
    gram = h.T @ h + jnp.float32(cfg.ridge) * jnp.eye(cfg.n_hidden, dtype=jnp.float32)
    return jnp.linalg.solve(gram, h.T @ y.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Fleet helpers: many independent heads, one per stream (leading axis S).
# ---------------------------------------------------------------------------


def init_fleet(cfg: OSELMConfig, n_streams: int) -> OSELMState:
    one = init_state(cfg)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_streams,) + a.shape), one)


def fleet_predict(state: OSELMState, x: jnp.ndarray, cfg: OSELMConfig):
    """x: (S, n_in) — one sample per stream."""
    return jax.vmap(lambda st, xx: predict(st, xx, cfg))(state, x)


def fleet_update(state: OSELMState, x: jnp.ndarray, y: jnp.ndarray, cfg: OSELMConfig,
                 mask: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None) -> OSELMState:
    """x: (S, n_in), y: (S, m), mask: (S,) — rank-1 update per stream.

    This is the vmap-of-rank-1 baseline; ``repro.engine`` and the serve path
    use :func:`fleet_rank1_update_h` (einsum-batched, kernel-routable)
    instead.  ``use_kernel`` (default: ``cfg.use_kernel``) dispatches to the
    batched Pallas entry rather than vmapping a scalar ``pallas_call``.
    """
    if mask is None:
        mask = jnp.ones(x.shape[0], jnp.float32)
    if use_kernel is None:
        use_kernel = cfg.use_kernel
    if use_kernel:
        return fleet_rank1_update(state, x, y, cfg, mask=mask, use_kernel=True)
    return jax.vmap(
        lambda st, xx, yy, mm: sequential_update(st, xx, yy, cfg, mask=mm, use_kernel=False)
    )(state, x, y, mask)


def fleet_rank1_update_h(
    state: OSELMState,  # leaves with leading S
    h: jnp.ndarray,  # (S, N) hidden activations, one row per stream
    y: jnp.ndarray,  # (S, m) one-hot targets
    cfg: OSELMConfig,
    mask: Optional[jnp.ndarray] = None,  # (S,) in {0, 1}
    use_kernel: Optional[bool] = None,
) -> OSELMState:
    """Fused fleet rank-1 RLS: the whole Woodbury update for S independent
    heads as batched einsums (one XLA fusion, no per-stream solve).

    Takes precomputed hidden activations so callers that already predicted
    this tick (the engine's fleet_step) never project twice.  A masked
    stream is an exact identity on (P, beta, count), same contract as
    ``sequential_update``.
    """
    if mask is None:
        mask = jnp.ones(h.shape[0], jnp.float32)
    if use_kernel is None:
        use_kernel = cfg.use_kernel
    hm = h * mask[:, None]
    ym = y.astype(jnp.float32) * mask[:, None]

    if use_kernel:
        from repro.kernels import ops  # lazy: kernels are optional at import

        new_p, new_beta = ops.oselm_rls_update_fleet(
            state.P, state.beta, hm[:, None, :], ym[:, None, :]
        )
    else:
        pht = jnp.einsum("snk,sk->sn", state.P, hm)  # (S, N) = P h
        den = 1.0 + jnp.einsum("sn,sn->s", hm, pht)  # (S,) = 1 + h P hᵀ
        new_p = state.P - pht[:, :, None] * (pht[:, None, :] / den[:, None, None])
        new_p = 0.5 * (new_p + new_p.transpose(0, 2, 1))  # symmetry (numerics)
        err = ym - jnp.einsum("sn,snm->sm", hm, state.beta)  # (S, m)
        # Rank-1 identity: P' hᵀ = (P - P hᵀh P/den) hᵀ = pht/den, so the
        # innovation beta' = beta + P' hᵀ e needs no (N, N) x (N, m) matmul
        # — the classic RLS gain vector, O(S N m) instead of O(S N² m).
        gain = pht / den[:, None]
        new_beta = state.beta + gain[:, :, None] * err[:, None, :]

    return OSELMState(
        beta=new_beta, P=new_p, count=state.count + mask.astype(jnp.int32)
    )


def fleet_rank1_update(
    state: OSELMState,
    x: jnp.ndarray,  # (S, n_in)
    y: jnp.ndarray,  # (S, m)
    cfg: OSELMConfig,
    mask: Optional[jnp.ndarray] = None,
    use_kernel: Optional[bool] = None,
) -> OSELMState:
    """As :func:`fleet_rank1_update_h` but projecting ``x`` itself."""
    return fleet_rank1_update_h(
        state, hidden(x, cfg), y, cfg, mask=mask, use_kernel=use_kernel
    )
