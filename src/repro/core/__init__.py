"""The paper's contribution: supervised ODL (OS-ELM) + auto data pruning.

Submodules:
  xorshift     — Xorshift16 (7,9,8) PRNG weights (sequential + counter-based)
  oselm        — OS-ELM predict / rank-k RLS sequential training
  pruning      — P1P2 confidence metric + auto-theta ladder controller
  drift        — lightweight EWMA drift detector (mode switching)
  labels       — teacher query protocol + communication metering
  odl_head     — DEPRECATED alias of repro.engine.scalar (Algorithm 1 now
                 lives in repro/engine; kept for the paper-repro tests)
  memory_model — paper Table 1/2 analytic memory & parameter model
  power_model  — paper Table 4 / Fig. 4 timing & power model
"""

from repro.core import (  # noqa: F401
    drift,
    labels,
    memory_model,
    odl_head,
    oselm,
    power_model,
    pruning,
    xorshift,
)
