"""repro: fleet-scale supervised ODL with auto data pruning (JAX/Pallas)."""
