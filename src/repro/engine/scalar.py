"""The paper's single-stream ODL API (Algorithm 1) — scalar S=1 view.

This is the engine-resident home of the API that used to live in
``core/odl_head.py`` (now a documented alias of this module).  The actual
state machine is the batched fleet engine (``engine/fleet.py``); the scalar
view adds a leading stream axis of 1, delegates to ``fleet_step`` /
``run_fleet``, and strips the axis again.  Semantics are bit-identical per
stream; code that handles more than one stream should use ``repro.engine``
directly (``init_fleet`` / ``run_fleet`` / ``stream.run``).

``ODLCoreConfig`` / ``ODLCoreState`` / ``StepOutput`` are the engine's
``EngineConfig`` / ``EngineState`` / ``FleetStepOutput`` (one set of pytree
classes for both views — see ``engine/types.py``), so existing checkpoints
and configs keep working.  The fleet import is deferred to call time so the
``repro.core`` -> alias -> engine import cycle resolves in both orders.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import oselm, pruning
from repro.engine.types import (
    ODLCoreConfig,
    ODLCoreState,
    StepOutput,
    init_state,
)

__all__ = [
    "ODLCoreConfig",
    "ODLCoreState",
    "StepOutput",
    "accuracy",
    "init_state",
    "run_stream",
    "run_training_phase",
    "step",
    "train_phase_step",
]


def _fleet():
    from repro.engine import fleet  # deferred: breaks the import cycle

    return fleet


def _expand(tree):
    """Scalar state/arrays -> fleet of one stream (leading axis 1)."""
    return jax.tree.map(lambda a: jnp.asarray(a)[None], tree)


def _squeeze(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _scalar_step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
    mode: str,
    teacher_available: Optional[jnp.ndarray],
    drift_active: Optional[jnp.ndarray],
) -> tuple[ODLCoreState, StepOutput]:
    t = teacher(idx, x)  # always traced (static shapes), used only if queried
    fstate, fout = _fleet().fleet_step(
        _expand(state),
        x[None],
        jnp.asarray(t, jnp.int32)[None],
        cfg,
        mode=mode,
        teacher_available=None if teacher_available is None else _expand(teacher_available),
        drift_active=None if drift_active is None else _expand(drift_active),
    )
    return _squeeze(fstate), _squeeze(fout)


def train_phase_step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
    drift_active: Optional[jnp.ndarray] = None,
    teacher_available: Optional[jnp.ndarray] = None,
) -> tuple[ODLCoreState, StepOutput]:
    """One sample of the paper's retraining phase (pruning always armed).

    ``drift_active`` models pruning condition 2 (default: not detected).
    ``teacher_available`` models the paper's retry-or-skip fault policy: when
    False the query is suppressed *and* no training happens this step.
    """
    return _scalar_step(
        state, x, idx, teacher, cfg, "train_phase", teacher_available, drift_active
    )


def step(
    state: ODLCoreState,
    x: jnp.ndarray,
    idx: jnp.ndarray,
    teacher: Callable,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Full Algorithm 1: drift detector switches predicting <-> training."""
    return _scalar_step(state, x, idx, teacher, cfg, "algo1", None, None)


def run_training_phase(
    state: ODLCoreState,
    xs: jnp.ndarray,  # (T, n_in)
    teacher_labels: jnp.ndarray,  # (T,) int32
    cfg: ODLCoreConfig,
    teacher_available: Optional[jnp.ndarray] = None,  # (T,) bool
) -> tuple[ODLCoreState, StepOutput]:
    """Scan the retraining phase over a stream (paper §3 step 3) — a one-
    stream ``engine.run_fleet``.

    Condition 1 is lifetime trained count — initial training (step 1) already
    satisfies max(N, 288), so pruning is armed from the first stream sample,
    exactly as required to reproduce Fig. 3/4 (see should_query docstring).
    """
    state = state._replace(prune=pruning.reset_phase(state.prune))
    avail = None if teacher_available is None else teacher_available[:, None]
    fstate, fouts = _fleet().run_fleet(
        _expand(state),
        xs[:, None],
        jnp.asarray(teacher_labels, jnp.int32)[:, None],
        cfg,
        mode="train_phase",
        teacher_available=avail,
    )
    return _squeeze(fstate), jax.tree.map(lambda a: a[:, 0], fouts)


def run_stream(
    state: ODLCoreState,
    xs: jnp.ndarray,
    teacher_labels: jnp.ndarray,
    cfg: ODLCoreConfig,
) -> tuple[ODLCoreState, StepOutput]:
    """Scan the full Algorithm-1 ``step`` over a stream (one-stream fleet)."""
    fstate, fouts = _fleet().run_fleet(
        _expand(state),
        xs[:, None],
        jnp.asarray(teacher_labels, jnp.int32)[:, None],
        cfg,
        mode="algo1",
    )
    return _squeeze(fstate), jax.tree.map(lambda a: a[:, 0], fouts)


def accuracy(
    state: ODLCoreState, xs: jnp.ndarray, ys: jnp.ndarray, cfg: ODLCoreConfig
) -> jnp.ndarray:
    """Batch test accuracy of the current head."""
    preds, _ = oselm.predict(state.elm, xs, cfg.elm)
    return jnp.mean((preds == ys).astype(jnp.float32))
