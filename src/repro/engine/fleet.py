"""Batched fleet engine for Algorithm 1 (see package docstring).

Design rules:
  * every leaf of ``EngineState`` carries a leading stream axis S;
  * all per-stream controller math (pruning ladder, drift detector) is
    elementwise jnp, so the scalar transition functions in ``core/`` apply
    to (S,) arrays unchanged — no vmap anywhere on the hot path;
  * the only matmuls are one (S, n_in) @ alpha hidden projection and the
    einsum-batched rank-1 Woodbury update (optionally the fused Pallas
    kernel via ``cfg.elm.use_kernel``);
  * one tick is split at the teacher round-trip: ``plan`` (predict, drift,
    query decision, comm metering) and ``learn`` (masked rank-1 RLS + the
    auto-theta controller observing answered queries).  ``fleet_step`` is
    exactly ``learn(plan(...))`` with same-tick labels, so the streaming
    runtime (``engine/stream.py``), which runs the two halves as separate
    dispatches with real teacher latency in between, degrades bit-for-bit
    to ``run_fleet`` when the teacher answers instantly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import drift as drift_mod
from repro.core import labels as labels_mod
from repro.core import oselm, pruning
from repro.distributed import sharding
from repro.engine.types import (
    EngineConfig,
    EngineState,
    FleetStepOutput,
    init_state,
)

# How many compiled runners to keep alive per process.  A serving process
# cycles through a handful of (cfg, mode, donate) combinations; unbounded
# caching leaks one executable per combination forever (see ROADMAP PR-2).
RUNNER_CACHE_SIZE = 32


def init_fleet(cfg: EngineConfig, n_streams: int) -> EngineState:
    return broadcast_streams(init_state(cfg), n_streams)


def broadcast_streams(state: EngineState, n_streams: int) -> EngineState:
    """Replicate one (scalar, no-S-axis) state across n_streams streams."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_streams,) + a.shape), state
    )


def stream_slice(state: EngineState, s: int) -> EngineState:
    """Extract stream ``s`` as a scalar (axis-free) state."""
    return jax.tree.map(lambda a: a[s], state)


# -- stacked-state helpers (cohort fusion, engine/cohort.py) ----------------
#
# A cohort stacks N same-shaped tenants' EngineStates along the leading
# stream axis (tenant axis folded onto S) so one fused plan/learn dispatch
# advances all of them.  Every per-stream op in this module is elementwise
# or einsum-batched over S, so row r of a stacked dispatch is bit-for-bit
# row r of the corresponding solo dispatch — the property the cohort
# engine's solo-parity guarantee rests on (locked by tests/test_cohort.py).


def stack_streams(states: list[EngineState]) -> EngineState:
    """Concatenate fleets along the leading stream axis."""
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *states)


def slice_streams(state: EngineState, lo: int, hi: int) -> EngineState:
    """Extract the ``[lo:hi]`` stream window (one cohort member's rows)."""
    return jax.tree.map(lambda a: a[lo:hi], state)


def remove_streams(state: EngineState, lo: int, hi: int) -> EngineState:
    """Drop the ``[lo:hi]`` stream window (evict a member from a cohort)."""
    return jax.tree.map(
        lambda a: jnp.concatenate([a[:lo], a[hi:]], axis=0), state
    )


@functools.lru_cache(maxsize=RUNNER_CACHE_SIZE)
def _patch_learn_runner(cfg: EngineConfig, lo: int, hi: int, donate: bool):
    """Learn on one member's ``[lo:hi]`` row window of a stacked cohort
    state, in place: slice the window out, run the member-width ``learn``,
    and scatter the updated P/beta/ladder rows back with ``.at[lo:hi]`` —
    donation keeps the full-width buffers in place, so a straggler reply
    (a ticket asked before its tenant joined the cohort, or before a
    resize) costs one member-width update, not a full-width one.  Rows
    outside the window are untouched, so this is bit-for-bit the solo
    ``learn`` on those rows."""

    def run_patch(elm, prune, drift, meter, h, labels, pred, conf, mask,
                  controller_on, theta):
        sub = EngineState(
            elm=jax.tree.map(lambda a: a[lo:hi], elm),
            prune=jax.tree.map(lambda a: a[lo:hi], prune),
            drift=jax.tree.map(lambda a: a[lo:hi], drift),
            meter=jax.tree.map(lambda a: a[lo:hi], meter),
        )
        new_sub = learn(
            sub, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        new_elm = jax.tree.map(
            lambda full, part: full.at[lo:hi].set(part), elm, new_sub.elm
        )
        new_prune = jax.tree.map(
            lambda full, part: full.at[lo:hi].set(part), prune, new_sub.prune
        )
        return new_elm, new_prune

    return jax.jit(run_patch, donate_argnums=(0, 1) if donate else ())


def _tree_where(cond: jnp.ndarray, a, b):
    """Per-stream select between two pytrees of (S,)-leading leaves."""
    return jax.tree.map(
        lambda x, y: jnp.where(cond.reshape(cond.shape + (1,) * (x.ndim - cond.ndim)), x, y),
        a,
        b,
    )


def _predict(state: EngineState, x: jnp.ndarray, cfg: EngineConfig):
    """Fleet predict: hidden projection once, per-stream readout via einsum."""
    h = oselm.hidden(x, cfg.elm)  # (S, N)
    o = jnp.einsum("sn,snm->sm", h, state.elm.beta)  # (S, m)
    return h, jnp.argmax(o, axis=-1), o


class PlanOutput(NamedTuple):
    """Everything the first half of a tick produces — including what must
    survive the teacher round-trip so ``learn`` can apply labels later."""

    h: jnp.ndarray  # (S, N) hidden activations at query time
    pred: jnp.ndarray  # (S,) int32 local prediction c
    outputs: jnp.ndarray  # (S, m) raw outputs O
    confidence: jnp.ndarray  # (S,) f32 p1 - p2
    queried: jnp.ndarray  # (S,) bool — streams shipping feats to the teacher
    controller_on: jnp.ndarray  # (S,) bool — ladder observes this tick
    theta: jnp.ndarray  # (S,) f32 threshold in force this tick
    mode_training: jnp.ndarray  # (S,) bool


def plan(
    state: EngineState,
    x: jnp.ndarray,  # (S, n_in)
    cfg: EngineConfig,
    mode: str = "algo1",
    teacher_available: Optional[jnp.ndarray] = None,  # (S,) bool
    drift_active: Optional[jnp.ndarray] = None,  # (S,) bool (train_phase only)
) -> tuple[EngineState, PlanOutput]:
    """Teacher-facing half of one tick: predict → confidence → drift →
    should_query, charge the comm meter for issued queries, and account the
    pruning ladder's SKIP events (streams the controller observes but that
    do not query — their success/streak transition needs no label).

    ``elm`` passes through untouched; the committed state advances drift,
    the per-phase counter reset on a drift rising edge, skip accounting,
    and the meter.  Queried streams' ladder transitions wait for ``learn``.

    Counter semantics under label loss: the meter charges bytes for every
    *issued* query here, while ``prune.queries`` counts only *answered*
    queries (incremented in ``learn``) — with a lossy teacher the two
    deliberately diverge (``StreamStats.queries_issued`` tracks the former;
    ``comm_volume_fraction`` reflects queries the controller observed).
    """
    if mode not in ("algo1", "train_phase", "serve"):
        raise ValueError(f"unknown engine mode {mode!r}")
    n_streams = x.shape[0]
    if teacher_available is None:
        teacher_available = jnp.ones((n_streams,), jnp.bool_)

    h, c, o = _predict(state, x, cfg)
    conf = pruning.confidence(o)

    if mode == "serve":
        # ``gate`` semantics for the streaming/multiplexed serving path:
        # the drift detector runs live and a drifting stream is forced to
        # query (the paper's pruning condition 2), the controller is always
        # armed, and there is no training-mode gating — exactly the
        # decision logic of ``gate``, so ``plan(mode='serve')`` + ``learn``
        # is bit-for-bit ``gate`` + ``apply_labels``.
        s = drift_mod.score(x, o, cfg.drift)
        new_drift = drift_mod.update(state.drift, s, cfg.drift)
        training = jnp.ones((n_streams,), jnp.bool_)
        prune_st = state.prune
        want_query = pruning.should_query(
            prune_st, o, state.elm.count, new_drift.active, cfg.prune
        )
        queried = want_query & teacher_available
        controller_on = teacher_available
    elif mode == "algo1":
        # IsDrift / IsTrainDone: per-stream detector with hysteresis.
        s = drift_mod.score(x, o, cfg.drift)  # (S,)
        new_drift = drift_mod.update(state.drift, s, cfg.drift)
        training = new_drift.active
        # Rising edge == IsDrift fired: re-arm the per-phase counter.
        entering = jnp.logical_and(training, jnp.logical_not(state.drift.active))
        prune_st = _tree_where(entering, pruning.reset_phase(state.prune), state.prune)
        want_query = pruning.should_query(
            prune_st, o, state.elm.count, jnp.zeros((n_streams,), jnp.bool_), cfg.prune
        )
        queried = training & want_query & teacher_available
        # Auto-theta only observes training-mode steps with a live teacher.
        controller_on = training & teacher_available
    else:
        if drift_active is None:
            drift_active = jnp.zeros((n_streams,), jnp.bool_)
        new_drift = state.drift
        training = jnp.ones((n_streams,), jnp.bool_)
        prune_st = state.prune
        want_query = pruning.should_query(
            prune_st, o, state.elm.count, drift_active, cfg.prune
        )
        queried = want_query & teacher_available
        controller_on = teacher_available

    theta = pruning.theta_of(prune_st, cfg.prune)
    meter = state.meter.charge_query(x.shape[-1], queried)
    # Skip accounting happens now: a skipped sample's ladder transition uses
    # only (conf > theta), never the teacher's answer (pruning.update with
    # queried=False ignores ``agree``), so it must not wait for the label.
    off = jnp.zeros((n_streams,), jnp.bool_)
    new_prune = _tree_where(
        controller_on & jnp.logical_not(queried),
        pruning.update(prune_st, off, off, conf, cfg.prune),
        prune_st,
    )

    new_state = sharding.constrain_fleet(
        EngineState(elm=state.elm, prune=new_prune, drift=new_drift, meter=meter)
    )
    out = PlanOutput(
        h=h,
        pred=c,
        outputs=o,
        confidence=conf,
        queried=queried,
        controller_on=controller_on,
        theta=theta,
        mode_training=training,
    )
    return new_state, out


def learn(
    state: EngineState,
    h: jnp.ndarray,  # (S, N) hidden activations captured at plan time
    labels: jnp.ndarray,  # (S,) int32 teacher answers (valid where mask)
    pred: jnp.ndarray,  # (S,) int32 plan-time local predictions
    confidence: jnp.ndarray,  # (S,) f32 plan-time P1P2 confidence
    mask: jnp.ndarray,  # (S,) bool — answered queries to apply
    controller_on: jnp.ndarray,  # (S,) bool — plan-time controller gate
    cfg: EngineConfig,
    theta: Optional[jnp.ndarray] = None,  # (S,) plan-time threshold
) -> EngineState:
    """Deferred half of a tick: masked rank-1 RLS on the teacher's answers
    plus the auto-theta ladder transition for the answered queries.

    ``h`` / ``pred`` / ``confidence`` / ``theta`` are the plan-time values,
    so a label arriving ticks later (or out of order) still trains on the
    features it was asked about and is judged against the threshold the
    query decision used — a disagreement on a low-confidence query steps
    theta up even if other ticks moved the ladder while the answer was in
    flight.  A stream outside ``mask`` is an exact identity.
    """
    y = labels_mod.one_hot(labels, cfg.elm.n_out)  # (S, m)
    agree = pred == labels
    new_elm = oselm.fleet_rank1_update_h(
        state.elm, h, y, cfg.elm, mask=mask.astype(jnp.float32)
    )
    new_prune = _tree_where(
        controller_on & mask,
        pruning.update(state.prune, mask, agree, confidence, cfg.prune, theta=theta),
        state.prune,
    )
    return sharding.constrain_fleet(
        state._replace(elm=new_elm, prune=new_prune)
    )


def fleet_step(
    state: EngineState,
    x: jnp.ndarray,  # (S, n_in)
    labels: jnp.ndarray,  # (S,) int32 teacher answers (used only where queried)
    cfg: EngineConfig,
    mode: str = "algo1",
    teacher_available: Optional[jnp.ndarray] = None,  # (S,) bool
    drift_active: Optional[jnp.ndarray] = None,  # (S,) bool (train_phase only)
) -> tuple[EngineState, FleetStepOutput]:
    """One fused tick for all S streams — ``learn`` composed directly on
    ``plan`` (a zero-latency teacher).  Semantics per stream are exactly the
    scalar Algorithm-1 ``step`` (mode='algo1') / §3 retraining
    ``train_phase_step`` (mode='train_phase') of ``engine/scalar.py``.
    """
    state, p = plan(
        state, x, cfg, mode=mode,
        teacher_available=teacher_available, drift_active=drift_active,
    )
    state = learn(
        state, p.h, labels, p.pred, p.confidence, p.queried, p.controller_on, cfg,
        theta=p.theta,
    )
    out = FleetStepOutput(
        pred=p.pred,
        outputs=p.outputs,
        queried=p.queried,
        trained=p.queried,
        theta=p.theta,
        confidence=p.confidence,
        mode_training=p.mode_training,
    )
    return state, out


def fleet_accuracy(
    state: EngineState,
    xs: jnp.ndarray,  # (B, n_in) shared test batch
    ys: jnp.ndarray,  # (B,) int32
    cfg: EngineConfig,
) -> jnp.ndarray:
    """Per-stream test accuracy of every head against one shared batch:
    one hidden projection, per-stream readout via einsum — returns (S,)."""
    h = oselm.hidden(xs, cfg.elm)  # (B, N)
    o = jnp.einsum("bn,snm->sbm", h, state.elm.beta)  # (S, B, m)
    preds = jnp.argmax(o, axis=-1)  # (S, B)
    return jnp.mean((preds == ys[None, :]).astype(jnp.float32), axis=-1)


# ---------------------------------------------------------------------------
# Chunked time scan
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=RUNNER_CACHE_SIZE)
def _chunk_runner(cfg: EngineConfig, mode: str, donate: bool):
    """One compiled executable per (cfg, mode, chunk shape): scans fleet_step
    over a (chunk, S) block of ticks.  Cached (bounded LRU — a long-lived
    server must not leak one executable per retired config) so chunk
    boundaries reuse the same jitted function, and the state argument is
    donated so P/beta update in place on accelerators."""

    def run_chunk(state, xs, labels, avail):
        def body(st, inp):
            x_t, lab_t, av_t = inp
            return fleet_step(st, x_t, lab_t, cfg, mode=mode, teacher_available=av_t)

        return jax.lax.scan(body, state, (xs, labels, avail))

    return jax.jit(run_chunk, donate_argnums=(0,) if donate else ())


def runner_cache_info() -> dict:
    """Hit/miss/size counters of the compiled-runner cache, for serving
    stats (``engine.stream.cache_stats`` merges these with its own)."""
    out = {}
    for name, fn in (
        ("chunk_runner", _chunk_runner),
        ("patch_learn_runner", _patch_learn_runner),
    ):
        info = fn.cache_info()
        out[name] = {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.currsize,
            "maxsize": info.maxsize,
        }
    return out


def run_fleet(
    state: EngineState,
    xs: jnp.ndarray,  # (T, S, n_in)
    labels: jnp.ndarray,  # (T, S) int32
    cfg: EngineConfig,
    mode: str = "algo1",
    teacher_available: Optional[jnp.ndarray] = None,  # (T, S) bool
    chunk: Optional[int] = None,
    donate: Optional[bool] = None,
) -> tuple[EngineState, FleetStepOutput]:
    """Run T ticks of S streams through the engine, ``chunk`` ticks per
    dispatch.  Returns (final state, outputs stacked over (T, S)).

    ``donate`` defaults to True off-CPU.  On CPU it defaults to False so
    ad-hoc callers may keep using the input state after the call — but CPU
    donation *does* alias buffers in-place (no copy, no warning), and at
    mega-fleet sizes the non-donated path is dominated by page-zeroing
    churn on the ~16 KB/stream P re-allocation (sys-time, not compute).
    Resident callers that own their state (``run_fleet_sharded``,
    ``run_fleet_shards``, the streaming runtime) pass ``donate=True``
    explicitly and get ~2.7x on CPU at S=65,536.

    When T is a multiple of ``chunk`` every dispatch hits the same compiled
    executable; a ragged final chunk costs exactly one extra compile.
    """
    t_total = xs.shape[0]
    if t_total == 0:
        s = xs.shape[1]
        m = cfg.elm.n_out
        empty = FleetStepOutput(
            pred=jnp.zeros((0, s), jnp.int32),
            outputs=jnp.zeros((0, s, m), jnp.float32),
            queried=jnp.zeros((0, s), jnp.bool_),
            trained=jnp.zeros((0, s), jnp.bool_),
            theta=jnp.zeros((0, s), jnp.float32),
            confidence=jnp.zeros((0, s), jnp.float32),
            mode_training=jnp.zeros((0, s), jnp.bool_),
        )
        return state, empty
    if chunk is None or chunk > t_total:
        chunk = t_total
    if donate is None:
        donate = jax.default_backend() != "cpu"
    if teacher_available is None:
        teacher_available = jnp.ones(xs.shape[:2], jnp.bool_)

    runner = _chunk_runner(cfg, mode, donate)
    outs = []
    t = 0
    while t < t_total:
        c = min(chunk, t_total - t)
        state, out = runner(
            state, xs[t : t + c], labels[t : t + c], teacher_available[t : t + c]
        )
        outs.append(out)
        t += c
    if len(outs) == 1:
        return state, outs[0]
    return state, jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *outs)


# ---------------------------------------------------------------------------
# Serving entry points: one tick split at the teacher round-trip.
# ---------------------------------------------------------------------------


class GateOutput(NamedTuple):
    """Plan-time decision context of one serving tick.

    Everything ``apply_labels`` needs to judge a teacher answer that comes
    back ticks later: the hidden activations the query trained on, the
    local prediction/confidence the agreement check compares against, and
    the threshold the query decision was made under.  Mirrors
    ``PlanOutput`` for the ``gate``/``apply_labels`` serving split.
    """

    h: jnp.ndarray  # (S, N) hidden activations at query time
    pred: jnp.ndarray  # (S,) int32 local prediction c
    outputs: jnp.ndarray  # (S, m) raw outputs O
    confidence: jnp.ndarray  # (S,) f32 p1 - p2 at query time
    queried: jnp.ndarray  # (S,) bool — streams shipping feats to the teacher
    theta: jnp.ndarray  # (S,) f32 threshold in force at query time
    feats: jnp.ndarray  # (S, n_in) the raw features (for a real teacher RPC)
    drift_active: jnp.ndarray  # (S,) bool


def gate(
    state: EngineState,
    x: jnp.ndarray,  # (S, n_in) features, one per stream
    cfg: EngineConfig,
) -> tuple[EngineState, GateOutput]:
    """Predict + decide which streams must consult the teacher.

    Runs the drift detector (a drifting stream is forced to query — the
    paper's pruning condition 2), charges the comm meter for issued
    queries, and accounts the ladder's skip events for the non-querying
    streams (same split as ``plan``/``learn``: skip transitions belong to
    decision time, query transitions to answer time — so applying several
    deferred replies in one tick cannot multiply skip counts).  Labels
    arrive later via ``apply_labels``, which takes the returned
    ``GateOutput`` so delayed replies are judged against *this* tick's
    prediction/confidence/theta, not whatever the weights say by the time
    the answer lands.
    """
    h, c, o = _predict(state, x, cfg)
    conf = pruning.confidence(o)
    s = drift_mod.score(x, o, cfg.drift)
    new_drift = drift_mod.update(state.drift, s, cfg.drift)
    query_mask = pruning.should_query(
        state.prune, o, state.elm.count, new_drift.active, cfg.prune
    )
    theta = pruning.theta_of(state.prune, cfg.prune)
    meter = state.meter.charge_query(x.shape[-1], query_mask)
    off = jnp.zeros_like(query_mask)
    new_prune = _tree_where(
        jnp.logical_not(query_mask),
        pruning.update(state.prune, off, off, conf, cfg.prune),
        state.prune,
    )
    new_state = sharding.constrain_fleet(
        state._replace(drift=new_drift, meter=meter, prune=new_prune)
    )
    out = GateOutput(
        h=h,
        pred=c,
        outputs=o,
        confidence=conf,
        queried=query_mask,
        theta=theta,
        feats=x,
        drift_active=new_drift.active,
    )
    return new_state, out


def apply_labels(
    state: EngineState,
    ctx: Union[GateOutput, PlanOutput],
    labels: jnp.ndarray,  # (S,) int32 teacher answers (valid where mask)
    mask: jnp.ndarray,  # (S,) bool — streams whose teacher answered
    cfg: EngineConfig,
) -> EngineState:
    """Asynchronous label application: masked rank-1 RLS + auto-theta step.

    ``ctx`` is the ``GateOutput`` (or ``PlanOutput``) captured when the
    query was issued: the RLS update trains on the plan-time ``h`` and the
    ladder judges agreement against the plan-time ``pred``/``confidence``
    under the plan-time ``theta`` — exactly like ``learn``.  Recomputing
    those from the *current* state (the pre-ISSUE-3 behavior, removed in
    ISSUE 4) is wrong with a laggy teacher: weights updated while the
    answer was in flight change the prediction, so the agree/confidence
    judgment no longer describes the decision the query belongs to.

    Only the answered streams (``mask``) transition the ladder — the skip
    accounting for everyone else already happened in ``gate`` — so calling
    this once per arrived reply (zero, one, or many per tick, depending on
    teacher latency) keeps per-tick controller semantics.
    """
    if not isinstance(ctx, (GateOutput, PlanOutput)):
        raise TypeError(
            "apply_labels needs the plan-time decision context: pass the "
            "GateOutput returned by gate() (or a PlanOutput from plan()). "
            "The raw-features recompute path was removed — it judged "
            "delayed replies against the *current* weights (stale-reply "
            f"semantics). Got {type(ctx).__name__}."
        )
    h, pred, conf, theta = ctx.h, ctx.pred, ctx.confidence, ctx.theta
    agree = pred == labels
    y = labels_mod.one_hot(labels, cfg.elm.n_out)
    new_elm = oselm.fleet_rank1_update_h(
        state.elm, h, y, cfg.elm, mask=mask.astype(jnp.float32)
    )
    new_prune = _tree_where(
        mask,
        pruning.update(state.prune, mask, agree, conf, cfg.prune, theta=theta),
        state.prune,
    )
    return sharding.constrain_fleet(
        state._replace(elm=new_elm, prune=new_prune)
    )


# ---------------------------------------------------------------------------
# Mesh-sharded fleets: the stream axis over a ("fleet",) device mesh.
# ---------------------------------------------------------------------------
#
# Every per-stream op above is elementwise or einsum-batched over S with all
# contractions on unsharded dims (n_in / n_hidden), so splitting the stream
# axis — whether by GSPMD partitioning one dispatch (``shard_fleet`` +
# ``run_fleet_sharded``) or by explicit per-shard dispatches
# (``split_fleet`` + ``run_fleet_shards``) — is bit-for-bit the unsharded
# run row-for-row, with zero cross-shard communication on the hot path
# (locked by tests/test_mesh_fleet.py).

# Streams per block for the explicit shard-local path: P is ~16 KB/stream,
# so 512-stream blocks keep each block's working set (~8 MB of P) inside a
# host L3 across the whole T-tick scan instead of streaming GBs per tick.
DEFAULT_STREAM_BLOCK = 512


def pad_streams(state: EngineState, cfg: EngineConfig, n_pad: int) -> EngineState:
    """Append ``n_pad`` fresh-init dead rows to a fleet (padding S up to a
    multiple of the shard count).  Dead rows are driven with
    ``teacher_available=False`` so they never query or learn; callers meter
    them (bench/stream stats report ``padded_streams``) and strip their
    rows from outputs."""
    if n_pad <= 0:
        return state
    return stack_streams([state, init_fleet(cfg, n_pad)])


def shard_fleet(
    state: EngineState, cfg: EngineConfig, mesh=None
) -> tuple[EngineState, int]:
    """GSPMD placement: pad S to a multiple of the mesh's fleet-axis size
    and ``device_put`` every leaf with a ``NamedSharding`` splitting its
    leading axis over the ``stream`` rule.  Returns ``(placed_state,
    n_pad)``.  Identity (and ``n_pad=0``) with no mesh.

    The placed state is meant to stay *resident*: advance it with
    ``run_fleet_sharded`` (donated dispatches keep P/beta updating in place
    per shard) and only pull it off the mesh at checkpoint time.
    """
    if mesh is not None and mesh is not sharding.mesh_or_none():
        with sharding.activate(mesh):
            return shard_fleet(state, cfg)
    if sharding.mesh_or_none() is None:
        return state, 0
    n_shards = sharding.fleet_axis_size()
    s = jax.tree.leaves(state)[0].shape[0]
    n_pad = (-s) % n_shards
    state = pad_streams(state, cfg, n_pad)
    return (
        jax.tree.map(
            lambda a: jax.device_put(a, sharding.fleet_sharding(a.ndim, a.shape)),
            state,
        ),
        n_pad,
    )


def run_fleet_sharded(
    state: EngineState,  # shard_fleet-placed (possibly padded) fleet
    xs: jnp.ndarray,  # (T, S_real, n_in)
    labels: jnp.ndarray,  # (T, S_real) int32
    cfg: EngineConfig,
    mode: str = "algo1",
    teacher_available: Optional[jnp.ndarray] = None,  # (T, S_real) bool
    chunk: Optional[int] = None,
) -> tuple[EngineState, FleetStepOutput]:
    """Advance a ``shard_fleet``-placed fleet by donated full-width
    dispatches; XLA partitions each dispatch over the mesh (state stays
    resident per shard, inputs are staged with matching shardings so no
    resharding happens inside the step).  Inputs are in *real* (unpadded)
    width: dead rows are appended here with ``teacher_available=False`` and
    stripped from the returned outputs, so callers never see padding.
    """
    s_pad = jax.tree.leaves(state)[0].shape[0]
    t, s_real = xs.shape[0], xs.shape[1]
    if teacher_available is None:
        teacher_available = jnp.ones((t, s_real), jnp.bool_)
    if s_pad != s_real:
        pad = s_pad - s_real
        if pad < 0:
            raise ValueError(f"state has {s_pad} streams < input width {s_real}")
        xs = jnp.concatenate(
            [xs, jnp.zeros((t, pad) + xs.shape[2:], xs.dtype)], axis=1
        )
        labels = jnp.concatenate([labels, jnp.zeros((t, pad), labels.dtype)], axis=1)
        teacher_available = jnp.concatenate(
            [teacher_available, jnp.zeros((t, pad), jnp.bool_)], axis=1
        )
    if sharding.mesh_or_none() is not None:

        def put(a):
            ns = sharding.named_sharding(
                None, "stream", *((None,) * (a.ndim - 2)), shape=a.shape
            )
            return jax.device_put(a, ns)

        xs, labels = put(xs), put(labels)
        teacher_available = put(teacher_available)
    state, out = run_fleet(
        state, xs, labels, cfg, mode=mode,
        teacher_available=teacher_available, chunk=chunk, donate=True,
    )
    if s_pad != s_real:
        out = jax.tree.map(lambda a: a[:, :s_real], out)
    return state, out


class FleetShards(NamedTuple):
    """Explicit shard-local layout: the fleet split into per-block states,
    shard k's blocks resident on mesh device k.

    Where ``shard_fleet`` hands one logical array to GSPMD, this layout
    makes the no-communication structure literal — each block is advanced
    by its own donated block-width dispatch, so a shard's P/beta never
    leave its device and (on cache-starved hosts) each block's working set
    stays L3-resident across the T-tick scan.  The streaming runtime's
    per-shard pending rings (``stream.ShardedStreamSession``) use the same
    row partition.
    """

    states: tuple  # per-block EngineState, block b on its shard's device
    bounds: tuple  # per-block (lo, hi) row window in the padded fleet
    n_pad: int  # dead rows appended to the tail (never surfaced in outputs)


def split_fleet(
    state: EngineState,
    cfg: EngineConfig,
    n_shards: Optional[int] = None,
    block: Optional[int] = None,
    devices=None,
) -> FleetShards:
    """Split a fleet into ``FleetShards``: pad S to a multiple of
    ``n_shards`` (default: the active mesh's fleet-axis size, 1 with no
    mesh), sub-divide each shard into ``block``-stream blocks (default
    ``DEFAULT_STREAM_BLOCK``, capped at the shard width), and place shard
    k's blocks on ``devices[k]`` (default: the active mesh's devices, else
    everything stays on the default device)."""
    if n_shards is None:
        n_shards = sharding.fleet_axis_size()
    if devices is None:
        mesh = sharding.mesh_or_none()
        if mesh is not None:
            devices = list(mesh.devices.flat)
    if devices is not None and len(devices) < n_shards:
        raise ValueError(f"{n_shards} shards > {len(devices)} devices")
    s = jax.tree.leaves(state)[0].shape[0]
    n_pad = (-s) % n_shards
    state = pad_streams(state, cfg, n_pad)
    width = (s + n_pad) // n_shards
    if block is None:
        block = DEFAULT_STREAM_BLOCK
    block = max(1, min(block, width))
    states, bounds = [], []
    for k in range(n_shards):
        dev = devices[k] if devices is not None else None
        lo = k * width
        while lo < (k + 1) * width:
            hi = min(lo + block, (k + 1) * width)
            sub = slice_streams(state, lo, hi)
            if dev is not None:
                sub = jax.device_put(sub, dev)
            states.append(sub)
            bounds.append((lo, hi))
            lo = hi
    return FleetShards(states=tuple(states), bounds=tuple(bounds), n_pad=n_pad)


def merge_fleet(shards: FleetShards) -> EngineState:
    """Reassemble one host-side fleet from shard-local blocks, stripping
    the dead-row padding (checkpoint/inspection path — the hot path never
    gathers)."""
    full = stack_streams([jax.device_get(st) for st in shards.states])
    s = jax.tree.leaves(full)[0].shape[0]
    if shards.n_pad:
        full = slice_streams(full, 0, s - shards.n_pad)
    return full


def run_fleet_shards(
    shards: FleetShards,
    xs: jnp.ndarray,  # (T, S_real, n_in)
    labels: jnp.ndarray,  # (T, S_real) int32
    cfg: EngineConfig,
    mode: str = "algo1",
    teacher_available: Optional[jnp.ndarray] = None,  # (T, S_real) bool
    chunk: Optional[int] = None,
) -> tuple[FleetShards, FleetStepOutput]:
    """Advance every block of a ``FleetShards`` by shard-local donated
    dispatches and restitch the outputs in row order.  Bit-for-bit the
    unsharded ``run_fleet`` at equal S (row independence — see the module
    banner); dead tail rows run with ``teacher_available=False`` and are
    stripped from the outputs.

    Block dispatches are shard-LOCAL (each block's state lives on one
    device), so the whole loop runs under ``sharding.deactivate()`` — a
    caller's multi-device mesh scope must not leak in, or the step's
    ``constrain_fleet`` would demand the full device set for
    single-device operands."""
    t, s_real = xs.shape[0], xs.shape[1]
    if teacher_available is None:
        teacher_available = jnp.ones((t, s_real), jnp.bool_)
    with sharding.deactivate():
        return _run_fleet_shards_body(
            shards, xs, labels, cfg, mode, teacher_available, chunk)


# odlint: shard-local
def _run_fleet_shards_body(
    shards, xs, labels, cfg, mode, teacher_available, chunk
) -> tuple[FleetShards, FleetStepOutput]:
    t, s_real = xs.shape[0], xs.shape[1]
    new_states, outs = [], []
    for st, (lo, hi) in zip(shards.states, shards.bounds):
        dev = None
        leaf = jax.tree.leaves(st)[0]
        if hasattr(leaf, "devices"):
            (dev,) = leaf.devices()
        real_hi = max(lo, min(hi, s_real))  # block may sit wholly in padding
        n_dead = hi - real_hi
        x_b = xs[:, lo:real_hi]
        lab_b = labels[:, lo:real_hi]
        av_b = teacher_available[:, lo:real_hi]
        if n_dead:
            x_b = jnp.concatenate(
                [x_b, jnp.zeros((t, n_dead) + xs.shape[2:], xs.dtype)], axis=1
            )
            lab_b = jnp.concatenate(
                [lab_b, jnp.zeros((t, n_dead), labels.dtype)], axis=1
            )
            av_b = jnp.concatenate(
                [av_b, jnp.zeros((t, n_dead), jnp.bool_)], axis=1
            )
        if dev is not None:
            x_b, lab_b, av_b = (
                jax.device_put(x_b, dev),
                jax.device_put(lab_b, dev),
                jax.device_put(av_b, dev),
            )
        st, out = run_fleet(
            st, x_b, lab_b, cfg, mode=mode,
            teacher_available=av_b, chunk=chunk, donate=True,
        )
        if n_dead:
            keep = out.pred.shape[1] - n_dead
            out = jax.tree.map(lambda a: a[:, :keep], out)
        new_states.append(st)
        outs.append(out)
    merged = jax.tree.map(
        lambda *a: jnp.concatenate([jax.device_get(x) for x in a], axis=1), *outs
    )
    return shards._replace(states=tuple(new_states)), merged
