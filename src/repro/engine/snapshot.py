"""Durable sessions: full-fidelity snapshot/restore of a live StreamSession.

The paper's premise is that on-device learned state is *paid for*: every
teacher query costs mJ-scale communication energy, so a crash that discards
a tenant's trained ``beta``/``P`` throws away real joules.  This module
serializes everything a ``StreamSession`` (``engine/stream.py``) needs to
continue exactly where it stopped:

  * the ``EngineState`` pytree (elm / prune ladder / drift detector / comm
    meter — every leaf, bit-exact through host numpy);
  * the ``PendingRing`` contents, each entry with its plan-time
    ``PlanOutput`` context (h / pred / confidence / theta), its raw
    features (so a fresh teacher connection can *re-ask* it), and its
    ticket id;
  * backpressure-policy state: ``block``'s deferred-ask queue and — via
    the ring entries' ``queried`` masks — ``coalesce``'s in-flight merge
    map (coalesce coverage is derived state: it is exactly the union of
    live ring masks, so restoring the ring restores the merge map);
  * ``StreamStats`` counters and the deterministic latency histogram;
  * the in-flight (dispatched, not yet finished) tick's features and
    ``PlanOutput``;
  * the tick-source cursor (``ticks_consumed``) so a resumable source can
    be repositioned; and
  * the teacher's internal state, when the teacher supports it
    (``snapshot_state()`` / ``restore_snapshot()`` — ``LatencyTeacher``
    does: RNG, ticket counter, undelivered inbox).

Published atomically through ``runtime/checkpoint.py`` — the payload is a
pytree of numpy leaves (plus one JSON metadata leaf), so
``CheckpointManager.save`` gives atomic rename-publish, keep-k GC, and the
crashed-mid-write fallback for free.

Restore guarantee: with a snapshot-capable deterministic teacher, a session
snapshotted at tick k and restored into a fresh process replays the exact
op sequence of the uninterrupted run — final ``EngineState``, outputs, and
accounting are bit-for-bit identical (locked by ``tests/test_snapshot.py``
for every backpressure policy).  With a teacher that cannot be snapshot
(e.g. ``engine.rpc.RpcTeacher`` — sockets do not survive a process), the
in-flight ring entries are either *re-asked* through the fresh teacher
(``pending="reask"``, metered as ``tickets_reasked``; the queries stay
counted once in ``queries_issued`` so the accounting identity is
preserved) or *dropped* (``pending="drop"``, metered as lost).

``engine/durable.py`` is the single-session driver (cadence snapshots +
crash-restart); ``engine/multiplex.py`` wires per-tenant snapshots,
resume, and live tenant migration (quiesce → snapshot → restore into
another multiplexer) on top of these primitives.
"""

from __future__ import annotations

import dataclasses
import json
import time
import zlib
from typing import Callable, Iterable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import drift as drift_mod
from repro.core import labels as labels_mod
from repro.core import oselm, pruning
from repro.engine import fleet, stream
from repro.engine.types import EngineConfig, EngineState
from repro.runtime import telemetry as _telemetry

SNAPSHOT_VERSION = 1

# Wire-format version of encode_snapshot/decode_snapshot frames — bumped
# independently of SNAPSHOT_VERSION (which versions the *tree* semantics).
SNAPSHOT_WIRE_VERSION = 1

# How a restore handles ring entries whose teacher state could not come
# along (socket teachers): re-ask them through the fresh teacher, drop them
# (metered as lost), or pick automatically (restore the teacher when it
# supports snapshots, re-ask otherwise).
PENDING_POLICIES = ("auto", "reask", "drop")


# ---------------------------------------------------------------------------
# Config <-> JSON-able dict
# ---------------------------------------------------------------------------


def config_to_dict(cfg: EngineConfig) -> dict:
    """EngineConfig as a JSON-able dict (tuples become lists)."""
    return {
        "elm": dataclasses.asdict(cfg.elm),
        "prune": dataclasses.asdict(cfg.prune),
        "drift": dataclasses.asdict(cfg.drift),
    }


def config_from_dict(d: dict) -> EngineConfig:
    prune = dict(d["prune"])
    prune["ladder"] = tuple(prune["ladder"])
    return EngineConfig(
        elm=oselm.OSELMConfig(**d["elm"]),
        prune=pruning.PruneConfig(**prune),
        drift=drift_mod.DriftConfig(**d["drift"]),
    )


# ---------------------------------------------------------------------------
# Pytree <-> numpy trees (CheckpointManager restores dicts/lists, not
# NamedTuples, so we serialize by field name and rebuild explicitly)
# ---------------------------------------------------------------------------


def _np_tree(nt) -> dict:
    """NamedTuple of arrays -> {field: host numpy array}.

    ``np.array`` (copy), not ``np.asarray``: on CPU a jax array and its
    numpy view can share memory, and the session keeps dispatching donated
    updates while the checkpoint writer thread serializes this tree — the
    snapshot must own its bytes.
    """
    return {k: np.array(v) for k, v in nt._asdict().items()}


def state_to_tree(state: EngineState) -> dict:
    return {
        "elm": _np_tree(state.elm),
        "prune": _np_tree(state.prune),
        "drift": _np_tree(state.drift),
        "meter": _np_tree(state.meter),
    }


def state_from_tree(tree: dict) -> EngineState:
    def build(cls, d):
        return cls(**{k: jnp.asarray(d[k]) for k in cls._fields})

    return EngineState(
        elm=build(oselm.OSELMState, tree["elm"]),
        prune=build(pruning.PruneState, tree["prune"]),
        drift=build(drift_mod.DriftState, tree["drift"]),
        meter=build(labels_mod.CommMeter, tree["meter"]),
    )


def _plan_to_tree(p: fleet.PlanOutput) -> dict:
    return _np_tree(p)


def _plan_from_tree(d: dict) -> fleet.PlanOutput:
    return fleet.PlanOutput(
        **{k: jnp.asarray(d[k]) for k in fleet.PlanOutput._fields}
    )


def _meta_leaf(meta: dict) -> np.ndarray:
    # One 0-d unicode leaf: np.save/np.load round-trips it without pickle,
    # and arbitrary-precision ints (the PCG64 state) survive via JSON.
    return np.asarray(json.dumps(meta))


def _meta_of(tree: dict) -> dict:
    return json.loads(np.asarray(tree["meta"]).item())


# ---------------------------------------------------------------------------
# Resumable tick sources (the "tick-source cursor" of a snapshot)
# ---------------------------------------------------------------------------


class ResumableTicks:
    """Tick source with a cursor: ``factory(start)`` builds an iterator
    positioned at tick ``start``.  The cursor counts ticks yielded, is
    recorded in every snapshot (``ticks_consumed``), and ``seek`` repoints
    the source for resume — the snapshot subsystem's contract for "the
    stream can be replayed from tick k".
    """

    def __init__(self, factory: Callable[[int], Iterable], start: int = 0):
        self.factory = factory
        self.cursor = start
        self._it = None

    def __iter__(self):
        return self

    def __next__(self):
        if self._it is None:
            self._it = iter(self.factory(self.cursor))
        x = next(self._it)  # StopIteration propagates to the driver
        self.cursor += 1
        return x

    def seek(self, tick: int) -> "ResumableTicks":
        self._it = None
        self.cursor = int(tick)
        return self


def array_ticks(xs) -> ResumableTicks:
    """Resumable view of a materialized (T, S, n_in) array (or list of
    per-tick arrays) — seek is an index, no replay cost."""

    def factory(start):
        for t in range(start, len(xs)):
            yield xs[t]

    return ResumableTicks(factory)


def seek_ticks(ticks, consumed: int) -> None:
    """Reposition a tick source at ``consumed`` ticks for resume; raises if
    the source is a plain iterator (snapshots record the cursor, but only a
    seekable source — ``ResumableTicks`` or anything with ``seek`` — can
    act on it)."""
    seek = getattr(ticks, "seek", None)
    if seek is None:
        raise ValueError(
            "resume needs a seekable tick source (snapshot.ResumableTicks "
            f"or an object with .seek), got {type(ticks).__name__}"
        )
    seek(consumed)


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


def _entry_tree(ent: stream.PendingTicket) -> dict:
    return {
        "tick": np.asarray(ent.tick, np.int64),
        "queried": np.asarray(ent.queried, bool),
        "x": np.asarray(ent.x),
        "plan": _plan_to_tree(ent.plan),
    }


def capture(sess: "stream.StreamSession") -> dict:
    """Serialize a live session to a pytree of numpy leaves + JSON meta.

    The session keeps running afterwards — capture is read-only (it forces
    device→host syncs of the state and any in-flight plan context).  Wall
    time elapsed so far is folded into the captured ``wall_s`` so resumed
    stats keep accumulating from the right total.

    Load signals travel too: ``tick_rate_ema`` and ``ring_occupancy_hwm``
    ride the meta ``stats`` dict like every other scalar, so a migrated
    tenant lands on its new worker with its wall-clock history intact.
    Telemetry trace rings (``runtime/telemetry.py``) deliberately do NOT —
    they are process-local observability, not session state.
    """
    if sess._finished:
        raise RuntimeError("cannot snapshot a finished session")
    tel = _telemetry.TELEMETRY
    tok = tel.tracer.begin("snapshot.save") if tel is not None else None
    stats = sess.stats
    wall_s = stats.wall_s
    if sess._t_start is not None:
        wall_s += time.perf_counter() - sess._t_start
    counters = {
        f.name: getattr(stats, f.name)
        for f in dataclasses.fields(stream.StreamStats)
        if f.name not in ("tick_ms", "label_latency_ticks", "wall_s")
    }
    meta = {
        "version": SNAPSHOT_VERSION,
        "t": sess.t,
        "mode": sess.mode,
        "backpressure": sess.backpressure,
        "capacity": sess.ring.capacity,
        "collect": sess.collect,
        "donate": sess._donate,
        "started": sess.started(),
        "has_pending": sess._p is not None,
        "ticks_consumed": sess.t + (1 if sess._x is not None else 0),
        "s": int(np.shape(np.asarray(sess.state.elm.count))[0]),
        "cfg": config_to_dict(sess.cfg),
        "stats": {**counters, "wall_s": wall_s},
        "ring_tickets": [int(t) for t in sess.ring.tickets()],
        "teacher_snapshot": hasattr(sess.teacher, "snapshot_state"),
    }
    tree: dict = {
        "meta": _meta_leaf(meta),
        "state": state_to_tree(sess.state),
        "ring": [_entry_tree(e) for e in sess.ring.entries()],
        "deferred": [
            {
                "tick": np.asarray(d.tick, np.int64),
                "queried": np.asarray(d.queried, bool),
                "x": np.asarray(d.x),
                "plan": _plan_to_tree(d.plan),
            }
            for d in sess._deferred
        ],
        "stats": {
            "tick_ms": np.asarray(stats.tick_ms, np.float64),
            "label_latency_ticks": np.asarray(
                stats.label_latency_ticks, np.float64
            ),
        },
    }
    if sess._p is not None:
        tree["pending"] = {"x": np.asarray(sess._x), "plan": _plan_to_tree(sess._p)}
    if meta["teacher_snapshot"]:
        tree["teacher"] = sess.teacher.snapshot_state()
    if sess.collect and sess._cols["pred"]:
        tree["collected"] = {
            k: np.stack(v) for k, v in sess._cols.items()
        }
        tree["collected"]["trained"] = np.stack(sess._trained_rows)
    if tok is not None:
        tel.tracer.end(tok, t=sess.t, ring=len(tree["ring"]),
                       **sess.telemetry_labels)
    return tree


def ticks_consumed(tree: dict) -> int:
    """How many ticks the snapshotted session had pulled from its source —
    the cursor a resumed tick source must seek to."""
    return int(_meta_of(tree)["ticks_consumed"])


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------


def restore(
    tree: dict,
    teacher: "stream.Teacher",
    cfg: Optional[EngineConfig] = None,
    ship: Optional[Callable] = None,
    pending: str = "auto",
) -> "stream.StreamSession":
    """Rebuild a ``StreamSession`` from a :func:`capture` tree.

    ``teacher`` is a *fresh* teacher instance (the old object died with its
    process).  If both the snapshot and the teacher support teacher state
    (``restore_snapshot``), the teacher is restored bit-for-bit — in-flight
    tickets will be answered exactly as in the uninterrupted run.
    Otherwise the ring's in-flight entries are handled per ``pending``:
    ``"reask"`` re-submits each one through the fresh teacher (new ticket
    ids, metered as ``tickets_reasked``; their queries remain counted once
    in ``queries_issued``), ``"drop"`` meters them as lost, and ``"auto"``
    picks reask.  Either way the query-accounting identity survives the
    restore.
    """
    if pending not in PENDING_POLICIES:
        raise ValueError(
            f"unknown pending policy {pending!r}; choose one of {PENDING_POLICIES}"
        )
    meta = _meta_of(tree)
    if meta["version"] != SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot version {meta['version']} != supported {SNAPSHOT_VERSION}"
        )
    tel = _telemetry.TELEMETRY
    tok = tel.tracer.begin("snapshot.restore") if tel is not None else None
    if cfg is None:
        cfg = config_from_dict(meta["cfg"])
    sess = stream.StreamSession(
        state_from_tree(tree["state"]),
        cfg,
        teacher,
        mode=meta["mode"],
        capacity=meta["capacity"],
        backpressure=meta["backpressure"],
        collect=meta["collect"],
        donate=meta["donate"],
        ship=ship,
    )
    sess.t = meta["t"]
    sess._t_start = time.perf_counter() if meta["started"] else None

    stats = sess.stats
    for name, value in meta["stats"].items():
        setattr(stats, name, type(getattr(stats, name))(value))
    for x in np.asarray(tree["stats"]["tick_ms"]).tolist():
        stats.tick_ms.append(x)
    for x in np.asarray(tree["stats"]["label_latency_ticks"]).tolist():
        stats.label_latency_ticks.append(x)

    entries = [
        stream.PendingTicket(
            tick=int(np.asarray(e["tick"])),
            queried=np.asarray(e["queried"], bool),
            plan=_plan_from_tree(e["plan"]),
            x=sess.ship(np.asarray(e["x"])),
        )
        for e in tree["ring"]
    ]
    tickets = [int(t) for t in meta["ring_tickets"]]

    restore_fn = getattr(teacher, "restore_snapshot", None)
    if pending == "auto" and "teacher" in tree and restore_fn is not None:
        # Same-host resume: the teacher continues bit-for-bit (RNG, ticket
        # counter, undelivered inbox), so the old ticket ids stay valid.
        restore_fn(tree["teacher"])
        for ticket, ent in zip(tickets, entries):
            sess.ring.push(ticket, ent)
    elif entries and pending != "drop":
        # Fresh teacher: the old tickets mean nothing to it.  Re-ask each
        # in-flight entry (oldest first, original order preserved) with its
        # captured features and origin tick; the plan-time context rides
        # along so the eventual answer is judged exactly as it would have
        # been.  These are new wire asks (tickets_issued) but NOT new
        # decisions (queries_issued unchanged) — the identity holds.
        for ent in entries:
            ticket = teacher.ask(ent.x, ent.queried, ent.tick)
            stats.tickets_issued += 1
            stats.tickets_reasked += 1
            sess.ring.push(ticket, ent)
    elif entries:
        # pending="drop": the in-flight queries can never be answered.
        for ent in entries:
            stats.tickets_lost += 1
            stats.queries_lost += int(ent.queried.sum())

    for d in tree["deferred"]:
        sess._deferred.append(
            stream.DeferredAsk(
                tick=int(np.asarray(d["tick"])),
                x=sess.ship(np.asarray(d["x"])),
                queried=np.asarray(d["queried"], bool),
                plan=_plan_from_tree(d["plan"]),
            )
        )

    if meta["has_pending"]:
        sess._x = sess.ship(np.asarray(tree["pending"]["x"]))
        sess._p = _plan_from_tree(tree["pending"]["plan"])

    if "collected" in tree:
        col = tree["collected"]
        for k in sess._cols:
            sess._cols[k] = [np.array(row) for row in np.asarray(col[k])]
        sess._trained_rows = [np.array(row) for row in np.asarray(col["trained"])]
    if tok is not None:
        tel.tracer.end(tok, t=sess.t, ring=len(entries), pending=pending)
    return sess


# ---------------------------------------------------------------------------
# Wire codec: a snapshot tree as ONE length-prefixed binary frame
# ---------------------------------------------------------------------------
#
# Until now a snapshot only moved in-process (extract -> admit) or through a
# shared checkpoint directory.  The elastic control plane (runtime/worker.py,
# runtime/elastic.py) migrates tenants *between processes over a socket*, so
# the tree needs a wire form.  It reuses the v2 frame conventions of
# engine/rpc.py — [0x02][4-byte LE header length][JSON header][raw payload] —
# with the header carrying the tree structure (runtime.checkpoint's manifest
# encoding) and a per-leaf spec list {path, dtype, shape, length, crc32}; the
# payload is every leaf's C-order bytes concatenated in spec order.  Each
# leaf carries its own zlib.crc32, so a flipped bit anywhere is rejected
# *naming the damaged leaf* instead of restoring a silently-corrupt P.


def encode_snapshot(tree: dict) -> bytes:
    """Serialize a :func:`capture` tree (or any dict/list tree of numpy
    leaves) to one self-delimiting binary frame."""
    from repro.engine import rpc as rpc_mod
    from repro.runtime import checkpoint as ckpt_mod

    specs = []
    chunks = []
    for path, leaf in ckpt_mod._flatten(tree):
        # tobytes() serializes any layout in C order; ascontiguousarray
        # would promote 0-d leaves (the unicode meta) to 1-d and break the
        # bitwise roundtrip.
        arr = np.asarray(leaf)
        buf = arr.tobytes()
        specs.append({
            "path": "/".join(path),
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "len": len(buf),
            "crc": zlib.crc32(buf),
        })
        chunks.append(buf)
    payload = b"".join(chunks)
    header = {
        "kind": "snapshot",
        "wire_version": SNAPSHOT_WIRE_VERSION,
        "payload_len": len(payload),
        "tree": ckpt_mod._manifest_of(tree),
        "leaves": specs,
    }
    return rpc_mod._encode_frame(header, payload)


def decode_snapshot(data: bytes) -> dict:
    """Rebuild the tree from :func:`encode_snapshot` bytes.

    Raises ``ValueError`` on a wrong version byte, a non-snapshot frame, a
    corrupt leaf checksum (naming the leaf), or wire-version mismatch; and
    ``EOFError`` when the buffer ends inside the frame (torn transfer).
    Every returned leaf owns its bytes — restoring from it never aliases
    the caller's buffer.
    """
    from repro.engine import rpc as rpc_mod
    from repro.runtime import checkpoint as ckpt_mod

    if len(data) < 5:
        raise EOFError(
            f"snapshot frame truncated: {len(data)} bytes is shorter than "
            "the [version][header length] preamble"
        )
    if data[0] != rpc_mod.WIRE_V2:
        raise ValueError(
            f"snapshot frame version byte {data[0]:#04x} != v2 "
            f"{rpc_mod.WIRE_V2:#04x} — not a snapshot wire frame"
        )
    hlen = int.from_bytes(data[1:5], "little")
    if len(data) < 5 + hlen:
        raise EOFError(
            f"snapshot frame truncated inside the header (wanted {hlen} "
            f"header bytes, have {len(data) - 5})"
        )
    try:
        header = json.loads(data[5 : 5 + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"corrupt snapshot frame header: {e}") from e
    if not isinstance(header, dict) or header.get("kind") != "snapshot":
        raise ValueError(
            f"frame is not a snapshot (kind={header.get('kind') if isinstance(header, dict) else header!r})"
        )
    if header.get("wire_version") != SNAPSHOT_WIRE_VERSION:
        raise ValueError(
            f"snapshot wire version {header.get('wire_version')} != "
            f"supported {SNAPSHOT_WIRE_VERSION}"
        )
    payload = data[5 + hlen :]
    if len(payload) != int(header["payload_len"]):
        raise EOFError(
            f"snapshot frame truncated in the payload (declared "
            f"{header['payload_len']} bytes, have {len(payload)})"
        )
    leaves = {}
    off = 0
    for spec in header["leaves"]:
        buf = payload[off : off + spec["len"]]
        off += spec["len"]
        if zlib.crc32(buf) != spec["crc"]:
            raise ValueError(
                f"snapshot leaf {spec['path']!r} failed its checksum — "
                "refusing to restore corrupt state"
            )
        arr = np.frombuffer(buf, dtype=np.dtype(spec["dtype"]))
        leaves[spec["path"]] = arr.reshape(spec["shape"]).copy()
    return ckpt_mod._unflatten(leaves, header["tree"])
