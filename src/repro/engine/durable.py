"""Durable session driver: cadence snapshots, resume, crash-restart smoke.

Thin glue over ``engine/snapshot.py`` for the single-tenant case —
``run_durable`` is ``stream.run`` plus a periodic
``CheckpointManager``-published snapshot and a ``resume=True`` path that
restores the latest published snapshot and seeks the tick source to the
recorded cursor.  The multi-tenant equivalents (per-tenant snapshot
directories, ``run_supervised`` crash-restart supervision, live tenant
migration) live in ``engine/multiplex.py``.

This module is also the kill-and-resume proof, runnable standalone::

    PYTHONPATH=src python -m repro.engine.durable --crash-smoke

spawns a child multiplexing two lossy tenants with cadence snapshots,
SIGKILLs it mid-stream once snapshots are published, resumes from the
snapshot directory, and asserts that every tenant completes with the
query-accounting identity intact — the CI smoke for the whole durability
stack (ISSUE 4).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import subprocess
import sys
import tempfile
import time
from typing import Iterable, Optional

import numpy as np

from repro.engine import multiplex, snapshot, stream
from repro.engine.types import EngineConfig, EngineState
from repro.runtime.checkpoint import CheckpointManager


def run_durable(
    state: Optional[EngineState],
    ticks: Iterable,
    cfg: EngineConfig,
    teacher: stream.Teacher,
    snapshot_dir: str,
    snapshot_every: int = 1000,
    resume: bool = False,
    keep: int = 3,
    mode: str = "algo1",
    capacity: int = 64,
    backpressure: str = "drop_oldest",
    collect: bool = True,
    drain: bool = True,
    donate: Optional[bool] = None,
):
    """``stream.run`` with durability: every ``snapshot_every`` ticks the
    session is serialized and published atomically (keep-``keep``) under
    ``snapshot_dir``.  With ``resume=True`` and a published snapshot, the
    run restores it — the tick source must then be seekable
    (``snapshot.ResumableTicks``); a teacher that supports
    ``restore_snapshot`` (e.g. ``LatencyTeacher``) resumes bit-for-bit,
    any other teacher gets the in-flight ring re-asked.

    Returns ``(final state, outputs, stats)`` exactly like ``stream.run``.
    """
    manager = CheckpointManager(snapshot_dir, keep=keep)
    sess = None
    if resume and manager.latest_step() is not None:
        _, tree = manager.restore()
        sess = stream.StreamSession.restore(tree, teacher, cfg=cfg)
        snapshot.seek_ticks(ticks, snapshot.ticks_consumed(tree))
    if sess is None:
        if state is None:
            raise ValueError("no state and no snapshot to resume from")
        sess = stream.StreamSession(
            state, cfg, teacher, mode=mode, capacity=capacity,
            backpressure=backpressure, collect=collect, donate=donate,
        )
    last_snap = sess.t
    it = iter(ticks)
    if not sess.started():
        x0 = next(it, None)
        if x0 is not None:
            sess.start(x0)
    # A started session always has a planned tick pending (``_p``) until the
    # source is exhausted — the same double-buffered drive as ``stream.run``,
    # except it also works for a session restored mid-stream.
    try:
        while sess._p is not None:
            nxt = next(it, None)
            sess.advance(nxt)
            if snapshot_every > 0 and sess.t - last_snap >= snapshot_every:
                manager.save_async(sess.t, sess.snapshot())
                last_snap = sess.t
    finally:
        # Settle any in-flight background write before returning OR before a
        # crash propagates — a restarted attempt must never race an orphaned
        # writer thread for the same step directory.
        manager.wait()
    return sess.finish(drain=drain)


# ---------------------------------------------------------------------------
# Kill-and-resume smoke (CI): two lossy tenants, SIGKILL, resume, reconcile
# ---------------------------------------------------------------------------

_SMOKE_TENANTS = 2
_SMOKE_S = 8
_N_IN, _N_HIDDEN, _N_OUT = 16, 16, 4


def _smoke_cfg() -> EngineConfig:
    from repro.core import drift as drift_mod
    from repro.core import oselm, pruning

    return EngineConfig(
        elm=oselm.OSELMConfig(
            n_in=_N_IN, n_hidden=_N_HIDDEN, n_out=_N_OUT, variant="hash", ridge=1e-2
        ),
        prune=pruning.PruneConfig(min_trained=1_000_000),  # cold: every tick asks
        drift=drift_mod.DriftConfig(),
    )


def _smoke_data(t_len: int, seed: int):
    rng = np.random.default_rng(seed)
    xs = np.tanh(rng.normal(size=(t_len, _SMOKE_S, _N_IN))).astype(np.float32)
    ys = rng.integers(0, _N_OUT, size=(t_len, _SMOKE_S)).astype(np.int32)
    return xs, ys


def _smoke_tenants(t_len: int, tick_sleep_s: float):
    """Fresh tenant list — deterministic across processes (seeded data and
    teachers; resumed teachers restore their RNG from the snapshot)."""
    from repro.engine import fleet

    cfg = _smoke_cfg()
    tenants = []
    for i in range(_SMOKE_TENANTS):
        xs, ys = _smoke_data(t_len, seed=100 + i)

        def factory(start, xs=xs):
            for t in range(start, len(xs)):
                if tick_sleep_s > 0:
                    time.sleep(tick_sleep_s)
                yield xs[t]

        tenants.append(
            multiplex.Tenant(
                name=f"tenant{i}",
                state=fleet.init_fleet(cfg, _SMOKE_S),
                ticks=snapshot.ResumableTicks(factory),
                cfg=cfg,
                teacher=stream.LatencyTeacher(
                    stream.array_labels(ys), latency=2, jitter=2,
                    loss_prob=0.2, partial_prob=0.1, seed=7 + i,
                ),
                mode="train_phase",
                capacity=4,
                backpressure=("drop_oldest", "coalesce")[i % 2],
                collect=False,
            )
        )
    return tenants


def _smoke_run(snapshot_dir: str, ticks: int, snapshot_every: int,
               tick_sleep_s: float, resume: bool) -> dict:
    results, agg = multiplex.run(
        _smoke_tenants(ticks, tick_sleep_s),
        snapshot_dir=snapshot_dir,
        snapshot_every=snapshot_every,
        resume=resume,
    )
    report = {}
    for name, r in sorted(results.items()):
        s = r.stats
        report[name] = {
            "ticks": s.ticks,
            "queries_issued": s.queries_issued,
            "labels_applied": s.labels_applied,
            "queries_lost": s.queries_lost,
            "queries_dropped": s.queries_dropped,
            "queries_coalesced": s.queries_coalesced,
            "tickets_reasked": s.tickets_reasked,
            "reconciled": s.reconciled,
        }
    return report


def _crash_smoke(ticks: int, snapshot_every: int) -> int:
    """Phase 1: child runs slowly with cadence snapshots; parent SIGKILLs it
    once every tenant has a published snapshot.  Phase 2: resume in-process
    from the snapshot directory, run to completion, assert reconciliation."""
    src_root = str(pathlib.Path(__file__).resolve().parents[2])
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory(prefix="durable_smoke_") as d:
        child = subprocess.Popen(
            [sys.executable, "-m", "repro.engine.durable", "--smoke-child",
             "--dir", d, "--ticks", str(ticks),
             "--snapshot-every", str(snapshot_every), "--tick-sleep-ms", "5"],
            env=env,
        )
        try:
            deadline = time.monotonic() + 120.0
            while time.monotonic() < deadline:
                published = [
                    name
                    for name in os.listdir(d)
                    if CheckpointManager(os.path.join(d, name)).latest_step()
                    is not None
                ]
                if len(published) >= _SMOKE_TENANTS:
                    break
                if child.poll() is not None:
                    raise RuntimeError(
                        "smoke child exited before any snapshot was published "
                        f"(rc={child.returncode}) — nothing to kill"
                    )
                time.sleep(0.05)
            else:
                raise RuntimeError("timed out waiting for snapshots")
            child.send_signal(signal.SIGKILL)  # crash mid-stream, mid-anything
            child.wait(timeout=30)
        finally:
            if child.poll() is None:
                child.kill()
                child.wait(timeout=30)
        report = _smoke_run(d, ticks, snapshot_every, tick_sleep_s=0.0, resume=True)
        # odlint: disable=ODL005 -- CI crash-smoke CLI prints its report
        print(json.dumps(report, indent=2))
        for name, r in report.items():
            assert r["reconciled"], f"{name}: accounting broken after resume: {r}"
            assert r["ticks"] == ticks, f"{name}: resumed run incomplete: {r}"
            assert r["labels_applied"] > 0, f"{name}: resumed run never trained"
    # odlint: disable=ODL005 -- CI crash-smoke CLI status line
    print(f"crash smoke OK: {_SMOKE_TENANTS} tenants killed mid-stream, "
          f"resumed from snapshots, accounting reconciled")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--crash-smoke", action="store_true",
                    help="SIGKILL a snapshotting child mid-stream, resume, "
                    "assert the accounting identity reconciles")
    ap.add_argument("--smoke-child", action="store_true",
                    help="(internal) run the lossy multi-tenant workload")
    ap.add_argument("--dir", default=None, help="snapshot directory")
    ap.add_argument("--ticks", type=int, default=400)
    ap.add_argument("--snapshot-every", type=int, default=25)
    ap.add_argument("--tick-sleep-ms", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)
    if args.crash_smoke:
        return _crash_smoke(args.ticks, args.snapshot_every)
    if args.smoke_child:
        assert args.dir, "--smoke-child needs --dir"
        report = _smoke_run(
            args.dir, args.ticks, args.snapshot_every,
            tick_sleep_s=args.tick_sleep_ms / 1000.0, resume=args.resume,
        )
        # odlint: disable=ODL005 -- smoke-child CLI: parent parses stdout
        print(json.dumps(report, indent=2))
        return 0
    ap.error("choose --crash-smoke or --smoke-child")
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
