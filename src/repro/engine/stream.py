"""Streaming async-teacher runtime: Algorithm 1 from a tick iterator.

``run_fleet`` needs the whole stream materialized as one ``(T, S, n_in)``
array with same-tick labels — fine for offline repro, wrong for the paper's
actual deployment story, where each tick arrives once and teacher answers
come back with real latency.  This module is the runtime for that case::

    ticks ──▶ plan (device) ──▶ queried feats ──▶ Teacher.ask ──╮
      ▲                                                         │ latency,
      │  host ingests tick t+1 while the device runs tick t     │ jitter,
      ╰─ learn (device) ◀── PendingRing ◀──── Teacher.poll ◀────╯ loss

Pieces:

* ``Teacher`` protocol — ``ask(feats, mask, tick) -> ticket`` and
  ``poll(tick) -> [TeacherReply]`` (plus ``in_flight()`` so the runtime
  knows when draining is pointless).  ``LatencyTeacher`` implements it with
  a tick-granular latency / jitter / loss / permanent-outage model;
  ``array_labels`` adapts a materialized label array (the paper's protocol,
  where ground truth plays the teacher).
* ``PendingRing`` — fixed-capacity buffer of in-flight tickets holding the
  plan-time features (``h``), prediction, and confidence until the answer
  arrives.  Overflow evicts the oldest ticket (metered), so memory stays
  bounded no matter how laggy the teacher; answers for evicted tickets are
  counted as orphaned and dropped.
* ``run`` — the double-buffered tick loop: the next tick is pulled from the
  iterator and shipped to the device while the current tick's ``plan``
  computes; answered labels apply out of order through the engine's masked
  ``learn``.  Per-tick wall latency and ask→answer label latency are
  recorded in ``StreamStats`` (p50/p95).

With a zero-latency teacher the runtime reproduces ``run_fleet`` outputs
and final state bit-for-bit (locked by ``tests/test_stream.py``): ``plan``
and ``learn`` are the exact two halves of ``fleet_step``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Iterable, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import fleet
from repro.engine.types import EngineConfig, EngineState, FleetStepOutput

# Safety bound on drain polling — a broken Teacher that reports in-flight
# tickets forever must not hang the runtime (serve.py uses it too).
MAX_DRAIN_TICKS = 1_000_000

# Latency distributions keep a sliding window: long-running servers must
# not grow per-tick history without bound (same class of fix as the
# bounded PendingRing and runner LRUs).  p50/p95 reflect recent ticks.
STATS_WINDOW = 4096


class TeacherReply(NamedTuple):
    """One answered ticket.  ``answered`` may be a subset of the asked mask
    (a teacher can answer some streams of a ticket and lose others)."""

    ticket: int
    labels: np.ndarray  # (S,) int32 — valid where ``answered``
    answered: np.ndarray  # (S,) bool


class Teacher(Protocol):
    """Asynchronous label oracle with tick-granular time."""

    def ask(self, feats, mask: np.ndarray, tick: int) -> int:
        """Submit one query batch (feats (S, n_in), mask (S,) bool marks the
        streams actually querying).  Returns a ticket id."""
        ...

    def poll(self, tick: int) -> list[TeacherReply]:
        """Labels that have arrived by ``tick`` (possibly out of order)."""
        ...

    def in_flight(self) -> int:
        """Tickets asked but not yet answered nor lost."""
        ...


# (tick, feats) -> (S,) int32 labels.  ``feats`` may be a device array; only
# pull it to host if the labels actually depend on it.
LabelFn = Callable[[int, object], np.ndarray]


def array_labels(labels) -> LabelFn:
    """Adapt a materialized (T, S) label array to a ``LabelFn`` — the
    paper's evaluation protocol, where ground truth plays the teacher."""
    arr = np.asarray(labels)

    def fn(tick, feats):
        del feats
        return np.asarray(arr[tick], np.int32)

    return fn


@dataclasses.dataclass
class LatencyTeacher:
    """Teacher with a configurable latency / jitter / loss / outage model.

    Each ``ask`` becomes one in-flight ticket answered ``latency`` ticks
    later, plus a uniform per-ticket jitter in [0, jitter] — so with jitter
    > 0 answers arrive out of order.  A ``loss_prob`` fraction of tickets
    is silently lost (never answered), and ``outage_after >= t`` kills
    every ticket asked at or after tick t — the paper's permanent-outage
    fault case ("queries will be retried later or skipped").
    """

    label_fn: LabelFn
    latency: int = 0
    jitter: int = 0
    loss_prob: float = 0.0
    outage_after: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._next_ticket = 0
        # (due_tick, ticket, mask, labels) — labels are computed at ask time
        # so they reflect the tick the query was about.
        self._inbox: list[tuple[int, int, np.ndarray, np.ndarray]] = []

    def ask(self, feats, mask, tick):
        ticket = self._next_ticket
        self._next_ticket += 1
        lost = (
            self.outage_after is not None and tick >= self.outage_after
        ) or (self.loss_prob > 0.0 and self._rng.uniform() < self.loss_prob)
        if not lost:
            due = tick + self.latency
            if self.jitter:
                due += int(self._rng.integers(0, self.jitter + 1))
            labels = np.asarray(self.label_fn(tick, feats), np.int32)
            self._inbox.append((due, ticket, np.asarray(mask, bool), labels))
        return ticket

    def poll(self, tick):
        ready = [e for e in self._inbox if e[0] <= tick]
        if not ready:
            return []
        self._inbox = [e for e in self._inbox if e[0] > tick]
        ready.sort(key=lambda e: (e[0], e[1]))
        return [TeacherReply(ticket=t, labels=lab, answered=m) for _, t, m, lab in ready]

    def in_flight(self):
        return len(self._inbox)


class PendingTicket(NamedTuple):
    """What must survive the teacher round-trip: the plan-time features and
    controller context of one asked tick."""

    tick: int
    queried: np.ndarray  # (S,) bool host copy of the asked mask
    plan: fleet.PlanOutput  # device arrays captured at query time


class PendingRing:
    """Fixed-capacity ordered map ticket -> entry.

    ``push`` evicts and returns the oldest entry when full (the runtime
    meters the drop); ``pop`` of an unknown/evicted ticket returns None.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._slots: "collections.OrderedDict[int, object]" = collections.OrderedDict()

    def __len__(self):
        return len(self._slots)

    def push(self, ticket: int, entry):
        dropped = None
        if len(self._slots) >= self.capacity:
            dropped = self._slots.popitem(last=False)[1]
        self._slots[ticket] = entry
        return dropped

    def pop(self, ticket: int):
        return self._slots.pop(ticket, None)

    def drain(self):
        """Remove and return all entries (oldest first)."""
        out = list(self._slots.values())
        self._slots.clear()
        return out


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


@dataclasses.dataclass
class StreamStats:
    """Counters + latency distributions of one ``run`` (or serving loop)."""

    ticks: int = 0
    stream_steps: int = 0
    tickets_issued: int = 0
    queries_issued: int = 0  # stream-queries (mask sum over all asks)
    labels_applied: int = 0  # stream-labels applied through ``learn``
    tickets_dropped: int = 0  # evicted by ring overflow
    queries_dropped: int = 0
    replies_orphaned: int = 0  # answered after their ticket was evicted
    tickets_lost: int = 0  # never answered (teacher loss / outage)
    wall_s: float = 0.0
    tick_ms: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=STATS_WINDOW)
    )
    label_latency_ticks: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=STATS_WINDOW)
    )

    @property
    def tick_p50_ms(self) -> float:
        return _percentile(self.tick_ms, 50)

    @property
    def tick_p95_ms(self) -> float:
        return _percentile(self.tick_ms, 95)

    @property
    def label_latency_p50(self) -> float:
        return _percentile(self.label_latency_ticks, 50)

    @property
    def label_latency_p95(self) -> float:
        return _percentile(self.label_latency_ticks, 95)

    @property
    def steps_per_s(self) -> float:
        return self.stream_steps / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "stream_steps": self.stream_steps,
            "steps_per_s": self.steps_per_s,
            "tickets_issued": self.tickets_issued,
            "queries_issued": self.queries_issued,
            "labels_applied": self.labels_applied,
            "tickets_dropped": self.tickets_dropped,
            "queries_dropped": self.queries_dropped,
            "replies_orphaned": self.replies_orphaned,
            "tickets_lost": self.tickets_lost,
            "tick_p50_ms": self.tick_p50_ms,
            "tick_p95_ms": self.tick_p95_ms,
            "label_latency_p50": self.label_latency_p50,
            "label_latency_p95": self.label_latency_p95,
            "caches": cache_stats(),
        }


# The per-tick runners take state leaves positionally and return only the
# leaves their half actually writes; the host reassembles the pytree with
# ``_replace`` (zero-copy).  Returning the full EngineState would make XLA
# materialize a fresh copy of every pass-through leaf each tick — P alone
# is S·N²·4 bytes, which at S=1024 dwarfs the tick's real compute.

@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _plan_runner(cfg: EngineConfig, mode: str, donate: bool):
    def run_plan(elm, prune, drift, meter, x):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        new_state, p = fleet.plan(state, x, cfg, mode=mode)
        return (new_state.prune, new_state.drift, new_state.meter), p

    # elm passes through plan untouched (and stays live on the host side),
    # so only the replaced controller leaves are donation candidates.
    return jax.jit(run_plan, donate_argnums=(1, 2, 3) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _learn_runner(cfg: EngineConfig, donate: bool):
    def run_learn(elm, prune, drift, meter, h, labels, pred, conf, mask, controller_on,
                  theta):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        new_state = fleet.learn(
            state, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        return new_state.elm, new_state.prune

    return jax.jit(run_learn, donate_argnums=(0, 1) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _learn_plan_runner(cfg: EngineConfig, mode: str, donate: bool):
    """Steady-state fused tick: apply one reply's labels, then plan the next
    tick, in a single dispatch.  Halves per-tick dispatch overhead and lets
    XLA fuse across the learn→plan boundary — the same fusion ``run_fleet``
    gets inside its scan — so the zero-latency stream keeps pace with it.
    """

    def run_learn_plan(
        elm, prune, drift, meter, h, labels, pred, conf, mask, controller_on, theta,
        x_next
    ):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        state = fleet.learn(
            state, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        new_state, p = fleet.plan(state, x_next, cfg, mode=mode)
        return (new_state.elm, new_state.prune, new_state.drift, new_state.meter), p

    return jax.jit(run_learn_plan, donate_argnums=(0, 1, 2, 3) if donate else ())


def cache_stats() -> dict:
    """Hit/miss counters for every compiled-runner cache in the engine."""
    out = dict(fleet.runner_cache_info())
    for name, fn in (
        ("plan_runner", _plan_runner),
        ("learn_runner", _learn_runner),
        ("learn_plan_runner", _learn_plan_runner),
    ):
        info = fn.cache_info()
        out[name] = {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.currsize,
            "maxsize": info.maxsize,
        }
    return out


def run(
    state: EngineState,
    ticks: Iterable,  # yields (S, n_in) feature arrays, one per tick
    cfg: EngineConfig,
    teacher: Teacher,
    mode: str = "algo1",
    capacity: int = 64,
    collect: bool = True,
    drain: bool = True,
    donate: Optional[bool] = None,
    stats: Optional[StreamStats] = None,
) -> tuple[EngineState, Optional[FleetStepOutput], StreamStats]:
    """Drive the engine from a tick iterator with an asynchronous teacher.

    Per tick: dispatch ``plan`` (device), ingest + ship the *next* tick
    while it runs (double buffering), then submit the queried features to
    ``teacher.ask`` and apply any answers ``teacher.poll`` returns through
    ``learn`` — out of order, against the features captured at query time.
    Pending tickets live in a ``capacity``-slot ring; overflow drops the
    oldest.  After the iterator is exhausted, answers still in flight are
    drained (``drain=True``) so no late label is silently discarded.

    Returns ``(final state, outputs, stats)``.  ``outputs`` mirrors
    ``run_fleet``'s stacked (T, S) ``FleetStepOutput`` (host arrays;
    ``trained`` marks label-application ticks) — or None when
    ``collect=False`` (long-running servers should not accumulate history)
    or the iterator was empty.

    ``donate`` (default True) lets every per-tick dispatch update P/beta
    and the controller leaves in place instead of allocating fresh buffers
    (P is the dominant one at S·N²·4 bytes/tick).  The runtime first takes
    ownership of ``state`` with a one-time copy, so the caller's pytree
    stays valid either way.
    """
    if donate is None:
        donate = True
    # Off-CPU, ship the next tick to the device eagerly so the transfer
    # overlaps the in-flight dispatch; on CPU the eager path is pure Python
    # overhead (~0.5 ms/call) and pjit's native conversion is far cheaper.
    ship = (lambda a: a) if jax.default_backend() == "cpu" else jax.device_put
    if donate:
        # Own the buffers we are about to donate tick after tick; the
        # caller's state must survive the run.
        state = jax.tree.map(jnp.copy, state)
    plan_fn = _plan_runner(cfg, mode, donate)
    learn_fn = _learn_runner(cfg, donate)
    fused_fn = _learn_plan_runner(cfg, mode, donate)
    ring = PendingRing(capacity)
    if stats is None:
        stats = StreamStats()
    cols: dict[str, list] = {
        k: [] for k in ("pred", "outputs", "queried", "theta", "confidence", "mode_training")
    }
    trained_rows: list[np.ndarray] = []

    full_mask_dev: list = [None]  # cached device-side all-True apply mask

    def _claim(reply: TeacherReply, now: int):
        """Resolve a reply against the ring; returns (plan, learn args) or
        None, with all drop/orphan accounting applied."""
        ent = ring.pop(reply.ticket)
        if ent is None:
            stats.replies_orphaned += 1
            return None
        mask = ent.queried & np.asarray(reply.answered, bool)
        n = int(mask.sum())
        if n == 0:
            # The teacher answered the ticket but covered none of its asked
            # streams — those queries are gone for good; meter the ticket as
            # lost so queries_issued stays reconcilable against
            # applied + dropped + lost.
            stats.tickets_lost += 1
            return None
        stats.labels_applied += n
        stats.label_latency_ticks.append(now - ent.tick)
        if collect and ent.tick < len(trained_rows):
            trained_rows[ent.tick] |= mask
        if n == mask.shape[0]:
            # Steady state (everyone queried, everyone answered): reuse one
            # device-resident mask instead of a fresh upload per tick.
            if full_mask_dev[0] is None or full_mask_dev[0].shape != mask.shape:
                full_mask_dev[0] = jnp.ones(mask.shape, jnp.bool_)
            mask_dev = full_mask_dev[0]
        else:
            mask_dev = jnp.asarray(mask)
        p = ent.plan
        return (
            p.h,
            ship(np.asarray(reply.labels, np.int32)),
            p.pred,
            p.confidence,
            mask_dev,
            p.controller_on,
            p.theta,
        )

    def _learn(state, args):
        new_elm, new_prune = learn_fn(
            state.elm, state.prune, state.drift, state.meter, *args
        )
        return state._replace(elm=new_elm, prune=new_prune)

    it = iter(ticks)
    nxt = next(it, None)
    t = 0
    t_start = time.perf_counter()
    p = None
    if nxt is not None:
        # First tick: nothing pending yet, plain plan dispatch.
        nxt = ship(nxt)
        (new_prune, new_drift, new_meter), p = plan_fn(
            state.elm, state.prune, state.drift, state.meter, nxt
        )
        state = state._replace(prune=new_prune, drift=new_drift, meter=new_meter)
    while nxt is not None:
        x = nxt
        t0 = time.perf_counter()
        # Double buffering: pull tick t+1 from the iterator and ship it to
        # the device while the device is busy with tick t's plan.
        nxt = next(it, None)
        if nxt is not None:
            nxt = ship(nxt)
        queried_host = np.asarray(p.queried)  # host syncs on tick t here
        if collect:
            for k in cols:
                cols[k].append(np.asarray(getattr(p, k)))
            trained_rows.append(np.zeros(queried_host.shape, bool))
        n_q = int(queried_host.sum())
        if n_q:
            ticket = teacher.ask(x, queried_host, t)
            stats.tickets_issued += 1
            stats.queries_issued += n_q
            dropped = ring.push(ticket, PendingTicket(t, queried_host, p))
            if dropped is not None:
                stats.tickets_dropped += 1
                stats.queries_dropped += int(dropped.queried.sum())
        applies = [a for a in (_claim(r, t) for r in teacher.poll(t)) if a is not None]
        if nxt is not None:
            # Steady state: fuse the last reply's learn with the next tick's
            # plan into one dispatch (earlier replies, if any, apply first,
            # so all of tick t's answers land before tick t+1 is planned).
            if applies:
                for args in applies[:-1]:
                    state = _learn(state, args)
                (elm2, prune2, drift2, meter2), p = fused_fn(
                    state.elm, state.prune, state.drift, state.meter,
                    *applies[-1], nxt,
                )
                state = EngineState(elm=elm2, prune=prune2, drift=drift2, meter=meter2)
            else:
                (new_prune, new_drift, new_meter), p = plan_fn(
                    state.elm, state.prune, state.drift, state.meter, nxt
                )
                state = state._replace(
                    prune=new_prune, drift=new_drift, meter=new_meter
                )
        else:
            for args in applies:
                state = _learn(state, args)
        stats.ticks += 1
        stats.stream_steps += int(x.shape[0])
        stats.tick_ms.append((time.perf_counter() - t0) * 1e3)
        t += 1

    if drain:
        drained = 0
        while len(ring) and teacher.in_flight() > 0 and drained < MAX_DRAIN_TICKS:
            for reply in teacher.poll(t):
                args = _claim(reply, t)
                if args is not None:
                    state = _learn(state, args)
            t += 1
            drained += 1
    lost = ring.drain()
    stats.tickets_lost += len(lost)
    stats.wall_s += time.perf_counter() - t_start

    outs = None
    if collect and cols["pred"]:
        outs = FleetStepOutput(
            pred=np.stack(cols["pred"]),
            outputs=np.stack(cols["outputs"]),
            queried=np.stack(cols["queried"]),
            trained=np.stack(trained_rows),
            theta=np.stack(cols["theta"]),
            confidence=np.stack(cols["confidence"]),
            mode_training=np.stack(cols["mode_training"]),
        )
    return state, outs, stats
