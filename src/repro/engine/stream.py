"""Streaming async-teacher runtime: Algorithm 1 from a tick iterator.

``run_fleet`` needs the whole stream materialized as one ``(T, S, n_in)``
array with same-tick labels — fine for offline repro, wrong for the paper's
actual deployment story, where each tick arrives once and teacher answers
come back with real latency.  This module is the runtime for that case::

    ticks ──▶ plan (device) ──▶ queried feats ──▶ Teacher.ask ──╮
      ▲                                                         │ latency,
      │  host ingests tick t+1 while the device runs tick t     │ jitter,
      ╰─ learn (device) ◀── PendingRing ◀──── Teacher.poll ◀────╯ loss

Pieces:

* ``Teacher`` protocol — ``ask(feats, mask, tick) -> ticket`` and
  ``poll(tick) -> [TeacherReply]`` (plus ``in_flight()`` so the runtime
  knows when draining is pointless).  ``LatencyTeacher`` implements it with
  a tick-granular latency / jitter / loss / partial-answer /
  permanent-outage model; ``array_labels`` adapts a materialized label
  array (the paper's protocol, where ground truth plays the teacher);
  ``engine.rpc.RpcTeacher`` implements the same protocol over a real TCP
  socket with wall-clock timeout → loss mapping.
* ``PendingRing`` — fixed-capacity buffer of in-flight tickets holding the
  plan-time features (``h``), prediction, and confidence until the answer
  arrives.  What happens when it saturates is a pluggable *backpressure
  policy* (``BACKPRESSURE_POLICIES``):

  - ``drop_oldest`` (default) — evict the oldest in-flight ticket, metered;
    its late answer is counted as orphaned.
  - ``drop_newest`` — refuse the new ask; the tick's queries are dropped.
  - ``block``      — defer the ask to a later tick: the plan context waits
    in a bounded host-side queue and is submitted as ring slots free up
    (FIFO, so ask order is preserved).
  - ``coalesce``   — a stream that re-queries while it already has a query
    in flight is merged into that in-flight ticket (no duplicate teacher
    traffic; the in-flight answer settles the decision it belongs to);
    only the uncovered remainder is asked, evicting the oldest on
    overflow.

* ``StreamSession`` — one stream's (one *tenant's*) runtime as an
  explicit state machine: ``start(x0)`` dispatches the first plan,
  ``advance(next_tick)`` finishes the current tick (ask → poll → learn,
  fused with the next tick's plan), ``finish()`` drains and returns
  ``(state, outputs, stats)``.  ``run`` drives a single session;
  ``engine.multiplex`` interleaves many sessions — with per-tenant
  configs, teachers, rings, and backpressure — over one process, sharing
  the bounded compiled-runner LRUs below.
* ``run`` — the double-buffered tick loop: the next tick is pulled from the
  iterator and shipped to the device while the current tick's ``plan``
  computes; answered labels apply out of order through the engine's masked
  ``learn``.  Per-tick wall latency and ask→answer label latency are
  recorded in ``StreamStats`` (p50/p95).

Query accounting reconciles exactly: every stream-query the plan decided
to issue ends in exactly one of ``labels_applied`` (answer applied),
``queries_dropped`` (backpressure victim), ``queries_lost`` (teacher loss,
outage, timeout, or partial-answer residue), or ``queries_coalesced``
(merged into an in-flight ticket; zero unless the policy is
``coalesce``) — ``StreamStats.reconciled`` states the identity.

With a zero-latency teacher the runtime reproduces ``run_fleet`` outputs
and final state bit-for-bit (locked by ``tests/test_stream.py``): ``plan``
and ``learn`` are the exact two halves of ``fleet_step``.

Sessions are durable: ``StreamSession.snapshot()`` serializes the whole
runtime state (engine pytree, ring + plan-time contexts, policy state,
stats, tick cursor, teacher state when supported) and ``restore`` resumes
it bit-for-bit — see ``engine/snapshot.py`` and ``tests/test_snapshot.py``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import json
import time
from typing import Callable, Iterable, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding
from repro.engine import fleet
from repro.engine.types import EngineConfig, EngineState, FleetStepOutput
from repro.runtime import telemetry as _telemetry

# Safety bound on drain polling — a broken Teacher that reports in-flight
# tickets forever must not hang the runtime (serve.py uses it too).
MAX_DRAIN_TICKS = 1_000_000

# Sleep between empty drain polls while replies are still in flight.
# Tick-granular teachers (LatencyTeacher) resolve by tick count, so this
# costs at most a few ms per drain; wall-clock teachers (RpcTeacher) need
# the drain to wait out real network latency without busy-spinning a core
# — and without burning through MAX_DRAIN_TICKS before the reply (or its
# timeout) can land.
DRAIN_IDLE_SLEEP_S = 200e-6

# Latency distributions keep a sliding window: long-running servers must
# not grow per-tick history without bound (same class of fix as the
# bounded PendingRing and runner LRUs).  p50/p95 reflect recent ticks.
STATS_WINDOW = 4096

# Smoothing of StreamStats.tick_rate_ema — a load signal, not an accounting
# counter, so responsiveness beats precision.
TICK_RATE_EMA_ALPHA = 0.1

# Pluggable pending-ring saturation policies (see module docstring).
BACKPRESSURE_POLICIES = ("drop_oldest", "drop_newest", "block", "coalesce")


class TeacherReply(NamedTuple):
    """One answered ticket.  ``answered`` may be a subset of the asked mask
    (a teacher can answer some streams of a ticket and lose others)."""

    ticket: int
    labels: np.ndarray  # (S,) int32 — valid where ``answered``
    answered: np.ndarray  # (S,) bool


class Teacher(Protocol):
    """Asynchronous label oracle with tick-granular time."""

    def ask(self, feats, mask: np.ndarray, tick: int) -> int:
        """Submit one query batch (feats (S, n_in), mask (S,) bool marks the
        streams actually querying).  ``tick`` is the tick the query is
        *about* — the current tick, except for asks the ``block`` policy
        deferred, which keep their origin tick.  Returns a ticket id."""
        ...

    def poll(self, tick: int) -> list[TeacherReply]:
        """Labels that have arrived by ``tick`` (possibly out of order)."""
        ...

    def in_flight(self) -> int:
        """Tickets asked but not yet answered nor lost."""
        ...


# (tick, feats) -> (S,) int32 labels.  ``feats`` may be a device array; only
# pull it to host if the labels actually depend on it.
LabelFn = Callable[[int, object], np.ndarray]


def array_labels(labels) -> LabelFn:
    """Adapt a materialized (T, S) label array to a ``LabelFn`` — the
    paper's evaluation protocol, where ground truth plays the teacher."""
    arr = np.asarray(labels)

    def fn(tick, feats):
        del feats
        return np.asarray(arr[tick], np.int32)

    return fn


@dataclasses.dataclass
class LatencyTeacher:
    """Teacher with a configurable latency / jitter / loss / outage model.

    Each ``ask`` becomes one in-flight ticket answered ``latency`` ticks
    later, plus a uniform per-ticket jitter in [0, jitter] — so with jitter
    > 0 answers arrive out of order.  A ``loss_prob`` fraction of tickets
    is silently lost (never answered), ``partial_prob`` drops each asked
    *stream* from its reply independently (a partially answered ticket —
    the residue is metered as ``queries_lost``), and ``outage_after >= t``
    kills every ticket asked at or after tick t — the paper's permanent-
    outage fault case ("queries will be retried later or skipped").
    """

    label_fn: LabelFn
    latency: int = 0
    jitter: int = 0
    loss_prob: float = 0.0
    partial_prob: float = 0.0
    outage_after: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._next_ticket = 0
        # (due_tick, ticket, mask, labels) — labels are computed at ask time
        # so they reflect the tick the query was about.
        self._inbox: list[tuple[int, int, np.ndarray, np.ndarray]] = []

    def ask(self, feats, mask, tick):
        ticket = self._next_ticket
        self._next_ticket += 1
        lost = (
            self.outage_after is not None and tick >= self.outage_after
        ) or (self.loss_prob > 0.0 and self._rng.uniform() < self.loss_prob)
        if not lost:
            due = tick + self.latency
            if self.jitter:
                due += int(self._rng.integers(0, self.jitter + 1))
            answered = np.asarray(mask, bool)
            if self.partial_prob > 0.0:
                keep = self._rng.uniform(size=answered.shape) >= self.partial_prob
                answered = answered & keep
            labels = np.asarray(self.label_fn(tick, feats), np.int32)
            self._inbox.append((due, ticket, answered, labels))
        return ticket

    def poll(self, tick):
        ready = [e for e in self._inbox if e[0] <= tick]
        if not ready:
            return []
        self._inbox = [e for e in self._inbox if e[0] > tick]
        ready.sort(key=lambda e: (e[0], e[1]))
        return [TeacherReply(ticket=t, labels=lab, answered=m) for _, t, m, lab in ready]

    def in_flight(self):
        return len(self._inbox)

    # -- snapshot support (engine/snapshot.py) -----------------------------

    def snapshot_state(self) -> dict:
        """Full teacher state as a numpy/JSON tree: RNG, ticket counter, and
        the undelivered inbox — restoring it makes a resumed run answer
        bit-for-bit like the uninterrupted one.  ``label_fn`` is NOT
        serialized; the restoring process reconstructs the teacher with the
        same label source before calling ``restore_snapshot``."""
        meta = {
            "kind": "latency",
            "next_ticket": self._next_ticket,
            "rng": self._rng.bit_generator.state,  # JSON-able (arbitrary ints)
        }
        return {
            "meta": np.asarray(json.dumps(meta, default=int)),
            "inbox": [
                {
                    "due": np.asarray(due, np.int64),
                    "ticket": np.asarray(ticket, np.int64),
                    "answered": np.asarray(answered, bool),
                    "labels": np.asarray(labels, np.int32),
                }
                for due, ticket, answered, labels in self._inbox
            ],
        }

    def restore_snapshot(self, tree: dict) -> None:
        meta = json.loads(np.asarray(tree["meta"]).item())
        self._next_ticket = int(meta["next_ticket"])
        self._rng.bit_generator.state = meta["rng"]
        self._inbox = [
            (
                int(np.asarray(e["due"])),
                int(np.asarray(e["ticket"])),
                np.asarray(e["answered"], bool),
                np.asarray(e["labels"], np.int32),
            )
            for e in tree["inbox"]
        ]


class PendingTicket(NamedTuple):
    """What must survive the teacher round-trip: the plan-time features and
    controller context of one asked tick.  ``x`` (the raw tick features)
    rides along so a snapshot restored against a *fresh* teacher connection
    can re-ask the in-flight queries (engine/snapshot.py); the ring is
    bounded, so this holds at most ``capacity`` extra (S, n_in) buffers."""

    tick: int
    queried: np.ndarray  # (S,) bool host copy of the asked mask
    plan: fleet.PlanOutput  # device arrays captured at query time
    x: object  # the tick's raw features (whatever the iterator yielded)


class PlanSlice:
    """Lazy row-window view of a cohort's full-width ``fleet.PlanOutput``.

    Cohort fusion (``engine/cohort.py``) plans all members of a cohort in
    one stacked dispatch; each member session's current plan and ring
    entries then hold a ``PlanSlice`` instead of a solo-width
    ``PlanOutput``.  Attribute access slices the full plan lazily
    (device-side), and ``_asdict`` matches the NamedTuple protocol, so the
    solo drain, snapshot (``snapshot._plan_to_tree``), and patch-learn
    paths treat it exactly like a ``PlanOutput``.  ``materialize()`` turns
    it into a real solo-width ``PlanOutput`` (detaching from the cohort).
    """

    __slots__ = ("full", "lo", "hi")

    def __init__(self, full: fleet.PlanOutput, lo: int, hi: int):
        self.full = full
        self.lo = lo
        self.hi = hi

    def __getattr__(self, name):
        # Only reached for names not in __slots__ — i.e. PlanOutput fields.
        return getattr(self.full, name)[self.lo : self.hi]

    def _asdict(self):
        return {
            k: getattr(self.full, k)[self.lo : self.hi]
            for k in fleet.PlanOutput._fields
        }

    def materialize(self) -> fleet.PlanOutput:
        return fleet.PlanOutput(**self._asdict())


class DeferredAsk(NamedTuple):
    """A ``block``-policy ask waiting for a free ring slot."""

    tick: int
    x: object  # the tick's features (whatever the iterator yielded)
    queried: np.ndarray  # (S,) bool
    plan: fleet.PlanOutput


class PendingRing:
    """Fixed-capacity ordered map ticket -> entry.

    ``push`` evicts and returns the oldest entry when full (the runtime
    meters the drop); ``pop`` of an unknown/evicted ticket returns None.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._slots: "collections.OrderedDict[int, object]" = collections.OrderedDict()

    def __len__(self):
        return len(self._slots)

    def full(self) -> bool:
        return len(self._slots) >= self.capacity

    def push(self, ticket: int, entry):
        dropped = None
        if len(self._slots) >= self.capacity:
            dropped = self._slots.popitem(last=False)[1]
        self._slots[ticket] = entry
        return dropped

    def pop(self, ticket: int):
        return self._slots.pop(ticket, None)

    def entries(self):
        """Live entries, oldest first (read-only view for coverage scans)."""
        return self._slots.values()

    def tickets(self):
        """Live ticket ids, oldest first (snapshot serialization)."""
        return self._slots.keys()

    def drain(self):
        """Remove and return all entries (oldest first)."""
        out = list(self._slots.values())
        self._slots.clear()
        return out


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


@dataclasses.dataclass
class StreamStats:
    """Counters + latency distributions of one ``run`` (or serving loop).

    Query accounting (stream-queries, i.e. mask sums): every query the plan
    decided to issue lands in exactly one terminal bucket, so
    ``queries_issued == labels_applied + queries_dropped + queries_lost +
    queries_coalesced`` always holds (``reconciled``).  With any policy but
    ``coalesce`` the last term is zero and the identity is the three-term
    one from ISSUE 3.
    """

    ticks: int = 0
    stream_steps: int = 0
    tickets_issued: int = 0  # teacher.ask calls actually made
    queries_issued: int = 0  # stream-queries the plan decided to issue
    labels_applied: int = 0  # stream-labels applied through ``learn``
    tickets_dropped: int = 0  # evicted / refused / expired by backpressure
    queries_dropped: int = 0
    replies_orphaned: int = 0  # answered after their ticket was evicted
    tickets_lost: int = 0  # never answered (teacher loss / outage / timeout)
    queries_lost: int = 0  # incl. the residue of partially answered tickets
    tickets_coalesced: int = 0  # asks merged (at least partly) into in-flight
    queries_coalesced: int = 0  # stream-queries settled by an in-flight ticket
    asks_deferred: int = 0  # ``block``: asks that waited for a ring slot
    tickets_reasked: int = 0  # in-flight tickets re-submitted after a restore
    wall_s: float = 0.0
    # Load signals for the elastic router (runtime/elastic.py): a wall-clock
    # EMA of the tick rate (ticks/s — NOT deterministic, excluded from
    # parity comparisons) and the pending ring's high-water occupancy (a
    # teacher that can't keep up shows here before queries start dropping).
    # Both travel in snapshots (engine/snapshot.py meta "stats") so a
    # migrated tenant keeps its wall-clock history — while the process-local
    # telemetry trace ring (runtime/telemetry.py) intentionally does NOT:
    # spans recorded on the source worker stay on the source, and parity
    # tests exclude both the EMA and the tracer accordingly
    # (tests/test_telemetry.py locks these restore semantics).
    tick_rate_ema: float = 0.0
    ring_occupancy_hwm: int = 0
    tick_ms: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=STATS_WINDOW)
    )
    label_latency_ticks: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=STATS_WINDOW)
    )

    @property
    def tick_p50_ms(self) -> float:
        return _percentile(self.tick_ms, 50)

    @property
    def tick_p95_ms(self) -> float:
        return _percentile(self.tick_ms, 95)

    @property
    def label_latency_p50(self) -> float:
        return _percentile(self.label_latency_ticks, 50)

    @property
    def label_latency_p95(self) -> float:
        return _percentile(self.label_latency_ticks, 95)

    @property
    def steps_per_s(self) -> float:
        return self.stream_steps / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def reconciled(self) -> bool:
        """The query-accounting identity (see class docstring)."""
        return self.queries_issued == (
            self.labels_applied
            + self.queries_dropped
            + self.queries_lost
            + self.queries_coalesced
        )

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "stream_steps": self.stream_steps,
            "steps_per_s": self.steps_per_s,
            "tickets_issued": self.tickets_issued,
            "queries_issued": self.queries_issued,
            "labels_applied": self.labels_applied,
            "tickets_dropped": self.tickets_dropped,
            "queries_dropped": self.queries_dropped,
            "replies_orphaned": self.replies_orphaned,
            "tickets_lost": self.tickets_lost,
            "queries_lost": self.queries_lost,
            "tickets_coalesced": self.tickets_coalesced,
            "queries_coalesced": self.queries_coalesced,
            "asks_deferred": self.asks_deferred,
            "tickets_reasked": self.tickets_reasked,
            "queries_reconciled": self.reconciled,
            "tick_rate_ema": self.tick_rate_ema,
            "ring_occupancy_hwm": self.ring_occupancy_hwm,
            "tick_p50_ms": self.tick_p50_ms,
            "tick_p95_ms": self.tick_p95_ms,
            "label_latency_p50": self.label_latency_p50,
            "label_latency_p95": self.label_latency_p95,
            "caches": cache_stats(),
        }


# The per-tick runners take state leaves positionally and return only the
# leaves their half actually writes; the host reassembles the pytree with
# ``_replace`` (zero-copy).  Returning the full EngineState would make XLA
# materialize a fresh copy of every pass-through leaf each tick — P alone
# is S·N²·4 bytes, which at S=1024 dwarfs the tick's real compute.
#
# The lru_caches are keyed on (cfg, mode, donate), so *tenants* of the
# multiplexer (engine/multiplex.py) that share a config share the same
# compiled executable — the whole point of multiplexing fleets over one
# process instead of one process per fleet.

@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _plan_runner(cfg: EngineConfig, mode: str, donate: bool):
    def run_plan(elm, prune, drift, meter, x):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        new_state, p = fleet.plan(state, x, cfg, mode=mode)
        return (new_state.prune, new_state.drift, new_state.meter), p

    # elm passes through plan untouched (and stays live on the host side),
    # so only the replaced controller leaves are donation candidates.
    return jax.jit(run_plan, donate_argnums=(1, 2, 3) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _learn_runner(cfg: EngineConfig, donate: bool):
    def run_learn(elm, prune, drift, meter, h, labels, pred, conf, mask, controller_on,
                  theta):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        new_state = fleet.learn(
            state, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        return new_state.elm, new_state.prune

    return jax.jit(run_learn, donate_argnums=(0, 1) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _learn_plan_runner(cfg: EngineConfig, mode: str, donate: bool):
    """Steady-state fused tick: apply one reply's labels, then plan the next
    tick, in a single dispatch.  Halves per-tick dispatch overhead and lets
    XLA fuse across the learn→plan boundary — the same fusion ``run_fleet``
    gets inside its scan — so the zero-latency stream keeps pace with it.
    """

    def run_learn_plan(
        elm, prune, drift, meter, h, labels, pred, conf, mask, controller_on, theta,
        x_next
    ):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        state = fleet.learn(
            state, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        new_state, p = fleet.plan(state, x_next, cfg, mode=mode)
        return (new_state.elm, new_state.prune, new_state.drift, new_state.meter), p

    return jax.jit(run_learn_plan, donate_argnums=(0, 1, 2, 3) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _plan_avail_runner(cfg: EngineConfig, mode: str, donate: bool):
    """``_plan_runner`` with an explicit ``teacher_available`` vector.

    Used by sessions carrying dead padding rows (``live < S`` in a sharded
    session's tail shard): padded rows plan with ``avail=False`` so they
    never query, never learn, and never touch the teacher — while every
    shard's dispatch keeps the same (padded) width and therefore shares one
    compiled executable."""

    def run_plan(elm, prune, drift, meter, x, avail):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        new_state, p = fleet.plan(state, x, cfg, mode=mode, teacher_available=avail)
        return (new_state.prune, new_state.drift, new_state.meter), p

    return jax.jit(run_plan, donate_argnums=(1, 2, 3) if donate else ())


@functools.lru_cache(maxsize=fleet.RUNNER_CACHE_SIZE)
def _learn_plan_avail_runner(cfg: EngineConfig, mode: str, donate: bool):
    """``_learn_plan_runner`` with an explicit ``teacher_available`` vector
    for the planned next tick (see ``_plan_avail_runner``)."""

    def run_learn_plan(
        elm, prune, drift, meter, h, labels, pred, conf, mask, controller_on, theta,
        x_next, avail
    ):
        state = EngineState(elm=elm, prune=prune, drift=drift, meter=meter)
        state = fleet.learn(
            state, h, labels, pred, conf, mask, controller_on, cfg, theta=theta
        )
        new_state, p = fleet.plan(
            state, x_next, cfg, mode=mode, teacher_available=avail
        )
        return (new_state.elm, new_state.prune, new_state.drift, new_state.meter), p

    return jax.jit(run_learn_plan, donate_argnums=(0, 1, 2, 3) if donate else ())


def cache_stats() -> dict:
    """Hit/miss counters for every compiled-runner cache in the engine."""
    out = dict(fleet.runner_cache_info())
    for name, fn in (
        ("plan_runner", _plan_runner),
        ("learn_runner", _learn_runner),
        ("learn_plan_runner", _learn_plan_runner),
        ("plan_avail_runner", _plan_avail_runner),
        ("learn_plan_avail_runner", _learn_plan_avail_runner),
    ):
        info = fn.cache_info()
        out[name] = {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.currsize,
            "maxsize": info.maxsize,
        }
    return out


def _default_ship():
    # Off-CPU, ship the next tick to the device eagerly so the transfer
    # overlaps the in-flight dispatch; on CPU the eager path is pure Python
    # overhead (~0.5 ms/call) and pjit's native conversion is far cheaper.
    return (lambda a: a) if jax.default_backend() == "cpu" else jax.device_put


class StreamSession:
    """One stream's (one tenant's) async-teacher runtime as a state machine.

    Lifecycle::

        sess = StreamSession(state, cfg, teacher, ...)
        sess.start(x0)          # dispatch the first tick's plan
        sess.advance(x1)        # finish tick 0 (ask/poll/learn), plan tick 1
        ...
        sess.advance(None)      # finish the last tick (no next plan)
        state, outs, stats = sess.finish()   # drain + accounting + outputs

    ``run`` drives exactly this sequence for a single session;
    ``engine.multiplex.run`` interleaves many sessions round-robin so N
    tenants share one process (and, via the bounded runner LRUs, one
    compiled executable per distinct ``(cfg, mode, donate)``).  Because the
    per-tenant op sequence is identical either way, a multiplexed tenant
    reproduces its solo ``run`` bit-for-bit.

    ``backpressure`` picks the ring-saturation policy (see module
    docstring / ``BACKPRESSURE_POLICIES``).
    """

    def __init__(
        self,
        state: EngineState,
        cfg: EngineConfig,
        teacher: Teacher,
        mode: str = "algo1",
        capacity: int = 64,
        backpressure: str = "drop_oldest",
        collect: bool = True,
        donate: Optional[bool] = None,
        stats: Optional[StreamStats] = None,
        ship: Optional[Callable] = None,
        live: Optional[int] = None,
    ):
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"unknown backpressure policy {backpressure!r}; "
                f"choose one of {BACKPRESSURE_POLICIES}"
            )
        if donate is None:
            donate = True
        if donate:
            # Own the buffers we are about to donate tick after tick; the
            # caller's state must survive the run.
            state = jax.tree.map(jnp.copy, state)
        self._donate = donate
        self.state = state
        self.cfg = cfg
        self.teacher = teacher
        self.mode = mode
        self.backpressure = backpressure
        self.collect = collect
        self.stats = stats if stats is not None else StreamStats()
        self.ring = PendingRing(capacity)
        self.ship = ship if ship is not None else _default_ship()
        # ``live``: only the first ``live`` rows are real streams — the tail
        # is dead padding (a sharded session's S-rounding, see
        # ShardedStreamSession).  Dead rows plan with teacher_available=False
        # (never query/learn) and are excluded from ``stream_steps``.
        self.live = None if live is not None and live >= jax.tree.leaves(state)[0].shape[0] else live
        self._avail = None  # device (S,) bool, built lazily at start()
        if self.live is None:
            self._plan_fn = _plan_runner(cfg, mode, donate)
            self._fused_fn = _learn_plan_runner(cfg, mode, donate)
        else:
            plan_raw = _plan_avail_runner(cfg, mode, donate)
            fused_raw = _learn_plan_avail_runner(cfg, mode, donate)
            self._plan_fn = lambda *a: plan_raw(*a, self._avail)
            self._fused_fn = lambda *a: fused_raw(*a, self._avail)
        self._learn_fn = _learn_runner(cfg, donate)
        # ``block``: asks waiting for a ring slot (bounded like the ring;
        # overflow drops the oldest deferred ask, metered).
        self._deferred: "collections.deque[DeferredAsk]" = collections.deque()
        self._cols: dict[str, list] = {
            k: []
            for k in ("pred", "outputs", "queried", "theta", "confidence",
                      "mode_training")
        }
        self._trained_rows: list[np.ndarray] = []
        self._full_mask_dev = None  # cached device-side all-True apply mask
        self._x = None  # current tick's features (plan dispatched, not asked)
        self._p = None  # current tick's PlanOutput
        self.t = 0
        self._t_start: Optional[float] = None
        self._finished = False
        # Telemetry label set for this session's registry series / spans
        # ({tenant, worker, shard, ...}); owners (multiplexer, sharded
        # session, worker) fill it in.  Purely observational — never read
        # on the compute path.
        self.telemetry_labels: dict = {}

    # -- lifecycle ---------------------------------------------------------

    def started(self) -> bool:
        return self._t_start is not None

    # odlint: shard-local
    def start(self, x0) -> None:
        """Dispatch the first tick's plan (nothing pending yet)."""
        assert not self.started(), "session already started"
        self._t_start = time.perf_counter()
        if self.live is not None and self._avail is None:
            self._avail = jnp.arange(int(np.shape(x0)[0])) < self.live
        x0 = self.ship(x0)
        (new_prune, new_drift, new_meter), p = self._plan_fn(
            self.state.elm, self.state.prune, self.state.drift, self.state.meter, x0
        )
        self.state = self.state._replace(
            prune=new_prune, drift=new_drift, meter=new_meter
        )
        self._x, self._p = x0, p

    # odlint: shard-local
    def advance(self, nxt) -> None:
        """Finish the current tick (ask → poll → learn) and plan ``nxt``.

        ``nxt`` is the next tick's features (shipped here) or None when the
        iterator is exhausted — the learn of any same-tick replies then runs
        unfused.  Mirrors one iteration of the double-buffered ``run`` loop.
        """
        x, p = self._x, self._p
        assert p is not None, "advance() before start()"
        t = self.t
        t0 = time.perf_counter()
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("stream.tick") if tel is not None else None
        if nxt is not None:
            nxt = self.ship(nxt)
        queried_host = np.asarray(p.queried)  # host syncs on tick t here
        if self.collect:
            for k in self._cols:
                self._cols[k].append(np.asarray(getattr(p, k)))
            self._trained_rows.append(np.zeros(queried_host.shape, bool))
        n_q = int(queried_host.sum())
        if n_q:
            # Decision-time metering: the comm meter already charged these
            # queries inside plan; every one of them must end in exactly one
            # of applied / dropped / lost / coalesced.
            self.stats.queries_issued += n_q
            self._submit(x, queried_host, p, t)
        applies = [
            a
            for a in (self._claim(r, t) for r in self.teacher.poll(t))
            if a is not None
        ]
        # Replies just freed ring slots: submit deferred (``block``) asks.
        self._flush_deferred(t)
        if nxt is not None:
            # Steady state: fuse the last reply's learn with the next tick's
            # plan into one dispatch (earlier replies, if any, apply first,
            # so all of tick t's answers land before tick t+1 is planned).
            if applies:
                for args in applies[:-1]:
                    self._learn(args)
                (elm2, prune2, drift2, meter2), p_next = self._fused_fn(
                    self.state.elm, self.state.prune, self.state.drift,
                    self.state.meter, *applies[-1], nxt,
                )
                self.state = EngineState(
                    elm=elm2, prune=prune2, drift=drift2, meter=meter2
                )
            else:
                (new_prune, new_drift, new_meter), p_next = self._plan_fn(
                    self.state.elm, self.state.prune, self.state.drift,
                    self.state.meter, nxt
                )
                self.state = self.state._replace(
                    prune=new_prune, drift=new_drift, meter=new_meter
                )
        else:
            for args in applies:
                self._learn(args)
            p_next = None
        self.stats.ticks += 1
        self.stats.stream_steps += (
            self.live if self.live is not None else int(np.shape(x)[0])
        )
        tick_s = time.perf_counter() - t0
        self.stats.tick_ms.append(tick_s * 1e3)
        if tick_s > 0:
            rate = 1.0 / tick_s
            ema = self.stats.tick_rate_ema
            self.stats.tick_rate_ema = (
                rate if ema == 0.0 else ema + TICK_RATE_EMA_ALPHA * (rate - ema)
            )
        self.t += 1
        self._x, self._p = nxt, p_next
        if tok is not None:
            tel.tracer.end(tok, t=t, queries=n_q, **self.telemetry_labels)

    def drain_replies(
        self,
        max_ticks: int = MAX_DRAIN_TICKS,
        idle_sleep_s: float = DRAIN_IDLE_SLEEP_S,
    ) -> bool:
        """Wait out in-flight replies after the tick source is exhausted.

        Polls while *either* the ring still holds tickets *or* the teacher
        still has replies in flight — a reply whose ticket was evicted must
        still be polled so ``replies_orphaned`` meters it (polling only
        while both held silently discarded those).  Deferred (``block``)
        asks keep flushing as slots free up.  Stops as soon as nothing more
        can ever arrive.

        Returns True when the ``max_ticks`` budget ran out with work
        possibly still in flight (the caller may resume — the multiplexer
        drains one bounded slice per scheduler round), False when the
        drain is complete.
        """
        drained = 0
        while len(self.ring) or self._deferred or self.teacher.in_flight() > 0:
            if drained >= max_ticks:
                return True
            replies = self._poll_and_apply()
            self._flush_deferred(self.t)
            self.t += 1
            drained += 1
            if self.teacher.in_flight() == 0 and not replies:
                # A threaded teacher (RpcTeacher) may resolve a ticket
                # *between* the poll above and the in_flight check — the
                # reply is already pollable even though in-flight just hit
                # zero.  Poll once more before concluding nothing can ever
                # arrive; only then are ring leftovers lost for good.
                if not self._poll_and_apply():
                    break
            elif not replies and idle_sleep_s > 0:
                time.sleep(idle_sleep_s)
        return False

    def quiesce(
        self,
        max_ticks: int = 4096,
        idle_sleep_s: float = DRAIN_IDLE_SLEEP_S,
    ) -> bool:
        """Migration quiesce: wait out in-flight replies *without* advancing
        the session's tick clock.  ``drain_replies`` is for an exhausted
        tick source and lets ``t`` run on; a live migration happens
        mid-stream, where ``t`` must keep matching the tick source after
        the move.  Polls at a virtual time horizon, applies every answer
        that arrives (so it does not have to travel in the snapshot), then
        restores ``t``.  Returns True when the ring fully quiesced —
        anything left is either lost (lossy teacher) or must be re-asked
        by the restore (``engine.snapshot``)."""
        t0 = self.t
        try:
            self.drain_replies(max_ticks=max_ticks, idle_sleep_s=idle_sleep_s)
        finally:
            self.t = t0
        return not len(self.ring)

    def pending_queries(self) -> int:
        """Stream-queries issued but not yet settled: asked tickets still
        in the ring plus ``block``-deferred asks.  With it the accounting
        identity closes at *any* instant — ``queries_issued ==
        labels_applied + queries_dropped + queries_lost +
        queries_coalesced + pending_queries()`` — which is what makes a
        live mid-run scrape (runtime/worker.py ``metrics``) checkable."""
        n = sum(int(ent.queried.sum()) for ent in self.ring.entries())
        n += sum(int(d.queried.sum()) for d in self._deferred)
        return n

    def sync_telemetry(self) -> None:
        """Mirror this session's ``StreamStats`` into the enabled registry
        (no-op when telemetry is disabled).  Called at ``finish()`` and by
        live scrapes; never on the per-tick path."""
        tel = _telemetry.TELEMETRY
        if tel is not None:
            _telemetry.sync_stream_stats(
                tel.registry, self.stats, pending=self.pending_queries(),
                **self.telemetry_labels
            )

    def _poll_and_apply(self) -> list[TeacherReply]:
        replies = self.teacher.poll(self.t)
        for reply in replies:
            args = self._claim(reply, self.t)
            if args is not None:
                self._learn(args)
        return replies

    # odlint: shard-local
    def finish(
        self, drain: bool = True
    ) -> tuple[EngineState, Optional[FleetStepOutput], StreamStats]:
        """Drain, settle terminal accounting, and build stacked outputs."""
        assert self._p is None, "finish() with a planned tick still pending"
        if self._finished:
            raise RuntimeError("session already finished")
        self._finished = True
        if drain:
            self.drain_replies()
        for ent in self.ring.drain():
            self.stats.tickets_lost += 1
            self.stats.queries_lost += int(ent.queried.sum())
        for d in self._deferred:
            # ``block`` asks that never got a slot: the queries never hit
            # the wire — backpressure dropped them.
            self.stats.tickets_dropped += 1
            self.stats.queries_dropped += int(d.queried.sum())
        self._deferred.clear()
        if self._t_start is not None:
            self.stats.wall_s += time.perf_counter() - self._t_start
        self.sync_telemetry()
        outs = None
        if self.collect and self._cols["pred"]:
            outs = FleetStepOutput(
                pred=np.stack(self._cols["pred"]),
                outputs=np.stack(self._cols["outputs"]),
                queried=np.stack(self._cols["queried"]),
                trained=np.stack(self._trained_rows),
                theta=np.stack(self._cols["theta"]),
                confidence=np.stack(self._cols["confidence"]),
                mode_training=np.stack(self._cols["mode_training"]),
            )
        return self.state, outs, self.stats

    # -- durability (engine/snapshot.py) -----------------------------------

    def snapshot(self) -> dict:
        """Full-fidelity serialization of this session: EngineState, ring
        contents with their plan-time context, backpressure-policy state
        (deferred asks; coalesce coverage is the ring masks), stats, the
        in-flight tick, the tick-source cursor, and — when the teacher
        supports it — the teacher's own state.  The returned tree is numpy
        leaves + one JSON meta leaf: hand it to
        ``runtime.checkpoint.CheckpointManager.save`` for atomic keep-k
        publication.  The session keeps running."""
        from repro.engine import snapshot as snapshot_mod

        return snapshot_mod.capture(self)

    @classmethod
    def restore(
        cls,
        tree: dict,
        teacher: Teacher,
        cfg=None,
        ship: Optional[Callable] = None,
        pending: str = "auto",
    ) -> "StreamSession":
        """Rebuild a session from ``snapshot()``'s tree (see
        ``engine.snapshot.restore`` for the pending-ticket policies).  The
        caller repositions the tick source at
        ``engine.snapshot.ticks_consumed(tree)`` and resumes driving
        ``advance``; under a deterministic snapshot-capable teacher the
        resumed run is bit-for-bit the uninterrupted one."""
        from repro.engine import snapshot as snapshot_mod

        return snapshot_mod.restore(tree, teacher, cfg=cfg, ship=ship, pending=pending)

    # -- internals ---------------------------------------------------------

    def _ask(self, x, queried: np.ndarray, p, t: int):
        """One actual teacher.ask + ring push (evicting oldest, metered)."""
        ticket = self.teacher.ask(x, queried, t)
        self.stats.tickets_issued += 1
        dropped = self.ring.push(ticket, PendingTicket(t, queried, p, x))
        self.stats.ring_occupancy_hwm = max(
            self.stats.ring_occupancy_hwm, len(self.ring)
        )
        if dropped is not None:
            self.stats.tickets_dropped += 1
            self.stats.queries_dropped += int(dropped.queried.sum())
            tel = _telemetry.TELEMETRY
            if tel is not None:
                tel.tracer.event(
                    "ring.evict", t=t, evicted_tick=dropped.tick,
                    queries=int(dropped.queried.sum()), **self.telemetry_labels
                )

    def _submit(self, x, queried: np.ndarray, p, t: int) -> None:
        """Route one tick's decided queries through the backpressure policy."""
        policy = self.backpressure
        if policy == "coalesce":
            # Streams already covered by an in-flight ticket are merged into
            # it: the in-flight answer settles the decision it belongs to,
            # and no duplicate query hits the wire.
            entries = list(self.ring.entries())  # oldest first
            cover = np.zeros_like(queried)
            for ent in entries:
                cover |= ent.queried
            rest = queried & ~cover
            if rest.any() and self.ring.full() and entries:
                # The residual ask below will evict the oldest in-flight
                # ticket, so its coverage can no longer settle anything:
                # streams only it covered must ride the new ticket, not be
                # credited as coalesced against a ticket that is about to
                # become an orphan.
                cover = np.zeros_like(queried)
                for ent in entries[1:]:
                    cover |= ent.queried
                rest = queried & ~cover
            merged = queried & cover
            n_m = int(merged.sum())
            if n_m:
                self.stats.tickets_coalesced += 1
                self.stats.queries_coalesced += n_m
            if rest.any():
                self._ask(x, rest, p, t)
            return
        if policy == "drop_newest" and self.ring.full():
            self.stats.tickets_dropped += 1
            self.stats.queries_dropped += int(queried.sum())
            return
        if policy == "block" and (self.ring.full() or self._deferred):
            # FIFO: never let a new ask jump a deferred one.
            self.stats.asks_deferred += 1
            self._deferred.append(DeferredAsk(t, x, queried, p))
            if len(self._deferred) > self.ring.capacity:
                d = self._deferred.popleft()
                self.stats.tickets_dropped += 1
                self.stats.queries_dropped += int(d.queried.sum())
            return
        self._ask(x, queried, p, t)

    def _flush_deferred(self, now: int) -> None:
        del now
        while self._deferred and not self.ring.full():
            d = self._deferred.popleft()
            # Ask with the ORIGIN tick — the tick the query is about — so
            # the ring entry marks the right `trained` row, label latency
            # meters end-to-end from the decision, and a ground-truth
            # teacher (array_labels) looks up the right tick's labels.
            self._ask(d.x, d.queried, d.plan, d.tick)

    def _claim_entry(self, reply: TeacherReply, now: int):
        """Accounting half of a reply claim: resolve the ticket against the
        ring with all drop/orphan/loss metering and trained-row marking.
        Returns ``(entry, mask)`` — the ring entry and the host-side apply
        mask — or None when nothing is applicable.  ``_claim`` composes
        this with ``_build_learn_args``; the cohort engine
        (``engine/cohort.py``) uses the halves separately so it can scatter
        many members' masks into one full-width fused learn."""
        stats = self.stats
        ent = self.ring.pop(reply.ticket)
        if ent is None:
            stats.replies_orphaned += 1
            return None
        asked = int(ent.queried.sum())
        mask = ent.queried & np.asarray(reply.answered, bool)
        n = int(mask.sum())
        if n == 0:
            # The teacher answered the ticket but covered none of its asked
            # streams — those queries are gone for good; meter the ticket
            # and every one of its queries as lost so the accounting
            # identity holds.
            stats.tickets_lost += 1
            stats.queries_lost += asked
            return None
        stats.labels_applied += n
        # Partial answer: the unanswered residue of this ticket will never
        # get labels — meter it now, at the only moment it is knowable.
        stats.queries_lost += asked - n
        stats.label_latency_ticks.append(now - ent.tick)
        if self.collect and ent.tick < len(self._trained_rows):
            self._trained_rows[ent.tick] |= mask
        return ent, mask

    def _build_learn_args(self, ent: PendingTicket, reply: TeacherReply,
                          mask: np.ndarray):
        """Device half of a reply claim: package one claimed reply as
        ``_learn_fn`` args (plan-time context + shipped labels + mask)."""
        n = int(mask.sum())
        if n == mask.shape[0]:
            # Steady state (everyone queried, everyone answered): reuse one
            # device-resident mask instead of a fresh upload per tick.
            if self._full_mask_dev is None or self._full_mask_dev.shape != mask.shape:
                self._full_mask_dev = jnp.ones(mask.shape, jnp.bool_)
            mask_dev = self._full_mask_dev
        else:
            mask_dev = jnp.asarray(mask)
        p = ent.plan
        return (
            p.h,
            self.ship(np.asarray(reply.labels, np.int32)),
            p.pred,
            p.confidence,
            mask_dev,
            p.controller_on,
            p.theta,
        )

    def _claim(self, reply: TeacherReply, now: int):
        """Resolve a reply against the ring; returns learn args or None,
        with all drop/orphan/loss accounting applied."""
        claimed = self._claim_entry(reply, now)
        if claimed is None:
            return None
        ent, mask = claimed
        return self._build_learn_args(ent, reply, mask)

    def _learn(self, args) -> None:
        new_elm, new_prune = self._learn_fn(
            self.state.elm, self.state.prune, self.state.drift, self.state.meter,
            *args
        )
        self.state = self.state._replace(elm=new_elm, prune=new_prune)


def run(
    state: EngineState,
    ticks: Iterable,  # yields (S, n_in) feature arrays, one per tick
    cfg: EngineConfig,
    teacher: Teacher,
    mode: str = "algo1",
    capacity: int = 64,
    backpressure: str = "drop_oldest",
    collect: bool = True,
    drain: bool = True,
    donate: Optional[bool] = None,
    stats: Optional[StreamStats] = None,
) -> tuple[EngineState, Optional[FleetStepOutput], StreamStats]:
    """Drive the engine from a tick iterator with an asynchronous teacher.

    Per tick: dispatch ``plan`` (device), ingest + ship the *next* tick
    while it runs (double buffering), then submit the queried features to
    ``teacher.ask`` and apply any answers ``teacher.poll`` returns through
    ``learn`` — out of order, against the features captured at query time.
    Pending tickets live in a ``capacity``-slot ring; saturation behavior
    is the pluggable ``backpressure`` policy (``BACKPRESSURE_POLICIES``;
    default drop-oldest).  After the iterator is exhausted, answers still
    in flight are drained (``drain=True``) so no late label is silently
    discarded.

    Returns ``(final state, outputs, stats)``.  ``outputs`` mirrors
    ``run_fleet``'s stacked (T, S) ``FleetStepOutput`` (host arrays;
    ``trained`` marks label-application ticks) — or None when
    ``collect=False`` (long-running servers should not accumulate history)
    or the iterator was empty.

    ``donate`` (default True) lets every per-tick dispatch update P/beta
    and the controller leaves in place instead of allocating fresh buffers
    (P is the dominant one at S·N²·4 bytes/tick).  The runtime first takes
    ownership of ``state`` with a one-time copy, so the caller's pytree
    stays valid either way.
    """
    sess = StreamSession(
        state, cfg, teacher, mode=mode, capacity=capacity,
        backpressure=backpressure, collect=collect, donate=donate, stats=stats,
    )
    it = iter(ticks)
    nxt = next(it, None)
    if nxt is not None:
        sess.start(nxt)
        while nxt is not None:
            # Double buffering: pull tick t+1 from the iterator (and ship it
            # inside advance) while the device is busy with tick t's plan.
            nxt = next(it, None)
            sess.advance(nxt)
    return sess.finish(drain=drain)


# ---------------------------------------------------------------------------
# Mesh-sharded streaming: per-shard sessions with shard-local pending rings.
# ---------------------------------------------------------------------------


class ShardedStreamSession:
    """N shard-local ``StreamSession``s advanced in lockstep over row
    windows of one full-width tick source.

    Everything per-tick is shard-local: shard k's ``EngineState`` rows live
    on device k (the active mesh's devices, or wherever ``devices`` says);
    its plan/learn dispatches, pending ring, backpressure state, and
    teacher connection cover only rows ``[k*width, (k+1)*width)``; and a
    label learns back only into the shard that planned the query — steady-
    state label application is N independent masked shard-width learns,
    never a full-width gather/scatter.  Host ingestion hands each shard a
    row-slice view of the incoming tick (zero-copy for unpadded shards)
    instead of staging any full-width buffer.  Per-shard query accounting
    reconciles shard-locally (``stats_summary()["per_shard"]``), which is
    how tests lock the no-cross-shard-traffic property.

    ``teachers`` is one ``Teacher`` per shard, or a factory
    ``shard_idx -> Teacher``; a shard's replies route to its own ring by
    construction.  For a shared remote teacher host, hand every shard a
    tenant handle of one ``rpc.BatchedRpcClient`` — shard asks then
    coalesce into batched frames on one socket without breaking shard
    locality (the demux is per-handle).

    S is padded up to a multiple of ``n_shards`` with *metered dead rows*
    at the tail: dead rows plan with ``teacher_available=False`` (never
    query, never learn, excluded from ``stream_steps``), every shard's
    dispatch keeps the same padded width — so all shards share one
    compiled runner per (cfg, mode, donate) — and ``finish()`` strips the
    padding from the merged state/outputs.  Bit-for-bit parity with the
    unsharded ``run`` at equal S under a deterministic lossless teacher is
    locked by tests/test_mesh_fleet.py.
    """

    def __init__(
        self,
        state: EngineState,
        cfg: EngineConfig,
        teachers,
        n_shards: Optional[int] = None,
        mode: str = "algo1",
        capacity: int = 64,
        backpressure: str = "drop_oldest",
        collect: bool = True,
        donate: Optional[bool] = None,
        devices=None,
    ):
        if n_shards is None:
            n_shards = sharding.fleet_axis_size()
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if devices is None:
            mesh = sharding.mesh_or_none()
            if mesh is not None:
                devices = list(mesh.devices.flat)
        if devices is not None and len(devices) < n_shards:
            raise ValueError(f"{n_shards} shards > {len(devices)} devices")
        if callable(teachers):
            teachers = [teachers(k) for k in range(n_shards)]
        teachers = list(teachers)
        if len(teachers) != n_shards:
            raise ValueError(
                f"need one teacher per shard: {len(teachers)} != {n_shards}"
            )
        s = int(jax.tree.leaves(state)[0].shape[0])
        self.s_real = s
        self.n_shards = n_shards
        self.n_pad = (-s) % n_shards
        self.width = (s + self.n_pad) // n_shards
        self.bounds = [
            (k * self.width, (k + 1) * self.width) for k in range(n_shards)
        ]
        padded = fleet.pad_streams(state, cfg, self.n_pad)
        self.sessions: list[StreamSession] = []
        for k, (lo, hi) in enumerate(self.bounds):
            sub = fleet.slice_streams(padded, lo, hi)
            if devices is not None:
                sub = jax.device_put(sub, devices[k])
            live = min(self.width, max(0, s - lo))
            self.sessions.append(
                StreamSession(
                    sub, cfg, teachers[k], mode=mode, capacity=capacity,
                    backpressure=backpressure, collect=collect, donate=donate,
                    live=live,
                )
            )
            self.sessions[-1].telemetry_labels = {"shard": str(k)}
        self._zeros = None  # shared immutable tick slice for fully-dead shards

    def _shard_tick(self, x: np.ndarray, k: int):
        lo, hi = self.bounds[k]
        if hi <= self.s_real:
            return x[lo:hi]  # view, no copy
        shape = (self.width,) + x.shape[1:]
        if lo >= self.s_real:
            if self._zeros is None or self._zeros.shape != shape or self._zeros.dtype != x.dtype:
                self._zeros = np.zeros(shape, x.dtype)
            return self._zeros
        # Tail shard with live + dead rows: fresh buffer per tick — the
        # previous tick's staged rows may still be referenced by the
        # session (the ask happens on the *next* advance) and by ring
        # tickets, so an in-place staging buffer would corrupt them.
        buf = np.zeros(shape, x.dtype)
        buf[: self.s_real - lo] = x[lo:]
        return buf

    def started(self) -> bool:
        return self.sessions[0].started()

    # Per-shard dispatches are shard-LOCAL (each session's operands live
    # on one device), so they must not inherit a caller's multi-device
    # mesh scope — under it ``constrain_fleet`` would demand the full
    # device set.  ``sharding.deactivate()`` makes the constraint the
    # identity for the duration of the shard calls.

    def start(self, x0) -> None:
        x0 = np.asarray(x0)
        with sharding.deactivate():
            for k, sess in enumerate(self.sessions):
                sess.start(self._shard_tick(x0, k))

    def advance(self, nxt) -> None:
        nxt = None if nxt is None else np.asarray(nxt)
        with sharding.deactivate():
            for k, sess in enumerate(self.sessions):
                sess.advance(None if nxt is None else self._shard_tick(nxt, k))

    def finish(
        self, drain: bool = True
    ) -> tuple[EngineState, Optional[FleetStepOutput], list[StreamStats]]:
        """Drain every shard, merge states/outputs in row order (stripping
        the dead-row padding), and return the per-shard stats list
        (``aggregate_stats`` folds it into one summary)."""
        states, outs, stats = [], [], []
        with sharding.deactivate():
            for sess in self.sessions:
                st, o, sstats = sess.finish(drain=drain)
                states.append(jax.device_get(st))
                outs.append(o)
                stats.append(sstats)
        merged = fleet.stack_streams(states)
        if self.n_pad:
            merged = fleet.slice_streams(merged, 0, self.s_real)
        out = None
        if outs and all(o is not None for o in outs):
            out = jax.tree.map(lambda *a: np.concatenate(a, axis=1), *outs)
            if self.n_pad:
                out = jax.tree.map(lambda a: a[:, : self.s_real], out)
        return merged, out, stats

    def stats_summary(self) -> dict:
        return aggregate_stats(
            [s.stats for s in self.sessions], padded_streams=self.n_pad
        )


def aggregate_stats(stats_list: list, padded_streams: int = 0) -> dict:
    """Fold per-shard ``StreamStats`` into one fleet-wide summary.

    Counters sum; latency percentiles pool the shard windows; the
    accounting identity must hold *per shard* (a reply can only settle a
    query its own shard issued), so ``queries_reconciled`` is the AND —
    any cross-shard leak shows up as one shard over- and another
    under-counting."""
    counters = (
        "stream_steps", "tickets_issued", "queries_issued", "labels_applied",
        "tickets_dropped", "queries_dropped", "replies_orphaned",
        "tickets_lost", "queries_lost", "tickets_coalesced",
        "queries_coalesced", "asks_deferred", "tickets_reasked",
    )
    out = {k: sum(getattr(s, k) for s in stats_list) for k in counters}
    out["ticks"] = max((s.ticks for s in stats_list), default=0)
    out["wall_s"] = max((s.wall_s for s in stats_list), default=0.0)
    out["steps_per_s"] = (
        out["stream_steps"] / out["wall_s"] if out["wall_s"] > 0 else 0.0
    )
    tick_ms = [v for s in stats_list for v in s.tick_ms]
    lab = [v for s in stats_list for v in s.label_latency_ticks]
    out["tick_p50_ms"] = _percentile(tick_ms, 50)
    out["tick_p95_ms"] = _percentile(tick_ms, 95)
    out["label_latency_p50"] = _percentile(lab, 50)
    out["label_latency_p95"] = _percentile(lab, 95)
    out["queries_reconciled"] = all(s.reconciled for s in stats_list)
    out["padded_streams"] = padded_streams
    out["n_shards"] = len(stats_list)
    out["per_shard"] = [s.summary() for s in stats_list]
    return out


def run_sharded(
    state: EngineState,
    ticks: Iterable,  # yields full-width (S, n_in) feature arrays
    cfg: EngineConfig,
    teachers,  # one Teacher per shard, or factory shard_idx -> Teacher
    n_shards: Optional[int] = None,
    mode: str = "algo1",
    capacity: int = 64,
    backpressure: str = "drop_oldest",
    collect: bool = True,
    drain: bool = True,
    donate: Optional[bool] = None,
    devices=None,
) -> tuple[EngineState, Optional[FleetStepOutput], list[StreamStats]]:
    """``run`` over a mesh-sharded fleet: the stream axis splits into
    ``n_shards`` shard-local sessions (default: the active mesh's fleet
    axis), each with its own pending ring and teacher — see
    ``ShardedStreamSession``.  Returns ``(final state, outputs, per-shard
    stats)`` with state/outputs already merged back to full (unpadded)
    width."""
    sess = ShardedStreamSession(
        state, cfg, teachers, n_shards=n_shards, mode=mode, capacity=capacity,
        backpressure=backpressure, collect=collect, donate=donate,
        devices=devices,
    )
    it = iter(ticks)
    nxt = next(it, None)
    if nxt is not None:
        sess.start(nxt)
        while nxt is not None:
            nxt = next(it, None)
            sess.advance(nxt)
    return sess.finish(drain=drain)
