"""Multi-tenant stream multiplexer: N independent fleets in one process.

The paper's deployment story is many edge devices sharing one teacher-side
host: each tenant is an independent fleet — its own ``EngineConfig``,
``EngineState``, tick source, ``Teacher``, pending-query ring, and
backpressure policy — but they all run in a single process, sharing the
engine's bounded compiled-runner LRUs (``stream._plan_runner`` /
``_learn_runner`` / ``_learn_plan_runner`` and ``fleet._chunk_runner``).
Tenants with the same ``(cfg, mode, donate)`` therefore share one compiled
executable: adding a tenant with a config already being served costs no
compile and no extra executable memory.

Cohort fusion (``fuse=True``, the default) goes one step further: tenants
that also share a stream width are packed into *cohorts*
(``engine/cohort.py``) whose ``EngineState`` pytrees stack along the
leading stream axis, so one fused stacked dispatch per tick advances the
whole cohort instead of one dispatch per tenant — eliminating the
tick-switch cache penalty entirely at high tenant counts.  Each tenant
keeps its own ring / teacher / backpressure / stats / tick cursor, and
per-tenant results stay bit-for-bit identical to the unfused run (locked
by ``tests/test_cohort.py``); ``fuse=False`` restores the one-dispatch-
per-tenant scheduler.

Scheduling (``sched``):

* ``"rr"`` (default) — round-robin with a ``quantum``-tick time slice:
  each tenant's ``StreamSession`` advances by up to ``quantum``
  plan/ask/poll/learn cycles before the scheduler moves on (switching
  every tick would evict the tenant's state from cache on every switch).
* ``"drr"`` — deficit round robin in *stream-step* (cost) units: every
  round each live tenant's deficit grows by the same credit
  (``quantum × min S``) and one tick debits that tenant's own S, so a
  tenant's share of device time is equal regardless of its size — an
  S=512 tenant runs ~1 tick for every 32 ticks of an S=16 tenant instead
  of head-of-line blocking it for ``quantum`` huge ticks.  Unspent credit
  carries over, so big tenants lose no throughput, only burstiness.

Because a session's per-tenant op sequence does not depend on what the
scheduler interleaves around it, a multiplexed tenant reproduces its solo
``stream.run`` bit-for-bit under either scheduler at any quantum (locked
by ``tests/test_multiplex.py``).  Tenants whose tick source is exhausted
are drained in bounded slices and finished; the multiplexer ends when
every tenant has finished.

Durability (``engine/snapshot.py``): pass ``snapshot_dir`` +
``snapshot_every`` and each tenant's session is serialized every
``snapshot_every`` ticks to ``<snapshot_dir>/<tenant>/step_*`` through
``runtime.checkpoint.CheckpointManager`` (atomic publish, keep-k, crashed
``.tmp`` fallback).  ``resume=True`` restores each tenant from its latest
published snapshot and seeks its (seekable) tick source to the recorded
cursor.  ``run_supervised`` wraps the whole thing in
``runtime.fault.run_with_restarts``: crash → restore → continue, bounded.

Live migration: ``Multiplexer.extract(name)`` quiesces a tenant (bounded
drain of in-flight replies), snapshots it, and removes it from this
scheduler; ``admit(tenant, snapshot=...)`` (or the ``snapshots=``
constructor arg) restores it into *another* multiplexer — in-flight
tickets that did not drain are re-asked through the new teacher connection
and metered (``tickets_reasked``), so the query-accounting identity
reconciles across the move.

Usage::

    results, agg = multiplex.run([
        multiplex.Tenant("edge-a", state_a, ticks_a, cfg_a, teacher_a),
        multiplex.Tenant("edge-b", state_b, ticks_b, cfg_b, teacher_b,
                         backpressure="coalesce"),
    ], sched="drr", snapshot_dir="/var/ckpt", snapshot_every=1000)

Teacher transport: ``shared_rpc_teachers`` builds per-tenant
``stream.Teacher`` handles over **shared** batched RPC connections —
tenants with the same ``(host, port)`` endpoint ride one
``rpc.BatchedRpcClient`` (one socket, one HMAC handshake per connection,
asks from all its tenants coalesced into single binary frames within the
flush window), so N tenants cost one round-trip stream per teacher host
instead of N.

``launch/serve.py`` drives this with ``--tenants`` / ``--backpressure`` /
``--sched`` / ``--snapshot-dir`` / ``--resume`` / ``--migrate``;
``benchmarks/multiplex_bench.py`` measures aggregate throughput and
``benchmarks/snapshot_bench.py`` the snapshot overhead.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import time
from typing import Iterable, NamedTuple, Optional

import numpy as np

from repro.engine import cohort as cohort_mod
from repro.engine import snapshot as snapshot_mod
from repro.engine import stream
from repro.engine.types import EngineConfig, EngineState, FleetStepOutput
from repro.runtime import fault
from repro.runtime import telemetry as _telemetry
from repro.runtime.checkpoint import CheckpointManager

SCHEDULERS = ("rr", "drr")


def shape_key(cfg: EngineConfig, mode: str, donate: Optional[bool], s: int) -> str:
    """Stable cross-process id of a tenant's compiled-shape class.

    This is exactly the cohort fuse key ``(cfg, mode, donate, S)`` as a
    short hash: two tenants with equal keys share compiled executables here
    and can fuse into one cohort.  The elastic router
    (``runtime/elastic.py``) packs same-key tenants onto the same worker so
    that sharing actually happens — the key must therefore be computable on
    both sides of the wire, hence a digest of the JSON config rather than a
    Python hash.
    """
    blob = json.dumps(
        {
            "cfg": snapshot_mod.config_to_dict(cfg),
            "mode": mode,
            "donate": bool(True if donate is None else donate),
            "s": int(s),
        },
        sort_keys=True,
    )
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


@dataclasses.dataclass
class Tenant:
    """One fleet behind the multiplexer.

    ``name`` keys the result dict (must be unique).  Everything else is
    exactly what ``stream.run`` takes — per tenant: its own config, state,
    tick source, teacher, ring capacity, and backpressure policy
    (``stream.BACKPRESSURE_POLICIES``).  ``state`` may be None when the
    tenant is admitted from a snapshot (the snapshot carries the state).
    """

    name: str
    state: Optional[EngineState]
    ticks: Iterable  # yields (S, n_in) feature arrays, one per tick
    cfg: Optional[EngineConfig]
    teacher: stream.Teacher
    mode: str = "algo1"
    capacity: int = 64
    backpressure: str = "drop_oldest"
    collect: bool = True
    donate: Optional[bool] = None


def shared_rpc_teachers(
    endpoints,
    timeout_s: float = 5.0,
    connect_timeout_s: float = 5.0,
    secret: Optional[str] = None,
    batch_window_s: Optional[float] = None,
    batch_max: Optional[int] = None,
    compress: bool = False,
):
    """Per-tenant teachers over shared batched RPC connections.

    ``endpoints[i]`` is tenant i's ``(host, port)``; tenants with the same
    endpoint share **one** ``rpc.BatchedRpcClient`` — one socket per
    teacher host, one HMAC handshake per connection (not per tenant), and
    every tenant's asks coalesced into batched frames within the flush
    window.  Returns ``(teachers, clients)``: ``teachers[i]`` is tenant
    i's ``stream.Teacher`` handle, ``clients`` the deduplicated
    connections (close them — not the handles — when the run is done).
    """
    from repro.engine import rpc  # deferred: keep `python -m repro.engine.rpc` clean

    if batch_window_s is None:
        batch_window_s = rpc.DEFAULT_BATCH_WINDOW_S
    if batch_max is None:
        batch_max = rpc.DEFAULT_BATCH_MAX
    clients: dict = {}
    teachers = []
    try:
        for i, (host, port) in enumerate(endpoints):
            key = (host, int(port))
            client = clients.get(key)
            if client is None:
                client = clients[key] = rpc.BatchedRpcClient(
                    host, int(port), timeout_s=timeout_s,
                    connect_timeout_s=connect_timeout_s, secret=secret,
                    batch_window_s=batch_window_s, batch_max=batch_max,
                    compress=compress,
                )
            teachers.append(client.tenant(name=f"tenant{i}"))
    except BaseException:
        # A later endpoint's dial/handshake failed: the clients already
        # built (sockets + reader/flusher threads) would otherwise leak
        # for the life of the process.
        for client in clients.values():
            with contextlib.suppress(Exception):
                client.close()
        raise
    return teachers, list(clients.values())


class TenantResult(NamedTuple):
    name: str
    state: EngineState
    outputs: Optional[FleetStepOutput]
    stats: stream.StreamStats


@dataclasses.dataclass
class MultiplexStats:
    """Aggregate view over one multiplexed run.

    ``wall_s`` is the scheduler's wall time (shared by all tenants — each
    tenant's own ``StreamStats.wall_s`` spans the whole multiplexed run,
    so per-tenant ``steps_per_s`` is *not* additive; use
    ``steps_per_s`` here for aggregate throughput).
    """

    n_tenants: int = 0
    rounds: int = 0
    stream_steps: int = 0
    ticks: int = 0
    snapshots: int = 0
    wall_s: float = 0.0

    @property
    def steps_per_s(self) -> float:
        return self.stream_steps / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "n_tenants": self.n_tenants,
            "rounds": self.rounds,
            "ticks": self.ticks,
            "stream_steps": self.stream_steps,
            "snapshots": self.snapshots,
            "steps_per_s": self.steps_per_s,
            "wall_s": self.wall_s,
            "caches": stream.cache_stats(),
        }


class _Slot:
    """Scheduler-side bookkeeping for one tenant."""

    # Drain polls allowed per scheduler slice: a drain poll is far cheaper
    # than a real tick (no device dispatch), but a laggy teacher must not
    # head-of-line block live tenants, so a draining tenant gets a bounded
    # budget per round and resumes next round.
    DRAIN_TICKS_PER_SLICE = 64
    DRAIN_IDLE_SLEEP_S = 50e-6

    def __init__(
        self,
        tenant: Tenant,
        manager: Optional[CheckpointManager] = None,
        snapshot_every: int = 0,
        resume: bool = False,
        snapshot_tree: Optional[dict] = None,
        pending: str = "auto",
        positioned: bool = False,
    ):
        self.tenant = tenant
        self.manager = manager
        self.snapshot_every = snapshot_every
        from_manager = False
        if snapshot_tree is None and resume and manager is not None:
            if manager.latest_step() is not None:
                _, snapshot_tree = manager.restore()
                from_manager = True
        if snapshot_tree is not None:
            self.session = stream.StreamSession.restore(
                snapshot_tree, tenant.teacher, cfg=tenant.cfg, pending=pending
            )
            consumed = snapshot_mod.ticks_consumed(snapshot_tree)
            if from_manager or getattr(tenant.ticks, "seek", None) is not None:
                # Crash-restart: the fresh source is definitely at tick 0 —
                # it MUST be seekable (seek_ticks raises otherwise; silently
                # replaying ticks 0..k-1 into a t=k session would corrupt
                # training).
                snapshot_mod.seek_ticks(tenant.ticks, consumed)
            elif not positioned:
                # An explicit migration snapshot may hand over the
                # partially-consumed iterator itself (what ``extract``
                # returns) — but only with an explicit opt-in: silently
                # treating a fresh tick-0 iterator as positioned at tick k
                # would replay ticks into a t=k session.
                raise ValueError(
                    f"tenant {tenant.name!r}: restoring a snapshot needs a "
                    "seekable tick source (snapshot.ResumableTicks), or "
                    "pass positioned=True when handing over the "
                    "partially-consumed iterator returned by extract()"
                )
        else:
            if tenant.state is None or tenant.cfg is None:
                raise ValueError(
                    f"tenant {tenant.name!r} has no state/cfg and no snapshot "
                    "to restore from"
                )
            self.session = stream.StreamSession(
                tenant.state,
                tenant.cfg,
                tenant.teacher,
                mode=tenant.mode,
                capacity=tenant.capacity,
                backpressure=tenant.backpressure,
                collect=tenant.collect,
                donate=tenant.donate,
            )
        # Telemetry series for this tenant key on its name (worker/serve
        # layers add their own labels at scrape time).
        self.session.telemetry_labels = {"tenant": tenant.name}
        # Tick cost for the deficit scheduler = this tenant's stream count.
        self.s = int(np.shape(np.asarray(self.session.state.elm.count))[0])
        self.deficit = 0.0
        self.last_ticks = 0  # real ticks advanced in the last step() call
        self.unit: Optional["_CohortUnit"] = None  # set while fused
        self.snapshots_taken = 0
        self._last_snap_t = self.session.t
        self.draining = False
        self._drain_ticks = 0  # cumulative, capped at stream.MAX_DRAIN_TICKS
        self.result: Optional[TenantResult] = None

    def step(self, drain: bool, n_ticks: int) -> bool:
        """Advance this tenant by up to ``n_ticks`` scheduler events (or
        one bounded drain slice once its ticks are exhausted).  Returns
        True while the tenant still wants scheduling."""
        sess = self.session
        self.last_ticks = 0
        if not self.draining:
            for _ in range(n_ticks):
                if not sess.started():
                    x0 = next(self.it, None)
                    if x0 is None:  # empty tick source: nothing to run
                        self.draining = True
                        break
                    sess.start(x0)
                    continue
                if sess._p is None:
                    # Session restored from a snapshot taken after its
                    # stream ended: nothing left to plan, only the drain.
                    self.draining = True
                    break
                nxt = next(self.it, None)
                sess.advance(nxt)
                self.last_ticks += 1
                if nxt is None:
                    self.draining = True
                    break
            self.maybe_snapshot()
            if not self.draining:
                return True
            if not drain:
                self._finish()
                return False
        # Draining: one bounded slice per round, so other tenants keep
        # ticking while this one waits out its teacher.  The cumulative cap
        # keeps a broken always-in-flight teacher from pinning the
        # scheduler forever (same bound a solo run's drain has).
        self._drain_ticks += self.DRAIN_TICKS_PER_SLICE
        if self._drain_ticks <= stream.MAX_DRAIN_TICKS and sess.drain_replies(
            max_ticks=self.DRAIN_TICKS_PER_SLICE,
            idle_sleep_s=self.DRAIN_IDLE_SLEEP_S,
        ):
            return True
        self._finish()
        return False

    @property
    def it(self):
        it = getattr(self, "_it", None)
        if it is None:
            it = self._it = iter(self.tenant.ticks)
        return it

    def maybe_snapshot(self, force: bool = False) -> bool:
        """Cadence snapshot: capture now, write on the manager's background
        thread (atomic publish — a crash mid-write falls back to the
        previous good step)."""
        if self.manager is None or self.result is not None:
            return False
        due = (
            self.snapshot_every > 0
            and self.session.t - self._last_snap_t >= self.snapshot_every
        )
        if not (due or force) or not self.session.started():
            return False
        if self.unit is not None:
            # Fused member: its session.state is stale while the cohort
            # holds the authoritative stacked rows — write them back first.
            self.unit.cohort.refresh(self.session)
        self.manager.save_async(self.session.t, self.session.snapshot())
        self._last_snap_t = self.session.t
        self.snapshots_taken += 1
        return True

    def _finish(self) -> None:
        # Any draining already happened incrementally in step().
        if self.manager is not None:
            self.manager.wait()  # never finish with a snapshot mid-write
        state, outs, stats = self.session.finish(drain=False)
        self.result = TenantResult(
            name=self.tenant.name, state=state, outputs=outs, stats=stats
        )


class _CohortUnit:
    """Scheduler-side unit driving one fused cohort of slots.

    Takes the place of its member slots in the scheduler's live list: one
    ``step`` advances the whole cohort in lockstep with fused dispatches
    (``engine/cohort.py``).  ``s`` — the DRR tick cost — is the shared
    member width, so each fused member receives exactly the credit/debit
    schedule its solo slot would (cohorts only form between same-width
    tenants); the fused tick just does all members' device work at once.
    """

    def __init__(self, slots: list[_Slot]):
        self.slots = list(slots)
        self.cohort = cohort_mod.CohortSession([s.session for s in slots])
        self.s = slots[0].s
        self.deficit = 0.0
        self.last_ticks = 0
        self.draining = False  # members drain solo, after release

    def attach(self, slot: _Slot) -> None:
        self.cohort.attach(slot.session)
        self.slots.append(slot)
        slot.unit = self

    def release(self, slot: _Slot) -> list[_Slot]:
        """Detach one member (live migration out).  Returns slots freed as
        a side effect: when one member remains the cohort dissolves and
        that member continues solo."""
        self.cohort.detach(slot.session)
        self.slots.remove(slot)
        slot.unit = None
        freed = []
        if len(self.slots) == 1:
            last = self.slots.pop()
            self.cohort.detach(last.session)
            last.unit = None
            freed.append(last)
        return freed

    def step(self, drain: bool, n_ticks: int) -> tuple[bool, list[_Slot]]:
        """Advance the cohort by up to ``n_ticks`` fused ticks.  Returns
        ``(live, released)`` — live False once the cohort dissolved;
        released slots (exhausted members, or the last member of a
        dissolved cohort) re-enter the scheduler as independent slots."""
        del drain  # released members drain through their solo slot path
        self.last_ticks = 0
        released: list[_Slot] = []
        for _ in range(n_ticks):
            if len(self.slots) < 2:
                break
            nxts = [next(s.it, None) for s in self.slots]
            detached, advanced = self.cohort.tick(nxts)
            if advanced:
                self.last_ticks += 1
            for sess in detached:
                slot = next(s for s in self.slots if s.session is sess)
                self.slots.remove(slot)
                slot.unit = None
                slot.draining = True
                slot.maybe_snapshot()
                released.append(slot)
        for slot in self.slots:
            slot.maybe_snapshot()
        if len(self.slots) == 1:
            # A cohort of one is pure overhead: dissolve, continue solo.
            last = self.slots.pop()
            self.cohort.detach(last.session)
            last.unit = None
            released.append(last)
        return bool(self.slots), released


DEFAULT_QUANTUM = 8


class Multiplexer:
    """The scheduler: drives N tenant sessions round-robin (or DRR) with
    optional per-tenant durability (see module docstring).

    ``round()`` runs one scheduler round and returns True while any tenant
    is live — drive it manually to interleave control (live migration),
    or call ``run()`` to completion.
    """

    def __init__(
        self,
        tenants: list[Tenant],
        drain: bool = True,
        quantum: int = DEFAULT_QUANTUM,
        sched: str = "rr",
        snapshot_dir: Optional[str] = None,
        snapshot_every: int = 0,
        snapshot_full_every: int = 1,
        resume: bool = False,
        snapshots: Optional[dict] = None,
        pending: str = "auto",
        fuse: bool = True,
    ):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        if sched not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {sched!r}; choose {SCHEDULERS}")
        if resume and snapshot_dir is None:
            raise ValueError(
                "resume=True needs snapshot_dir — without it every tenant "
                "would silently start from scratch"
            )
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")
        self.drain = drain
        self.quantum = quantum
        self.sched = sched
        self.fuse = fuse
        self._cohorts: dict = {}  # fuse key -> live _CohortUnit
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        # Cadence saves ship only changed leaves, with a full snapshot every
        # k-th save (runtime/checkpoint.py); 1 = every save full.
        self.snapshot_full_every = snapshot_full_every
        self._resume = resume
        self._pending = pending
        self.agg = MultiplexStats(n_tenants=len(tenants))
        self._slots: list[_Slot] = []
        # Scheduling units: solo _Slots and fused _CohortUnits (fuse=True).
        self._live: list = []
        self._t0: Optional[float] = None
        for t in tenants:
            self.admit(t, snapshot=(snapshots or {}).get(t.name))
        self.agg.n_tenants = len(self._slots)

    # -- tenant management -------------------------------------------------

    def _manager_for(self, name: str) -> Optional[CheckpointManager]:
        if self.snapshot_dir is None:
            return None
        return CheckpointManager(
            os.path.join(self.snapshot_dir, name),
            full_every=self.snapshot_full_every,
        )

    def admit(self, tenant: Tenant, snapshot: Optional[dict] = None,
              positioned: bool = False) -> None:
        """Add a tenant — fresh, resumed from its snapshot directory, or
        restored from an explicit ``snapshot`` tree (live migration).
        ``positioned=True`` asserts that a non-seekable ``tenant.ticks`` is
        already at the snapshot's cursor (i.e. it is the iterator
        ``extract`` returned, not a fresh tick-0 source)."""
        if any(s.tenant.name == tenant.name for s in self._slots):
            raise ValueError(f"tenant name {tenant.name!r} already admitted")
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("migrate.admit") if tel is not None else None
        slot = _Slot(
            tenant,
            manager=self._manager_for(tenant.name),
            snapshot_every=self.snapshot_every,
            resume=self._resume,
            snapshot_tree=snapshot,
            pending=self._pending,
            positioned=positioned,
        )
        self._slots.append(slot)
        self._live.append(slot)
        self.agg.n_tenants = len(self._slots)
        if tok is not None:
            tel.tracer.end(
                tok, tenant=tenant.name, restored=snapshot is not None
            )
            tel.registry.count("odl_mux_admits")

    def _slot(self, name: str) -> _Slot:
        for s in self._slots:
            if s.tenant.name == name:
                return s
        raise KeyError(f"no tenant named {name!r}")

    def session(self, name: str) -> stream.StreamSession:
        return self._slot(name).session

    def finished(self, name: str) -> bool:
        return self._slot(name).result is not None

    def live_tenants(self) -> list[str]:
        """Names of tenants still being scheduled (admission order)."""
        return [s.tenant.name for s in self._slots if s.result is None]

    def finished_results(self) -> dict[str, TenantResult]:
        """Per-tenant results of every *finished* tenant — unlike
        ``results()``, callable while others are still live (the worker
        serves long-lived fleets that never fully drain)."""
        return {
            s.tenant.name: s.result for s in self._slots if s.result is not None
        }

    def load_report(self) -> list[dict]:
        """Per-live-tenant load signals for the elastic router: tick cursor,
        tick-rate EMA, ring occupancy (current/high-water/capacity), the
        compiled-shape key placement packs by, and whether the tenant is
        currently riding a fused cohort.  Accurate while fused — everything
        reported here is per-tenant host state, which cohort ticking keeps
        current."""
        out = []
        for slot in self._slots:
            if slot.result is not None:
                continue
            sess = slot.session
            stats = sess.stats
            out.append({
                "name": slot.tenant.name,
                "t": sess.t,
                "s": slot.s,
                "shape_key": shape_key(sess.cfg, sess.mode, sess._donate, slot.s),
                "tick_rate_ema": stats.tick_rate_ema,
                "ring": len(sess.ring),
                "ring_hwm": stats.ring_occupancy_hwm,
                "ring_capacity": sess.ring.capacity,
                "queries_issued": stats.queries_issued,
                "labels_applied": stats.labels_applied,
                "draining": slot.draining,
                "fused": slot.unit is not None,
            })
        return out

    def sync_telemetry(self) -> None:
        """Mirror every tenant's ``StreamStats`` (live sessions and
        finished results alike) into the enabled registry — the pull half
        of the one-source-of-truth design.  Called by live scrapes
        (``runtime/worker.py`` ``metrics``) and end-of-run reports; no-op
        when telemetry is disabled, never on the per-tick path."""
        tel = _telemetry.TELEMETRY
        if tel is None:
            return
        for slot in self._slots:
            if slot.result is not None:
                _telemetry.sync_stream_stats(
                    tel.registry, slot.result.stats, pending=0,
                    tenant=slot.tenant.name,
                )
            else:
                slot.session.sync_telemetry()
        tel.registry.gauge("odl_mux_tenants", len(self._slots))

    def extract(self, name: str, quiesce_ticks: int = 4096):
        """Live-migrate a tenant out: snapshot the session and remove it
        from this scheduler.

        When the teacher cannot snapshot its own state, the session first
        quiesces (bounded drain of in-flight replies, salvaging answers
        that would die with the connection — still-unanswered tickets stay
        in the ring, travel in the snapshot, and are re-asked on restore).
        A snapshot-capable teacher (``snapshot_state``) skips the quiesce:
        its undelivered inbox rides the snapshot verbatim, so the restored
        run replays every reply at its original due tick.  Draining early
        would apply labels *before* the plans they interleave with in the
        uninterrupted run — those plans then see a different ``elm`` and
        can flip query decisions, breaking bit-for-bit migration.

        Returns ``(snapshot_tree, ticks)``: the serialized session and the
        tenant's *partially-consumed* tick iterator (positioned at the next
        unread tick — for a seekable source this is the source itself and
        ``admit`` re-seeks it; for a plain sequence/generator it is the
        live iterator, so migration never replays ticks).  Hand both to
        another multiplexer's ``admit`` (same process) or persist the tree
        through a ``CheckpointManager`` and reopen a seekable source at
        ``snapshot.ticks_consumed(tree)`` (another process).
        """
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("migrate.extract") if tel is not None else None
        slot = self._slot(name)
        if slot.result is not None:
            raise ValueError(f"tenant {name!r} already finished; nothing to migrate")
        if slot.unit is not None:
            # Migrating out of a fused cohort: detach first (writes the
            # member's stacked rows + pending plan back into its session),
            # then the ordinary solo quiesce/snapshot flow applies.
            unit = slot.unit
            freed = unit.release(slot)
            if not unit.slots and unit in self._live:
                idx = self._live.index(unit)
                self._live[idx : idx + 1] = freed
            else:
                self._live.extend(freed)
            self._cohorts = {k: u for k, u in self._cohorts.items() if u.slots}
        snapshot_capable = (
            getattr(slot.session.teacher, "snapshot_state", None) is not None
        )
        if quiesce_ticks > 0 and not snapshot_capable:
            slot.session.quiesce(
                max_ticks=quiesce_ticks, idle_sleep_s=slot.DRAIN_IDLE_SLEEP_S
            )
        tree = slot.session.snapshot()
        if slot.manager is not None:
            slot.manager.wait()
            slot.manager.save(slot.session.t, tree)
        self._slots.remove(slot)
        if slot in self._live:
            self._live.remove(slot)
        self.agg.n_tenants = len(self._slots)
        if tok is not None:
            tel.tracer.end(tok, tenant=name, t=slot.session.t)
            tel.registry.count("odl_mux_extracts")
        return tree, slot.it

    # -- scheduling --------------------------------------------------------

    def round(self) -> bool:
        """One scheduler round over all live tenants.  Returns True while
        any tenant still wants scheduling."""
        try:
            return self._round()
        except BaseException:
            # Settle in-flight background snapshot writes before the crash
            # propagates: a supervised restart in this process must never
            # race an orphaned writer thread for the same step directory.
            for s in self._slots:
                if s.manager is not None:
                    with contextlib.suppress(Exception):
                        s.manager.wait()
            raise

    def _form_cohorts(self) -> None:
        """Pack fusable live slots into cohorts by ``(cfg, mode, donate, S)``.

        Runs at every round start, so tenants admitted mid-run (including
        live-migration snapshots restored with pending tickets) join a
        matching cohort at the next scheduling boundary.  Singleton groups
        stay on the solo slot path — a cohort only pays off with >= 2
        members.  The stream width S is part of the key: cohort members
        tick in lockstep, and fusing different widths would break the DRR
        scheduler's per-tenant fairness (each fused member must cost
        exactly what its solo slot would)."""
        groups: dict = {}
        for u in self._live:
            if not isinstance(u, _Slot) or u.unit is not None or u.draining:
                continue
            sess = u.session
            if sess.started() and sess._p is None:
                continue  # restored after its stream ended: drain only
            key = (sess.cfg, sess.mode, sess._donate, u.s)
            groups.setdefault(key, []).append(u)
        for key, slots in groups.items():
            unit = self._cohorts.get(key)
            if unit is not None and unit.slots:
                for s in slots:
                    unit.attach(s)
                    self._live.remove(s)
            elif len(slots) >= 2:
                unit = _CohortUnit(slots)
                for s in slots:
                    s.unit = unit
                idx = min(self._live.index(s) for s in slots)
                for s in slots:
                    self._live.remove(s)
                self._live.insert(idx, unit)
                self._cohorts[key] = unit
                tel = _telemetry.TELEMETRY
                if tel is not None:
                    tel.tracer.event(
                        "cohort.pack", members=len(slots), s=unit.s,
                        tenants=",".join(s.tenant.name for s in slots),
                    )
                    tel.registry.count("odl_mux_cohorts_packed")

    def _step_unit(self, u, n_ticks: int) -> list:
        """Step one scheduler unit; returns the units live after it (the
        unit itself, plus any slots a cohort released this round — an
        exhausted member immediately gets its first solo drain slice, like
        the solo path's same-call drain)."""
        out = []
        if isinstance(u, _CohortUnit):
            live, released = u.step(self.drain, n_ticks)
            if live:
                out.append(u)
            else:
                self._cohorts = {
                    k: un for k, un in self._cohorts.items() if un is not u
                }
                tel = _telemetry.TELEMETRY
                if tel is not None:
                    tel.tracer.event("cohort.dissolve", released=len(released))
                    tel.registry.count("odl_mux_cohorts_dissolved")
            for r in released:
                r.deficit = 0.0
                if r.draining and not self.drain:
                    r._finish()  # drain=False: settle, exactly like solo
                elif not r.draining or r.step(self.drain, 0):
                    out.append(r)
        elif u.step(self.drain, n_ticks):
            out.append(u)
        return out

    def _round(self) -> bool:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if not self._live:
            return False
        self.agg.rounds += 1
        if self.fuse:
            self._form_cohorts()
        units = list(self._live)  # pre-round units, for debit metering below
        if self.sched == "drr":
            # Credit is sized by the smallest *ticking* tenant: a tenant
            # that is only draining costs no device time and must not gate
            # everyone else's budget (a small drained tenant stuck waiting
            # out a slow teacher would otherwise collapse live tenants to
            # ~1 tick per S_big/S_small rounds).  A cohort unit's cost is
            # its (shared) member width, so each fused member sees the
            # same credit/debit schedule as its solo slot.
            ticking = [u.s for u in self._live if not u.draining]
            credit = self.quantum * min(ticking) if ticking else 0
            nxt = []
            for u in self._live:
                u.deficit += credit
                n = int(u.deficit // u.s)
                stepped = self._step_unit(u, n)
                u.deficit -= u.last_ticks * u.s
                if u.draining:
                    u.deficit = 0.0  # drained slices don't consume credit
                nxt.extend(stepped)
            self._live = nxt
        else:
            nxt = []
            for u in self._live:
                nxt.extend(self._step_unit(u, self.quantum))
            self._live = nxt
        tel = _telemetry.TELEMETRY
        if tel is not None:
            # Scheduler-level meters: rounds, and the stream-step debits
            # this round actually charged (ticks × per-unit cost S — the
            # DRR deficit currency; for rr the same product measures the
            # round's device work).
            tel.registry.count("odl_mux_rounds")
            tel.registry.count(
                "odl_mux_quantum_debits",
                sum(u.last_ticks * u.s for u in units),
            )
            tel.registry.gauge("odl_mux_live_units", len(self._live))
        return bool(self._live)

    def run(self) -> tuple[dict[str, TenantResult], MultiplexStats]:
        while self.round():
            pass
        return self.results()

    def results(self) -> tuple[dict[str, TenantResult], MultiplexStats]:
        """Finalize and collect per-tenant results + aggregate stats."""
        if self._live:
            raise RuntimeError("results() with tenants still live; drive round()")
        if self._t0 is not None:
            self.agg.wall_s = time.perf_counter() - self._t0
        self.agg.stream_steps = sum(s.result.stats.stream_steps for s in self._slots)
        self.agg.ticks = sum(s.result.stats.ticks for s in self._slots)
        # Snapshots *taken* this run (keep-k GC prunes the directories, so
        # counting surviving step dirs would undercount).
        self.agg.snapshots = sum(s.snapshots_taken for s in self._slots)
        return {s.tenant.name: s.result for s in self._slots}, self.agg


def run(
    tenants: list[Tenant],
    drain: bool = True,
    quantum: int = DEFAULT_QUANTUM,
    sched: str = "rr",
    snapshot_dir: Optional[str] = None,
    snapshot_every: int = 0,
    resume: bool = False,
    fuse: bool = True,
) -> tuple[dict[str, TenantResult], MultiplexStats]:
    """Multiplex every tenant's stream over this process to completion.

    ``quantum`` is the scheduler time slice: how many consecutive ticks one
    tenant runs before the scheduler moves on.  Switching tenants every
    tick (quantum=1) evicts the previous tenant's state (P alone is
    S·N²·4 bytes) from cache on every switch and costs ~15-45% aggregate
    throughput at S=512; a few ticks per slice amortize that while keeping
    per-tenant scheduling delay bounded.  ``sched="drr"`` measures the
    slice in stream-steps instead of ticks so small tenants are not
    starved by huge ones (see module docstring).  The per-tenant result is
    bit-for-bit identical for every quantum and scheduler — only
    wall-clock interleaving changes.

    ``fuse`` (default True) packs tenants with the same ``(cfg, mode,
    donate)`` and stream width into *cohorts* advanced by one fused
    stacked dispatch per tick instead of one per tenant
    (``engine/cohort.py``) — per-tenant results stay bit-for-bit identical
    to the unfused (and solo) run; only device dispatch count and
    wall-clock interleaving change.

    ``snapshot_dir`` + ``snapshot_every`` enable per-tenant durability;
    ``resume=True`` restores tenants from their latest published snapshot
    (tick sources must then be seekable — ``snapshot.ResumableTicks``).

    Returns ``(results, agg)``: ``results[name]`` is that tenant's
    ``(state, outputs, stats)`` — identical to what a solo ``stream.run``
    over the same inputs returns — and ``agg`` is the aggregate
    ``MultiplexStats`` (true wall time, total steps).
    """
    if not tenants:
        raise ValueError("multiplex.run needs at least one tenant")
    return Multiplexer(
        tenants,
        drain=drain,
        quantum=quantum,
        sched=sched,
        snapshot_dir=snapshot_dir,
        snapshot_every=snapshot_every,
        resume=resume,
        fuse=fuse,
    ).run()


def run_supervised(
    make_tenants,
    snapshot_dir: str,
    snapshot_every: int = 1000,
    max_restarts: int = 3,
    **kw,
):
    """Crash-restart supervision around a durable multiplexed run.

    ``make_tenants()`` must build a *fresh* tenant list (fresh teacher
    instances, seekable tick sources) on every attempt — the previous
    attempt's objects died with it.  Each attempt resumes every tenant
    from its latest published snapshot under ``snapshot_dir`` (or from
    scratch when none exists yet); ``runtime.fault.run_with_restarts``
    bounds the retry loop.
    """

    class _DirView:
        """Adapter: the per-tenant snapshot directory tree viewed as one
        checkpointed unit for the supervisor (restore is a no-op — each
        tenant restores itself from its own subdirectory)."""

        def latest_step(self):
            steps = [
                s
                for name in (
                    os.listdir(snapshot_dir) if os.path.isdir(snapshot_dir) else []
                )
                if os.path.isdir(os.path.join(snapshot_dir, name))
                for s in [CheckpointManager(os.path.join(snapshot_dir, name)).latest_step()]
                if s is not None
            ]
            return max(steps) if steps else None

        def restore(self):
            return self.latest_step(), None

    def run_attempt(state, start_step):
        del state, start_step  # per-tenant restore happens inside run()
        return run(
            make_tenants(),
            snapshot_dir=snapshot_dir,
            snapshot_every=snapshot_every,
            resume=True,
            **kw,
        )

    return fault.run_with_restarts(
        lambda: None, run_attempt, _DirView(), max_restarts=max_restarts
    )


# The multiplexer's compiled-executable sharing is observable here: tenant
# configs that hash equal hit the same LRU entries.
cache_stats = stream.cache_stats
