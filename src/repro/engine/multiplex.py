"""Multi-tenant stream multiplexer: N independent fleets in one process.

The paper's deployment story is many edge devices sharing one teacher-side
host: each tenant is an independent fleet — its own ``EngineConfig``,
``EngineState``, tick source, ``Teacher``, pending-query ring, and
backpressure policy — but they all run in a single process, sharing the
engine's bounded compiled-runner LRUs (``stream._plan_runner`` /
``_learn_runner`` / ``_learn_plan_runner`` and ``fleet._chunk_runner``).
Tenants with the same ``(cfg, mode, donate)`` therefore share one compiled
executable: adding a tenant with a config already being served costs no
compile and no extra executable memory.

Scheduling is round-robin with a ``quantum``-tick time slice (default 8):
each tenant's ``StreamSession`` (``engine/stream.py``) advances by up to
``quantum`` plan/ask/poll/learn cycles before the scheduler moves on —
switching every tick would evict the tenant's state from cache on every
switch.  Because a session's per-tenant op sequence does not depend on
what the scheduler interleaves around it, a multiplexed tenant reproduces
its solo ``stream.run`` bit-for-bit at any quantum (locked by
``tests/test_multiplex.py``).
Tenants whose tick source is exhausted are finished (drained) immediately;
the multiplexer ends when every tenant has finished.

Usage::

    results, agg = multiplex.run([
        multiplex.Tenant("edge-a", state_a, ticks_a, cfg_a, teacher_a),
        multiplex.Tenant("edge-b", state_b, ticks_b, cfg_b, teacher_b,
                         backpressure="coalesce"),
    ])
    results["edge-a"].state, results["edge-a"].stats.tick_p95_ms, ...

``launch/serve.py`` drives this with ``--tenants`` / ``--backpressure``;
``benchmarks/multiplex_bench.py`` measures per-tenant tick p50/p95 and
aggregate steps/s against N sequential ``stream.run`` calls.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, NamedTuple, Optional

from repro.engine import stream
from repro.engine.types import EngineConfig, EngineState, FleetStepOutput


@dataclasses.dataclass
class Tenant:
    """One fleet behind the multiplexer.

    ``name`` keys the result dict (must be unique).  Everything else is
    exactly what ``stream.run`` takes — per tenant: its own config, state,
    tick source, teacher, ring capacity, and backpressure policy
    (``stream.BACKPRESSURE_POLICIES``).
    """

    name: str
    state: EngineState
    ticks: Iterable  # yields (S, n_in) feature arrays, one per tick
    cfg: EngineConfig
    teacher: stream.Teacher
    mode: str = "algo1"
    capacity: int = 64
    backpressure: str = "drop_oldest"
    collect: bool = True
    donate: Optional[bool] = None


class TenantResult(NamedTuple):
    name: str
    state: EngineState
    outputs: Optional[FleetStepOutput]
    stats: stream.StreamStats


@dataclasses.dataclass
class MultiplexStats:
    """Aggregate view over one multiplexed run.

    ``wall_s`` is the scheduler's wall time (shared by all tenants — each
    tenant's own ``StreamStats.wall_s`` spans the whole multiplexed run,
    so per-tenant ``steps_per_s`` is *not* additive; use
    ``steps_per_s`` here for aggregate throughput).
    """

    n_tenants: int = 0
    rounds: int = 0
    stream_steps: int = 0
    ticks: int = 0
    wall_s: float = 0.0

    @property
    def steps_per_s(self) -> float:
        return self.stream_steps / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "n_tenants": self.n_tenants,
            "rounds": self.rounds,
            "ticks": self.ticks,
            "stream_steps": self.stream_steps,
            "steps_per_s": self.steps_per_s,
            "wall_s": self.wall_s,
            "caches": stream.cache_stats(),
        }


class _Slot:
    """Scheduler-side bookkeeping for one tenant."""

    # Drain polls allowed per scheduler slice: a drain poll is far cheaper
    # than a real tick (no device dispatch), but a laggy teacher must not
    # head-of-line block live tenants, so a draining tenant gets a bounded
    # budget per round and resumes next round.
    DRAIN_TICKS_PER_SLICE = 64
    DRAIN_IDLE_SLEEP_S = 50e-6

    def __init__(self, tenant: Tenant):
        self.tenant = tenant
        self.it = iter(tenant.ticks)
        self.session = stream.StreamSession(
            tenant.state,
            tenant.cfg,
            tenant.teacher,
            mode=tenant.mode,
            capacity=tenant.capacity,
            backpressure=tenant.backpressure,
            collect=tenant.collect,
            donate=tenant.donate,
        )
        self.draining = False
        self._drain_ticks = 0  # cumulative, capped at stream.MAX_DRAIN_TICKS
        self.result: Optional[TenantResult] = None

    def step(self, drain: bool, quantum: int) -> bool:
        """Advance this tenant by up to ``quantum`` scheduler events (or
        one bounded drain slice once its ticks are exhausted).  Returns
        True while the tenant still wants scheduling."""
        sess = self.session
        if not self.draining:
            for _ in range(quantum):
                if not sess.started():
                    x0 = next(self.it, None)
                    if x0 is None:  # empty tick source: nothing to run
                        self.draining = True
                        break
                    sess.start(x0)
                    continue
                nxt = next(self.it, None)
                sess.advance(nxt)
                if nxt is None:
                    self.draining = True
                    break
            if not self.draining:
                return True
            if not drain:
                self._finish()
                return False
        # Draining: one bounded slice per round, so other tenants keep
        # ticking while this one waits out its teacher.  The cumulative cap
        # keeps a broken always-in-flight teacher from pinning the
        # scheduler forever (same bound a solo run's drain has).
        self._drain_ticks += self.DRAIN_TICKS_PER_SLICE
        if self._drain_ticks <= stream.MAX_DRAIN_TICKS and sess.drain_replies(
            max_ticks=self.DRAIN_TICKS_PER_SLICE,
            idle_sleep_s=self.DRAIN_IDLE_SLEEP_S,
        ):
            return True
        self._finish()
        return False

    def _finish(self) -> None:
        # Any draining already happened incrementally in step().
        state, outs, stats = self.session.finish(drain=False)
        self.result = TenantResult(
            name=self.tenant.name, state=state, outputs=outs, stats=stats
        )


DEFAULT_QUANTUM = 8


def run(
    tenants: list[Tenant],
    drain: bool = True,
    quantum: int = DEFAULT_QUANTUM,
) -> tuple[dict[str, TenantResult], MultiplexStats]:
    """Multiplex every tenant's stream over this process, round-robin.

    ``quantum`` is the scheduler time slice: how many consecutive ticks one
    tenant runs before the scheduler moves on.  Switching tenants every
    tick (quantum=1) evicts the previous tenant's state (P alone is
    S·N²·4 bytes) from cache on every switch and costs ~15-45% aggregate
    throughput at S=512; a few ticks per slice amortize that while keeping
    per-tenant scheduling delay bounded by (n_tenants-1)·quantum ticks.
    The per-tenant result is bit-for-bit identical for every quantum — only
    wall-clock interleaving changes (a weighted/fairness scheduler is a
    ROADMAP follow-on).

    Returns ``(results, agg)``: ``results[name]`` is that tenant's
    ``(state, outputs, stats)`` — identical to what a solo ``stream.run``
    over the same inputs returns — and ``agg`` is the aggregate
    ``MultiplexStats`` (true wall time, total steps).
    """
    if not tenants:
        raise ValueError("multiplex.run needs at least one tenant")
    if quantum < 1:
        raise ValueError(f"quantum must be >= 1, got {quantum}")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"tenant names must be unique, got {names}")

    slots = [_Slot(t) for t in tenants]
    agg = MultiplexStats(n_tenants=len(tenants))
    t0 = time.perf_counter()
    live = list(slots)
    while live:
        agg.rounds += 1
        live = [s for s in live if s.step(drain, quantum)]
    agg.wall_s = time.perf_counter() - t0
    for s in slots:
        agg.stream_steps += s.result.stats.stream_steps
        agg.ticks += s.result.stats.ticks
    return {s.tenant.name: s.result for s in slots}, agg


# The multiplexer's compiled-executable sharing is observable here: tenant
# configs that hash equal hit the same LRU entries.
cache_stats = stream.cache_stats
