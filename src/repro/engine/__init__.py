"""repro.engine — fleet-scale ODL: Algorithm 1 batched over streams.

This package is the single owner of the paper's ODL state machine (OS-ELM
+ P1P2 auto-pruning + drift gating) at every scale: the S=1 paper repro
(``engine.scalar``, re-exported by the deprecated ``core/odl_head.py``
alias), the offline batched fleet (``run_fleet``), and the online
streaming deployment with a laggy teacher (``engine.stream``).

State layout
------------
``EngineState`` (``engine/types.py``) is a single pytree with a leading
stream axis ``S`` on every leaf::

    EngineState
    ├── elm:   OSELMState   beta (S, N, m) · P (S, N, N) · count (S,)
    ├── prune: PruneState   level/streak/queries/skips/phase_trained (S,)
    ├── drift: DriftState   mean/var/steps/hits/calm/active (S,)
    └── meter: CommMeter    up_bytes/down_bytes (S,)

One tick, split at the teacher round-trip
-----------------------------------------
``plan(state, x: (S, n_in))`` performs predict → confidence → drift update
→ should_query for all S streams (one hidden-projection matmul, everything
else elementwise), charges the comm meter, and accounts the pruning
ladder's skip events.  ``learn(state, h, labels, pred, conf, mask, ...)``
later applies teacher answers: masked einsum-batched rank-1 Woodbury RLS
(optionally the fused Pallas kernel via ``cfg.elm.use_kernel``) plus the
ladder transition for the answered queries — against the *plan-time*
features, so answers may arrive ticks later and out of order.
``fleet_step`` is exactly ``learn`` composed on ``plan`` (a zero-latency
teacher) and stays the offline single-dispatch tick.

Chunked time scan
-----------------
``run_fleet(state, xs: (T, S, n_in), labels: (T, S))`` scans ``fleet_step``
over time inside jit, in chunks of ``chunk`` ticks: a Python loop dispatches
one donated jit call per chunk (``donate_argnums=0`` — P, the dominant
buffer at S·N²·4 bytes, is updated in place on TPU), and each chunk's
compiled executable is cached per ``(cfg, mode, chunk shape)`` in a
*bounded* LRU (``fleet.RUNNER_CACHE_SIZE``; hit/miss counters via
``fleet.runner_cache_info`` / ``stream.cache_stats``) so chunk boundaries
never recompile and long-lived servers never leak executables.

Streaming runtime & teacher protocol
------------------------------------
``stream.run(state, ticks, cfg, teacher)`` drives the same state machine
from an *iterator* of (S, n_in) ticks — nothing materializes over T.  A
``stream.Teacher`` (``ask(feats, mask, tick) -> ticket`` /
``poll(tick) -> [TeacherReply]`` / ``in_flight()``) answers with real
latency; ``stream.LatencyTeacher`` models latency, jitter, loss, and
permanent outage.  In-flight tickets wait in a fixed-capacity
``PendingRing`` (overflow drops the oldest, metered), answers apply out of
order through masked ``learn``, and host ingestion of tick t+1 overlaps
device compute of tick t (double buffering).  ``StreamStats`` reports
p50/p95 tick latency, label latency in ticks, and drop/orphan/loss
counters.  With a zero-latency teacher the runtime reproduces
``run_fleet`` bit-for-bit (locked by ``tests/test_stream.py``).

Sharding
--------
Every step constrains the leading axis of all state leaves to the
``"stream"`` logical axis (``distributed/sharding.py``), which the default
rule table maps to ``("pod", "data")`` — under an active mesh the fleet
splits across devices with zero cross-stream communication.

Modes
-----
* ``mode="algo1"``       — the paper's full Algorithm 1: the per-stream
  drift detector switches predicting ↔ training; queries only happen in
  training mode.
* ``mode="train_phase"`` — the §3 evaluation protocol: an explicit
  retraining phase, pruning always armed, optional per-stream
  ``teacher_available`` outage modelling.
* ``mode="serve"``       — the serving cascade: live drift detector
  (a drifting stream is forced to query — pruning condition 2), controller
  always armed, no training-mode gating.  Exactly the ``gate`` decision
  logic, so ``plan(mode='serve')``/``learn`` and ``gate``/``apply_labels``
  are the same state machine (``launch/serve.py`` multiplexes the former;
  ``models/model.py``'s fused decode step uses the latter).

Multi-tenant multiplexer & backpressure
---------------------------------------
``multiplex.run(tenants)`` serves N independent fleets — each a
``multiplex.Tenant`` with its own config, state, tick source, ``Teacher``,
pending ring, and *backpressure policy* — from one process, round-robin
with a ``quantum``-tick time slice (cache locality; results are
quantum-invariant).  Tenants with the same ``(cfg, mode,
donate)`` share a compiled executable through the bounded runner LRUs, so
a tenant using an already-served config costs no compile.  The pending
ring's saturation behavior is pluggable (``stream.BACKPRESSURE_POLICIES``):
``drop_oldest`` (evict, metered), ``drop_newest`` (refuse the new ask),
``block`` (defer the ask until a slot frees), and ``coalesce`` (merge a
re-querying stream into its in-flight ticket — no duplicate teacher
traffic).  Query accounting reconciles exactly: ``queries_issued ==
labels_applied + queries_dropped + queries_lost (+ queries_coalesced)``.
``engine.rpc.RpcTeacher`` speaks the same Teacher protocol over a real TCP
socket with timeout→loss mapping, so the latency model is no longer the
only teacher transport; ``engine.rpc.BatchedRpcClient`` shares **one**
such connection across all tenants of a teacher host, coalescing asks
that land within a flush window into single length-prefixed binary
frames (v2 wire format; v1 newline-JSON stays supported) and demuxing
replies to per-tenant ``BatchedRpcTeacher`` handles —
``multiplex.shared_rpc_teachers`` dedups endpoints into shared clients.

Serving entry points (``gate`` / ``apply_labels``) remain for callers that
carry their own features (``models/model.py``'s decode loop feeds backbone
hidden states): ``gate`` returns a ``GateOutput`` capturing the plan-time
decision context (h/pred/confidence/theta), and ``apply_labels`` judges
the — possibly delayed — teacher answer against exactly that context
(raw query-time features are rejected: recomputing the judgment from
current weights is stale-reply semantics), the same contract as
``plan``/``learn``.  ``launch/serve.py`` multiplexes N tenant fleets over
the decode loop with these same pieces.

Scheduling is round-robin by default; ``multiplex`` also offers deficit
round robin (``sched="drr"``) that charges each tick its stream count, so
an S=512 tenant cannot starve an S=16 one — per-tenant results are
bit-for-bit identical under either scheduler.

Durable sessions
----------------
On-device learned state is paid for in teacher-communication energy, so a
crash must not discard it.  ``engine/snapshot.py`` serializes a live
``StreamSession`` with full fidelity — ``EngineState``, the pending ring
with each ticket's plan-time context and raw features, backpressure-policy
state (deferred ``block`` asks; ``coalesce``'s merge map is the ring
masks), ``StreamStats``, the in-flight tick, the tick-source cursor, and
(when supported, e.g. ``LatencyTeacher``) the teacher's own state —
published atomically with keep-k GC through
``runtime.checkpoint.CheckpointManager``.  ``StreamSession.snapshot()`` /
``StreamSession.restore()`` are the session-level API; a restored run is
bit-for-bit the uninterrupted one under a deterministic snapshot-capable
teacher (``tests/test_snapshot.py``, every backpressure policy).  Teachers
that cannot be snapshot (``rpc.RpcTeacher`` — sockets) have their
in-flight tickets re-asked through the fresh connection and metered
(``tickets_reasked``), preserving the query-accounting identity.
``engine/durable.py`` drives a single durable session (and is the
kill-and-resume CI smoke: ``python -m repro.engine.durable
--crash-smoke``); ``multiplex.Multiplexer`` adds per-tenant cadence
snapshots + ``resume``, ``run_supervised`` wraps attempts in
``runtime.fault.run_with_restarts``, and ``extract``/``admit`` implement
live tenant migration (quiesce → snapshot → restore into another
multiplexer).  ``launch/serve.py`` exposes all of it
(``--snapshot-dir``/``--snapshot-every``/``--resume``/``--migrate``).
"""

from repro.engine.fleet import (  # noqa: F401
    EngineConfig,
    EngineState,
    FleetShards,
    FleetStepOutput,
    GateOutput,
    PlanOutput,
    apply_labels,
    broadcast_streams,
    fleet_accuracy,
    fleet_step,
    gate,
    init_fleet,
    init_state,
    learn,
    merge_fleet,
    pad_streams,
    plan,
    run_fleet,
    run_fleet_sharded,
    run_fleet_shards,
    runner_cache_info,
    shard_fleet,
    split_fleet,
    stream_slice,
)

# fleet must import first: its repro.core imports resolve the
# core -> odl_head(alias) -> engine.scalar cycle before scalar/stream load.
# (engine.durable and engine.rpc are importable leaves with CLIs — kept out
# of the package import so ``python -m repro.engine.durable`` stays clean.)
from repro.engine import multiplex, scalar, snapshot, stream  # noqa: E402,F401
