"""repro.engine — fleet-scale ODL: Algorithm 1 batched over streams.

This package owns the scalable serving substrate for the paper's ODL core
(OS-ELM + P1P2 auto-pruning + drift gating).  Where ``core/odl_head.py``
expresses Algorithm 1 for ONE stream (and is now a thin ``S = 1`` shim kept
for the paper-repro tests), the engine runs the same state machine for a
whole fleet of independent streams in one fused, jitted step.

State layout
------------
``EngineState`` is a single pytree with a leading stream axis ``S`` on every
leaf::

    EngineState
    ├── elm:   OSELMState   beta (S, N, m) · P (S, N, N) · count (S,)
    ├── prune: PruneState   level/streak/queries/skips/phase_trained (S,)
    ├── drift: DriftState   mean/var/steps/hits/calm/active (S,)
    └── meter: CommMeter    up_bytes/down_bytes (S,)

One ``fleet_step(state, x: (S, n_in), labels: (S,))`` performs
predict → confidence → drift update → should_query → masked rank-1 RLS for
all S streams with batched linear algebra (one hidden-projection matmul and
einsum-batched Woodbury updates — no per-stream Python, no vmapped k×k
solves).  With ``cfg.elm.use_kernel`` the RLS update routes through the
fused Pallas kernel (``kernels/oselm_update.oselm_rls_update_fleet``), which
reads each P tile once for both the downdate and the beta update.

Chunked time scan
-----------------
``run_fleet(state, xs: (T, S, n_in), labels: (T, S))`` scans ``fleet_step``
over time inside jit, in chunks of ``chunk`` ticks: a Python loop dispatches
one donated jit call per chunk (``donate_argnums=0`` — P, the dominant
buffer at S·N²·4 bytes, is updated in place on TPU), and each chunk's
compiled executable is cached per ``(cfg, mode, chunk shape)`` so chunk
boundaries never recompile.  T×S stream-steps therefore cost T/chunk
dispatches total instead of T×S per-sample Python overhead.

Sharding
--------
Every ``fleet_step`` constrains the leading axis of all state leaves to the
``"stream"`` logical axis (``distributed/sharding.py``), which the default
rule table maps to ``("pod", "data")`` — under an active mesh the fleet
splits across devices with zero cross-stream communication.

Modes
-----
* ``mode="algo1"``       — the paper's full Algorithm 1: the per-stream
  drift detector switches predicting ↔ training; queries only happen in
  training mode.
* ``mode="train_phase"`` — the §3 evaluation protocol: an explicit
  retraining phase, pruning always armed, optional per-stream
  ``teacher_available`` outage modelling.

Serving entry points (``gate`` / ``apply_labels``) split one step at the
label round-trip: ``gate`` predicts and decides which streams must consult
the teacher (charging the comm meter); ``apply_labels`` later applies the
teacher's answers with the same masked RLS update.  ``models/model.py``'s
serve path and ``launch/serve.py`` run on these.
"""

from repro.engine.fleet import (  # noqa: F401
    EngineConfig,
    EngineState,
    FleetStepOutput,
    apply_labels,
    broadcast_streams,
    fleet_step,
    gate,
    init_fleet,
    run_fleet,
    stream_slice,
)
