"""Cohort fusion: advance N same-shaped tenants in one fused dispatch.

The multiplexer (``engine/multiplex.py``) round-robins tenants through
*separate* jitted calls, paying a measured 15-45% tick-switch cache
penalty at high tenant counts even when every tenant shares the same
compiled executable.  A **cohort** removes the switches entirely: tenants
with the same ``(cfg, mode, donate)`` and stream width stack their
``EngineState`` pytrees along the leading stream axis (the tenant axis
folded onto S — every per-stream op in ``engine/fleet.py`` is elementwise
or einsum-batched over S, so row r of a stacked dispatch is bit-for-bit
row r of the solo dispatch), and one fused ``plan`` / ``learn`` /
``learn+plan`` call per tick advances all of them.

What fuses, and what stays per-tenant:

* **Fused** — the device work: plan, learn, the steady-state fused
  learn+plan, the queried-mask host sync, and (when tenants collect
  outputs) the per-tick column pulls.
* **Per-tenant** — everything a tenant observes: its ``PendingRing``,
  ``Teacher`` connection, backpressure policy, ``StreamStats`` counters,
  output collection, and tick cursor.  The demux happens at the host
  boundary: each member's slice of the stacked plan drives its own
  ``_submit`` / ``_claim_entry`` exactly as solo, so the per-tenant op
  sequence — and therefore every output, counter, and the query-accounting
  identity — is bit-for-bit the solo run's.

Replies demultiplex back through three learn paths, chosen per reply:

* **aligned** — the common case: a reply whose ring entry is a
  ``stream.PlanSlice`` of a full-width plan at the member's current
  bounds.  All aligned replies of a round that share the same full plan
  combine into ONE full-width learn: each member's mask/labels scatter
  into their row window and everyone else's rows ride along under
  ``mask=False``, which is an exact identity.
* **fused** — when the last round is a single aligned group and no member
  is joining or leaving, its learn fuses with the next tick's stacked plan
  into one dispatch (bitwise identical to the separate dispatches — the
  engine's ops compile reassociation-free, locked by tests).
* **patch** — stragglers: a ticket asked before its tenant joined the
  cohort (live migration in) or before a resize.  Its solo-width plan
  context learns through ``fleet._patch_learn_runner``, which updates just
  that member's row window of the stacked P/beta in place.

Members join (``attach``) and leave (``detach``) mid-stream: detach
writes the member's current rows (and a materialized solo plan) back into
its ``StreamSession``, which then runs solo — so live migration out of a
fused cohort is the ordinary quiesce/snapshot flow, and a restored
snapshot admits straight into a matching cohort slot.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import fleet, stream
from repro.engine.types import EngineState
from repro.runtime import telemetry as _telemetry

_COL_KEYS = ("pred", "outputs", "queried", "theta", "confidence", "mode_training")


class CohortSession:
    """Lockstep driver for N member ``StreamSession``s on one stacked state.

    Members keep their own sessions (ring, teacher, stats, tick cursor);
    while fused, a member's ``session.state`` is stale — the cohort's
    stacked ``state`` is authoritative, and ``detach`` / ``refresh`` write
    the member's rows back.
    """

    def __init__(self, members: list[stream.StreamSession]):
        if not members:
            raise ValueError("cohort needs at least one member")
        head = members[0]
        self.cfg = head.cfg
        self.mode = head.mode
        self.donate = head._donate
        self.ship = head.ship
        self.members: list[stream.StreamSession] = []
        self.bounds: list[tuple[int, int]] = []
        self.state: Optional[EngineState] = None
        # Same LRU keys as the members' own runners: fusing adds no cache
        # entries, the jit specializes internally per stacked width.
        self._plan_fn = stream._plan_runner(self.cfg, self.mode, self.donate)
        self._learn_fn = stream._learn_runner(self.cfg, self.donate)
        self._fused_fn = stream._learn_plan_runner(self.cfg, self.mode, self.donate)
        self._full_mask_dev = None  # cached device-side all-True apply mask
        # Stack every founding member in ONE tree concat (attach-at-a-time
        # would pay N-1 intermediate full copies — measurable at N=16).
        for m in members:
            self._admit_bookkeeping(m)
        self.state = fleet.stack_streams(
            [jax.tree.map(jnp.asarray, m.state) for m in members]
        ) if len(members) > 1 else jax.tree.map(jnp.copy, head.state)

    @property
    def total(self) -> int:
        return self.bounds[-1][1] if self.bounds else 0

    # -- membership --------------------------------------------------------

    def attach(self, sess: stream.StreamSession) -> None:
        """Absorb a session — fresh, running solo, or restored mid-stream.

        Its current state rows are appended to the stacked state; a pending
        solo-width plan (mid-stream join) keeps working through the
        straggler patch-learn path until the next fused plan re-aligns it.
        The caller must supply this member's next tick on the very next
        ``tick()`` — its rows take part in every fused dispatch from then
        on, exactly like its solo session would have.
        """
        self._admit_bookkeeping(sess)
        if self.state is None:
            # Own the rows we are about to donate tick after tick (the
            # member's own buffers must stay valid until detach overwrites
            # its .state); every later attach/detach concat re-owns anyway.
            self.state = jax.tree.map(jnp.copy, sess.state)
        else:
            self.state = fleet.stack_streams(
                [self.state, jax.tree.map(jnp.asarray, sess.state)]
            )

    def _admit_bookkeeping(self, sess: stream.StreamSession) -> None:
        """Validate a joining session and claim its row window — everything
        ``attach`` does except touching the stacked state, so ``__init__``
        can stack all founders in one concat."""
        if (sess.cfg, sess.mode, sess._donate) != (self.cfg, self.mode, self.donate):
            raise ValueError(
                "cohort members must share (cfg, mode, donate); "
                f"got {(sess.cfg, sess.mode, sess._donate)!r}"
            )
        if sess.started() and sess._p is None:
            raise ValueError("cannot attach a session with nothing left to plan")
        s = int(np.shape(np.asarray(sess.state.elm.count))[0])
        lo = self.total
        self.members.append(sess)
        self.bounds.append((lo, lo + s))

    def detach(self, sess: stream.StreamSession) -> stream.StreamSession:
        """Hand a member back to solo operation: write its current rows
        (and a materialized solo plan, if one is pending) back into the
        session and drop them from the stacked state.  Ring entries that
        still hold ``PlanSlice`` views keep working — solo learns slice
        them lazily."""
        i = self.members.index(sess)
        lo, hi = self.bounds[i]
        sess.state = fleet.slice_streams(self.state, lo, hi)
        if isinstance(sess._p, stream.PlanSlice):
            sess._p = sess._p.materialize()
        self.members.pop(i)
        w = hi - lo
        self.bounds = self.bounds[:i] + [
            (a - w, b - w) for a, b in self.bounds[i + 1 :]
        ]
        self.state = (
            fleet.remove_streams(self.state, lo, hi) if self.members else None
        )
        return sess

    def refresh(self, sess: stream.StreamSession) -> None:
        """Write a member's current rows back into its (stale) session
        state without detaching — cadence snapshots of fused members."""
        lo, hi = self.bounds[self.members.index(sess)]
        sess.state = fleet.slice_streams(self.state, lo, hi)

    # -- the fused tick ----------------------------------------------------

    def tick(self, nxts: list) -> tuple[list, bool]:
        """Advance every member one tick with fused device dispatches.

        ``nxts[i]`` is member i's next tick features — its first tick when
        the member has not started, None when its source is exhausted (the
        member finishes this tick's asks/polls/learns like a solo
        ``advance(None)``, then detaches).  Returns ``(detached, advanced)``:
        the sessions handed back to solo operation, and whether any member
        actually advanced a tick (False for the all-start first tick).
        """
        t0 = time.perf_counter()
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("cohort.tick") if tel is not None else None
        members = list(self.members)
        assert len(nxts) == len(members), "one next-tick entry per member"
        # Keep next-tick features on the host: one np.concatenate + ONE
        # transfer ships the whole cohort's tick (vs a device_put per member
        # plus a device-side concat — the old per-tick hot spot).  Members
        # hold their host array as ``_x``; ring tickets and snapshots only
        # ever read its values.
        x_host = [None if x is None else np.asarray(x) for x in nxts]
        full = self._aligned_full()
        queried_full = np.asarray(full.queried) if full is not None else None
        cols_full = None
        if queried_full is not None and any(
            m.collect and m.started() for m in members
        ):
            # One host sync per column for the whole cohort instead of one
            # per member (values are identical either way — pure movement).
            cols_full = {k: np.asarray(getattr(full, k)) for k in _COL_KEYS}

        # Per-member tick bookkeeping: collect, submit asks, claim replies.
        # Cross-member order is irrelevant (rows are independent); each
        # member's own op order matches its solo ``advance`` exactly.
        applies: list[list] = []
        ticking: list[int] = []
        for i, m in enumerate(members):
            if not m.started():
                applies.append([])
                continue
            ticking.append(i)
            lo, hi = self.bounds[i]
            p = m._p
            queried_host = (
                queried_full[lo:hi] if queried_full is not None
                else np.asarray(p.queried)
            )
            if m.collect:
                for k in _COL_KEYS:
                    m._cols[k].append(
                        cols_full[k][lo:hi] if cols_full is not None
                        else np.asarray(getattr(p, k))
                    )
                m._trained_rows.append(np.zeros(queried_host.shape, bool))
            n_q = int(queried_host.sum())
            if n_q:
                m.stats.queries_issued += n_q
                m._submit(m._x, queried_host, p, m.t)
            member_applies = []
            for r in m.teacher.poll(m.t):
                claimed = m._claim_entry(r, m.t)
                if claimed is not None:
                    member_applies.append((claimed[0], claimed[1], r))
            m._flush_deferred(m.t)
            applies.append(member_applies)

        planning = [i for i in range(len(members)) if nxts[i] is not None]
        resizing = len(planning) != len(members)
        p_next = None

        def x_next_stacked():
            hosts = [x_host[i] for i in planning]
            return self.ship(
                np.concatenate(hosts, axis=0) if len(hosts) > 1 else hosts[0]
            )

        # Learns in rounds: round j applies each member's j-th claimed
        # reply, preserving every member's own apply order while letting
        # replies that share a full plan combine into one dispatch.
        n_rounds = max((len(a) for a in applies), default=0)
        for j in range(n_rounds):
            groups: dict[int, list] = {}
            order: list[tuple[int, fleet.PlanOutput]] = []
            stragglers: list[tuple[int, object, np.ndarray, object]] = []
            for i, member_applies in enumerate(applies):
                if j >= len(member_applies):
                    continue
                ent, mask, reply = member_applies[j]
                p = ent.plan
                if (
                    isinstance(p, stream.PlanSlice)
                    and p.full.queried.shape[0] == self.total
                    and (p.lo, p.hi) == self.bounds[i]
                ):
                    key = id(p.full)
                    if key not in groups:
                        groups[key] = []
                        order.append((key, p.full))
                    groups[key].append((i, ent, mask, reply))
                else:
                    stragglers.append((i, ent, mask, reply))
            fuse = (
                j == n_rounds - 1
                and not resizing
                and len(order) == 1
                and not stragglers
            )
            for key, fullp in order:
                args = self._group_args(fullp, groups[key])
                if fuse:
                    (elm2, prune2, drift2, meter2), p_next = self._fused_fn(
                        self.state.elm, self.state.prune, self.state.drift,
                        self.state.meter, *args, x_next_stacked(),
                    )
                    self.state = EngineState(
                        elm=elm2, prune=prune2, drift=drift2, meter=meter2
                    )
                else:
                    new_elm, new_prune = self._learn_fn(
                        self.state.elm, self.state.prune, self.state.drift,
                        self.state.meter, *args,
                    )
                    self.state = self.state._replace(elm=new_elm, prune=new_prune)
            for i, ent, mask, reply in stragglers:
                self._patch_learn(i, ent, mask, reply)

        # Tick accounting for members that advanced (solo `advance` parity;
        # the shared wall time lands in every advanced member's tick_ms).
        for i in ticking:
            m = members[i]
            m.stats.ticks += 1
            m.stats.stream_steps += int(np.shape(m._x)[0])
            m.t += 1

        # Detach exhausted members before the next plan re-slices bounds.
        detached = []
        leaving = [i for i in range(len(members)) if nxts[i] is None]
        if leaving and len(leaving) == len(self.members):
            # Equal-length streams all run dry on the same tick — the common
            # shutdown.  Write each member's rows back with one slice apiece
            # and drop the stacked state wholesale, instead of per-member
            # ``detach`` paying a shrinking remove_streams concat each time.
            for i in leaving:
                m = members[i]
                m._x, m._p = None, None
                m.state = fleet.slice_streams(self.state, *self.bounds[i])
                detached.append(m)
            self.members, self.bounds, self.state = [], [], None
        else:
            for i in leaving:
                m = members[i]
                m._x, m._p = None, None
                detached.append(self.detach(m))

        # Plan the next tick for everyone remaining (starts fresh members).
        if planning and p_next is None:
            (prune2, drift2, meter2), p_next = self._plan_fn(
                self.state.elm, self.state.prune, self.state.drift,
                self.state.meter, x_next_stacked(),
            )
            self.state = self.state._replace(
                prune=prune2, drift=drift2, meter=meter2
            )
        if p_next is not None:
            for idx, i in enumerate(planning):
                m = members[i]
                lo, hi = self.bounds[idx]
                if not m.started():
                    m._t_start = t0
                m._x = x_host[i]
                m._p = stream.PlanSlice(p_next, lo, hi)
        wall_ms = (time.perf_counter() - t0) * 1e3
        rate = 1e3 / wall_ms if wall_ms > 0 else 0.0
        for i in ticking:
            st = members[i].stats
            st.tick_ms.append(wall_ms)
            if rate > 0:  # same load signal solo `advance` keeps
                st.tick_rate_ema = (
                    rate if st.tick_rate_ema == 0.0
                    else st.tick_rate_ema
                    + stream.TICK_RATE_EMA_ALPHA * (rate - st.tick_rate_ema)
                )
        if tok is not None:
            tel.tracer.end(
                tok, members=len(members), s=self.total, detached=len(detached)
            )
        return detached, bool(ticking)

    # -- internals ---------------------------------------------------------

    def _aligned_full(self) -> Optional[fleet.PlanOutput]:
        """The one full-width plan every started member's pending plan
        slices at current bounds — or None (first tick, or a member joined
        mid-stream with a solo plan / pre-resize slice)."""
        full = None
        for i, m in enumerate(self.members):
            if not m.started():
                continue
            p = m._p
            if (
                not isinstance(p, stream.PlanSlice)
                or p.full.queried.shape[0] != self.total
                or (p.lo, p.hi) != self.bounds[i]
            ):
                return None
            if full is None:
                full = p.full
            elif p.full is not full:
                return None
        return full

    def _group_args(self, fullp: fleet.PlanOutput, group: list):
        """Scatter one round's aligned member masks/labels into full-width
        learn args against their shared full plan.  Members outside the
        group ride along under mask=False — an exact identity."""
        total = self.total
        mask_full = np.zeros((total,), bool)
        labels_full = np.zeros((total,), np.int32)
        for i, ent, mask, reply in group:
            lo, hi = self.bounds[i]
            mask_full[lo:hi] = mask
            labels_full[lo:hi] = np.asarray(reply.labels, np.int32)
        if mask_full.all():
            if self._full_mask_dev is None or self._full_mask_dev.shape[0] != total:
                self._full_mask_dev = jnp.ones((total,), jnp.bool_)
            mask_dev = self._full_mask_dev
        else:
            mask_dev = jnp.asarray(mask_full)
        return (
            fullp.h,
            self.ship(labels_full),
            fullp.pred,
            fullp.confidence,
            mask_dev,
            fullp.controller_on,
            fullp.theta,
        )

    def _patch_learn(self, i: int, ent, mask: np.ndarray, reply) -> None:
        """Straggler reply: learn one member's solo-width plan context into
        its row window of the stacked state."""
        m = self.members[i]
        lo, hi = self.bounds[i]
        args = m._build_learn_args(ent, reply, mask)
        fn = fleet._patch_learn_runner(self.cfg, lo, hi, self.donate)
        new_elm, new_prune = fn(
            self.state.elm, self.state.prune, self.state.drift,
            self.state.meter, *args,
        )
        self.state = self.state._replace(elm=new_elm, prune=new_prune)
