"""Shared pytree/config types for the ODL engine (single source of truth).

``EngineConfig`` / ``EngineState`` / ``FleetStepOutput`` describe one ODL
head when their leaves are axis-free, and a whole fleet when every leaf
carries a leading stream axis S.  The scalar-era names (``ODLCoreConfig`` /
``ODLCoreState`` / ``StepOutput``) from the deprecated ``core/odl_head.py``
API are aliases of the *same* classes, so existing checkpoints, configs,
and the paper-repro tests keep working unchanged.

This module is a leaf of the engine package: it imports only ``repro.core``
submodules (never ``core/__init__`` attributes), which keeps the
``repro.core`` -> ``odl_head`` (alias) -> ``repro.engine`` -> ``repro.core``
import cycle resolvable from either entry point.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import drift as drift_mod
from repro.core import labels as labels_mod
from repro.core import oselm, pruning


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """ODL configuration (identical semantics for S = 1 and a fleet)."""

    elm: oselm.OSELMConfig = oselm.OSELMConfig()
    prune: pruning.PruneConfig = None  # type: ignore[assignment]
    drift: drift_mod.DriftConfig = drift_mod.DriftConfig()

    def __post_init__(self):
        if self.prune is None:
            object.__setattr__(
                self, "prune", pruning.PruneConfig.for_hidden(self.elm.n_hidden)
            )


class EngineState(NamedTuple):
    """elm/prune/drift/meter; axis-free leaves for one head, leading-S
    leaves for a fleet."""

    elm: oselm.OSELMState
    prune: pruning.PruneState
    drift: drift_mod.DriftState
    meter: labels_mod.CommMeter


class FleetStepOutput(NamedTuple):
    pred: jnp.ndarray  # int32 local predicted class c
    outputs: jnp.ndarray  # (.., m) raw outputs O
    queried: jnp.ndarray  # bool
    trained: jnp.ndarray  # bool
    theta: jnp.ndarray  # f32 current threshold
    confidence: jnp.ndarray  # f32 p1 - p2
    mode_training: jnp.ndarray  # bool


def init_state(cfg: EngineConfig) -> EngineState:
    """Fresh axis-free (single-head) state; broadcast for a fleet via
    ``engine.broadcast_streams`` / ``engine.init_fleet``."""
    return EngineState(
        elm=oselm.init_state(cfg.elm),
        prune=pruning.init_state(),
        drift=drift_mod.init_state(),
        meter=labels_mod.CommMeter.zero(),
    )


# Scalar-era names (see core/odl_head.py, the documented alias module).
ODLCoreConfig = EngineConfig
ODLCoreState = EngineState
StepOutput = FleetStepOutput
