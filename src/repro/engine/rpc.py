"""RpcTeacher: the ``stream.Teacher`` protocol over a real TCP socket.

``LatencyTeacher`` models the teacher round-trip in *ticks*; this module
replaces the model with an actual network hop so the streaming runtime and
the multiplexer can be exercised against a real transport: a label server
on the other end of a socket, wall-clock latency, and a timeout → loss
mapping (a reply that misses the deadline is treated exactly like a lost
ticket — the runtime's ring entry drains as ``queries_lost``, and a
straggler reply that limps in after its timeout is discarded, never
applied).

Wire protocol (loopback-grade, stdlib-only): newline-delimited JSON, one
object per line.

  request:  {"ticket": int, "tick": int, "mask": [bool, ...],
             "feats": [[f, ...], ...]}
  reply:    {"ticket": int, "labels": [int, ...], "answered": [bool, ...]}

Authentication (``secret=...`` / ``--secret``): a *mutual* shared-secret
HMAC challenge–response on connect.  The server opens every connection
with ``{"challenge": <hex nonce>}``; the client answers
``{"auth": HMAC_SHA256(secret, challenge), "nonce": <hex nonce>}``; the
server verifies the digest and answers the client's nonce with
``{"auth_ok": HMAC_SHA256(secret, nonce)}`` before any label traffic.  A
wrong or missing digest closes the socket (an unauthenticated client
never receives a label), and a server that cannot answer the client's
nonce — an imposter that merely emits a challenge — is rejected by the
client before any of its labels can train the fleet.  Without a secret
the handshake is skipped entirely (backwards compatible).

The bundled ``LabelServer`` answers deterministically —
``label[s] = (7 * tick + s) % n_out`` — so round-trip tests can assert
exact labels; a real deployment would put the pod-side backbone ensemble
behind the same two message shapes.  Run it standalone::

    PYTHONPATH=src python -m repro.engine.rpc --port 0 --n-out 6

(``--port 0`` binds an ephemeral port and prints ``PORT <p>`` on stdout —
that is what ``loopback_server`` parses), or self-test the full
client/server round trip in one process pair::

    PYTHONPATH=src python -m repro.engine.rpc --selftest
"""

from __future__ import annotations

import argparse
import contextlib
import hmac
import json
import os
import pathlib
import secrets as secrets_mod
import socket
import subprocess
import sys
import threading
import time
from typing import Optional

import numpy as np

from repro.engine.stream import TeacherReply


def expected_label(tick: int, s: int, n_out: int) -> int:
    """The deterministic rule ``LabelServer`` answers with."""
    return (7 * tick + s) % n_out


def _digest(secret: str, challenge: str) -> str:
    return hmac.new(
        secret.encode(), challenge.encode(), "sha256"
    ).hexdigest()


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class LabelServer:
    """Threaded loopback label server (one thread per client connection)."""

    def __init__(self, port: int = 0, n_out: int = 6, delay_s: float = 0.0,
                 host: str = "127.0.0.1", secret: Optional[str] = None):
        self.n_out = n_out
        self.delay_s = delay_s
        self.secret = secret
        self.auth_failures = 0  # connections rejected by the HMAC handshake
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.port = self._sock.getsockname()[1]
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break
            t = threading.Thread(target=self._client, args=(conn,), daemon=True)
            t.start()
            self._threads.append(t)

    def start(self) -> "LabelServer":
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        self._threads.append(t)
        return self

    def close(self) -> None:
        self._stop.set()
        with contextlib.suppress(OSError):
            self._sock.close()

    def _client(self, conn: socket.socket) -> None:
        with conn, conn.makefile("rwb") as f:
            if self.secret is not None and not self._handshake(f):
                self.auth_failures += 1
                return  # close unauthenticated connections before any label
            for line in f:
                try:
                    req = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if self.delay_s:
                    time.sleep(self.delay_s)
                mask = req.get("mask", [])
                labels = [
                    expected_label(req.get("tick", 0), s, self.n_out)
                    for s in range(len(mask))
                ]
                out = {"ticket": req["ticket"], "labels": labels, "answered": mask}
                try:
                    f.write((json.dumps(out) + "\n").encode())
                    f.flush()
                except OSError:
                    break

    def _handshake(self, f) -> bool:
        """Mutual challenge–response: send a nonce, require its keyed digest
        back (constant-time compare), then prove *our* knowledge of the
        secret by answering the client's nonce — all before serving a
        single label."""
        challenge = secrets_mod.token_hex(16)
        try:
            f.write((json.dumps({"challenge": challenge}) + "\n").encode())
            f.flush()
            line = f.readline()
        except OSError:
            return False
        try:
            reply = json.loads(line)
        except json.JSONDecodeError:
            return False
        if not isinstance(reply, dict):
            return False
        if not hmac.compare_digest(
            str(reply.get("auth", "")), _digest(self.secret, challenge)
        ):
            return False
        try:
            f.write((json.dumps(
                {"auth_ok": _digest(self.secret, str(reply.get("nonce", "")))}
            ) + "\n").encode())
            f.flush()
        except OSError:
            return False
        return True


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class RpcTeacher:
    """``stream.Teacher`` over a TCP socket, with timeout → loss mapping.

    ``ask`` serializes the tick's features + mask and sends them; a reader
    thread validates each reply against its ticket's deadline *at arrival
    time* and queues the survivors in an inbox that ``poll`` drains — so a
    reply that made the deadline is never lost to a late poll (e.g. a tick
    stalled behind an XLA compile).  A ticket unanswered for ``timeout_s``
    wall seconds leaves ``in_flight()`` and is mapped to loss: the
    runtime's pending ring entry is never claimed (it drains as
    ``queries_lost``), and a reply that misses its deadline is dropped at
    arrival (counted in ``timed_out``) — never delivered, so a stale
    straggler cannot train the fleet.
    """

    def __init__(self, host: str, port: int, timeout_s: float = 5.0,
                 connect_timeout_s: float = 5.0, secret: Optional[str] = None):
        self.timeout_s = timeout_s
        self._sock = socket.create_connection((host, port), timeout=connect_timeout_s)
        self._wfile = self._sock.makefile("wb")
        if secret is not None:
            # Mutual authentication, synchronously, before the reader thread
            # owns the socket: answer the server's nonce with its keyed
            # digest, then require the server to answer OURS — a server that
            # sends no challenge, or that cannot prove it knows the secret
            # (an imposter emitting a bare challenge to fish for labels to
            # train us on), is refused before any label traffic.
            with self._sock.makefile("rb") as rf:
                try:
                    hello = json.loads(rf.readline())
                except (OSError, json.JSONDecodeError):
                    hello = None  # silent/closed server: not authenticated
                if not isinstance(hello, dict) or "challenge" not in hello:
                    self._sock.close()
                    raise ConnectionError(
                        "label server sent no auth challenge but a "
                        "--teacher-secret is configured; refusing the "
                        "unauthenticated connection"
                    )
                nonce = secrets_mod.token_hex(16)
                self._wfile.write((json.dumps({
                    "auth": _digest(secret, hello["challenge"]),
                    "nonce": nonce,
                }) + "\n").encode())
                self._wfile.flush()
                try:
                    proof = json.loads(rf.readline())
                except (OSError, json.JSONDecodeError):
                    proof = None
            ok = isinstance(proof, dict) and hmac.compare_digest(
                str(proof.get("auth_ok", "")), _digest(secret, nonce)
            )
            if not ok:
                self._sock.close()
                raise ConnectionError(
                    "label server failed to prove knowledge of the shared "
                    "secret; refusing to train on its labels"
                )
        # connect_timeout_s governed the dial (and the auth readline above);
        # steady-state reads must block indefinitely — reply deadlines are
        # enforced per ticket, not by a socket idle timeout.
        self._sock.settimeout(None)
        self._lock = threading.Lock()
        self._next_ticket = 0
        # ticket -> wall deadline; present == still in flight.
        self._pending: dict[int, float] = {}
        self._inbox: list[TeacherReply] = []
        self.timed_out = 0  # tickets whose reply missed (or never made) the deadline
        self._reader = threading.Thread(target=self._read_loop, daemon=True)
        self._reader.start()

    def _read_loop(self) -> None:
        try:
            with self._sock.makefile("rb") as f:
                for line in f:
                    try:
                        msg = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    if not isinstance(msg, dict) or "ticket" not in msg:
                        continue  # e.g. an unexpected auth challenge
                    reply = TeacherReply(
                        ticket=int(msg["ticket"]),
                        labels=np.asarray(msg["labels"], np.int32),
                        answered=np.asarray(msg["answered"], bool),
                    )
                    arrived = time.monotonic()
                    with self._lock:
                        deadline = self._pending.pop(reply.ticket, None)
                        if deadline is None:
                            # Unknown ticket, or already expired (and
                            # counted) by _expire.
                            continue
                        if arrived > deadline:
                            self.timed_out += 1  # straggler: timeout -> loss
                            continue
                        self._inbox.append(reply)
        except (OSError, ValueError):
            pass  # socket closed

    def ask(self, feats, mask, tick: int) -> int:
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._pending[ticket] = time.monotonic() + self.timeout_s
        req = {
            "ticket": ticket,
            "tick": int(tick),
            "mask": np.asarray(mask, bool).tolist(),
            "feats": np.asarray(feats, np.float32).tolist(),
        }
        try:
            self._wfile.write((json.dumps(req) + "\n").encode())
            self._wfile.flush()
        except OSError:
            # Dead socket == permanent outage: the ticket stays pending
            # until its deadline, then maps to loss like any other timeout.
            pass
        return ticket

    def _expire(self) -> None:
        now = time.monotonic()
        with self._lock:
            dead = [t for t, dl in self._pending.items() if dl < now]
            for t in dead:
                del self._pending[t]
                self.timed_out += 1

    def poll(self, tick: int) -> list[TeacherReply]:
        self._expire()  # never-arrived tickets past their deadline -> loss
        with self._lock:
            out, self._inbox = self._inbox, []
        return out

    def in_flight(self) -> int:
        self._expire()
        with self._lock:
            return len(self._pending)

    def close(self) -> None:
        with contextlib.suppress(OSError):
            self._wfile.close()
        with contextlib.suppress(OSError):
            self._sock.close()

    def __enter__(self) -> "RpcTeacher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Loopback subprocess helper
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def loopback_server(n_out: int = 6, delay_s: float = 0.0,
                    secret: Optional[str] = None):
    """Spawn ``python -m repro.engine.rpc`` as a subprocess label server on
    an ephemeral loopback port; yields ``(host, port)`` and tears the
    process down on exit."""
    src_root = str(pathlib.Path(__file__).resolve().parents[2])
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.engine.rpc", "--port", "0",
           "--n-out", str(n_out), "--delay-ms", str(int(delay_s * 1000))]
    if secret is not None:
        cmd += ["--secret", secret]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, env=env, text=True)
    try:
        line = proc.stdout.readline()
        if not line.startswith("PORT "):
            raise RuntimeError(f"label server failed to start: {line!r}")
        yield "127.0.0.1", int(line.split()[1])
    finally:
        proc.terminate()
        proc.wait(timeout=10)


def _selftest() -> int:
    """Round trips over a subprocess loopback server (CI smoke): plain, then
    HMAC-authenticated, then an unauthenticated client against a secured
    server (must get nothing)."""
    s, n_out = 4, 6
    feats = np.zeros((s, 3), np.float32)
    mask = np.ones((s,), bool)

    def roundtrip(host, port, secret=None, timeout_s=10.0):
        with RpcTeacher(host, port, timeout_s=timeout_s, secret=secret) as teacher:
            ticket = teacher.ask(feats, mask, tick=3)
            deadline = time.monotonic() + 10.0
            replies = []
            while not replies and time.monotonic() < deadline:
                if teacher.in_flight() == 0 and not replies:
                    replies = teacher.poll(0)
                    break
                replies = teacher.poll(0)
                time.sleep(0.01)
            return ticket, replies

    want = [expected_label(3, i, n_out) for i in range(s)]
    with loopback_server(n_out=n_out) as (host, port):
        ticket, replies = roundtrip(host, port)
        assert replies and replies[0].ticket == ticket, "no reply"
        assert replies[0].labels.tolist() == want, replies[0].labels
    with loopback_server(n_out=n_out, secret="s3cr3t") as (host, port):
        ticket, replies = roundtrip(host, port, secret="s3cr3t")
        assert replies and replies[0].labels.tolist() == want, "auth roundtrip"
        # Unauthenticated client: the server closes the connection; the ask
        # times out into loss and no label ever arrives.
        _, replies = roundtrip(host, port, secret=None, timeout_s=0.5)
        assert not replies, "unauthenticated client must receive nothing"
    print("rpc selftest OK (plain + hmac + reject):", want)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--n-out", type=int, default=6)
    ap.add_argument("--delay-ms", type=int, default=0,
                    help="server-side per-request delay (timeout testing)")
    ap.add_argument("--secret", default=None,
                    help="shared secret: require the HMAC challenge-response "
                    "handshake on every connection")
    ap.add_argument("--selftest", action="store_true",
                    help="spawn a loopback server and round-trip one ask")
    args = ap.parse_args(argv)
    if args.selftest:
        return _selftest()
    server = LabelServer(port=args.port, n_out=args.n_out,
                         delay_s=args.delay_ms / 1000.0, secret=args.secret)
    print(f"PORT {server.port}", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
